"""The paper's own evaluation models (ForkKV §7.1): Llama3-8B, Qwen2.5-7B,
Qwen2.5-14B — used by the benchmark suite, not part of the assigned pool."""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

LLAMA3_8B = ModelConfig(
    name="llama3-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
    lora=LoRAConfig(rank=16), scan_layers=True, citation="arXiv:2407.21783")

QWEN25_7B = ModelConfig(
    name="qwen2.5-7b", family="dense", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    lora=LoRAConfig(rank=16), scan_layers=True, citation="Qwen2.5")

QWEN25_14B = ModelConfig(
    name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=13824, vocab_size=152064,
    lora=LoRAConfig(rank=16), scan_layers=True, citation="Qwen2.5")


def tiny_serving_model(rank: int = 16) -> ModelConfig:
    """Small llama-family model for the CPU serving engine / benchmarks."""
    return ModelConfig(
        name="serve-tiny", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=1024,
        dtype="float32", lora=LoRAConfig(rank=rank), scan_layers=True,
        remat=False)
