"""dbrx-132b [moe]: 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe", num_layers=40, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=10752, vocab_size=100352,
    num_experts=16, num_experts_per_tok=4, moe_d_ff=10752,
    lora=LoRAConfig(rank=16), scan_layers=True, scan_groups=8,
    citation="hf:databricks/dbrx-base")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="dbrx-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, moe_d_ff=256, vocab_size=512,
        num_experts=4, num_experts_per_tok=2, dtype="float32",
        moe_capacity_factor=8.0,
        scan_groups=0, remat=False)
