"""Pallas ResidualAttention kernels vs. the pure-jnp oracle.

Sweeps shapes, dtypes, GQA group sizes, ranks, windows and cache-length
padding; asserts allclose between the interpret-mode kernel and ref.py.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rope as rope_lib
from repro.kernels import ref as ref_mod
from repro.kernels import residual_attention as ra


def make_inputs(key, *, bsz, sq, sk, hq, hkv, d, r, dtype, decode=False):
    ks = jax.random.split(key, 8)
    q = jax.random.normal(ks[0], (bsz, sq, hq, d), dtype)
    k_base = jax.random.normal(ks[1], (bsz, sk, hkv, d), dtype)
    v_base = jax.random.normal(ks[2], (bsz, sk, hkv, d), dtype)
    k_res = jax.random.normal(ks[3], (bsz, sk, r), dtype) * 0.3
    v_res = jax.random.normal(ks[4], (bsz, sk, r), dtype) * 0.3
    b_k = jax.random.normal(ks[5], (bsz, r, hkv * d), dtype) * 0.3
    b_v = jax.random.normal(ks[6], (bsz, r, hkv * d), dtype) * 0.3
    kpos = jnp.broadcast_to(jnp.arange(sk), (bsz, sk))
    sin, cos = rope_lib.rope_sincos(kpos, d)
    sin, cos = sin.astype(dtype), cos.astype(dtype)
    if decode:
        kv_len = jax.random.randint(ks[7], (bsz,), 1, sk + 1)
        qpos = (kv_len - 1)[:, None]
    else:
        kv_len = jnp.full((bsz,), sk, jnp.int32)
        qpos = jnp.broadcast_to(jnp.arange(sq), (bsz, sq))
    return q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len


def tolerances(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bsz,sq,sk,hq,hkv,d,r", [
    (1, 128, 128, 4, 4, 64, 16),      # MHA
    (2, 64, 192, 8, 2, 64, 16),       # GQA group 4, sk not block-multiple
    (1, 100, 257, 6, 1, 128, 8),      # MQA, ragged shapes
    (2, 128, 128, 4, 4, 64, 32),      # larger rank
])
def test_prefill_matches_ref(dtype, bsz, sq, sk, hq, hkv, d, r):
    inp = make_inputs(jax.random.PRNGKey(0), bsz=bsz, sq=sq, sk=sk, hq=hq,
                      hkv=hkv, d=d, r=r, dtype=dtype)
    q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len = inp
    scale = d ** -0.5
    got = ra.residual_attention_prefill(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len,
        scale=scale, block_q=64, block_k=64, interpret=True)
    want = ref_mod.residual_attention_ref(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
        qpos=qpos, kv_len=kv_len, scale=scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **tolerances(dtype))


@pytest.mark.parametrize("window", [0, 32])
def test_prefill_sliding_window(window):
    dtype = jnp.float32
    inp = make_inputs(jax.random.PRNGKey(1), bsz=1, sq=96, sk=96, hq=4,
                      hkv=2, d=64, r=16, dtype=dtype)
    q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len = inp
    got = ra.residual_attention_prefill(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len,
        scale=0.125, window=window, block_q=32, block_k=32, interpret=True)
    want = ref_mod.residual_attention_ref(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
        qpos=qpos, kv_len=kv_len, window=window, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **tolerances(dtype))


def test_prefill_chunked_offset():
    """Chunked prefill: queries are a later chunk attending to a longer cache."""
    dtype = jnp.float32
    bsz, sq, sk, hq, hkv, d, r = 1, 64, 192, 4, 2, 64, 16
    inp = make_inputs(jax.random.PRNGKey(2), bsz=bsz, sq=sq, sk=sk, hq=hq,
                      hkv=hkv, d=d, r=r, dtype=dtype)
    q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, _, _ = inp
    qpos = jnp.broadcast_to(jnp.arange(128, 128 + sq), (bsz, sq))
    kv_len = jnp.asarray([128 + sq], jnp.int32)
    got = ra.residual_attention_prefill(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len,
        scale=0.125, block_q=64, block_k=64, interpret=True)
    want = ref_mod.residual_attention_ref(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
        qpos=qpos, kv_len=kv_len, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **tolerances(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bsz,sk,hq,hkv,d,r,window", [
    (4, 256, 8, 2, 64, 16, 0),
    (2, 130, 4, 4, 128, 8, 0),
    (3, 256, 4, 1, 64, 32, 64),      # MQA + sliding window
])
def test_decode_matches_ref(dtype, bsz, sk, hq, hkv, d, r, window):
    inp = make_inputs(jax.random.PRNGKey(3), bsz=bsz, sq=1, sk=sk, hq=hq,
                      hkv=hkv, d=d, r=r, dtype=dtype, decode=True)
    q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len = inp
    scale = d ** -0.5
    got = ra.residual_attention_decode(
        q[:, 0], k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, kv_len,
        scale=scale, window=window, block_k=64, interpret=True)
    want = ref_mod.residual_attention_ref(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
        qpos=qpos, kv_len=kv_len, window=window, scale=scale)[:, 0]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **tolerances(dtype))


def test_zero_residual_reduces_to_plain_attention():
    """With zero rCache the kernel must equal vanilla attention on bCache."""
    from repro.core import attention as attn_lib
    dtype = jnp.float32
    inp = make_inputs(jax.random.PRNGKey(4), bsz=2, sq=64, sk=64, hq=4,
                      hkv=2, d=64, r=16, dtype=dtype)
    q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len = inp
    z = jnp.zeros_like(k_res)
    got = ra.residual_attention_prefill(
        q, k_base, v_base, z, z, b_k, b_v, sin, cos, qpos, kv_len,
        scale=0.125, block_q=32, block_k=32, interpret=True)
    want = attn_lib.mha(q, k_base, v_base, causal=True, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# RG-LRU linear-scan kernel (Griffin recurrence)
# --------------------------------------------------------------------------
def _lru_oracle(a, b, h0):
    bb = b.at[:, 0].add(a[:, 0] * h0)

    def op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, states = jax.lax.associative_scan(op, (a, bb), axis=1)
    return states, states[:, -1]


@pytest.mark.parametrize("bsz,s,w,bs,bw,dtype", [
    (2, 128, 128, 64, 64, jnp.float32),
    (1, 200, 96, 64, 64, jnp.float32),      # ragged shapes (padding path)
    (2, 128, 128, 64, 64, jnp.bfloat16),
])
def test_rg_lru_matches_oracle(bsz, s, w, bs, bw, dtype):
    from repro.kernels.rg_lru import rg_lru_scan
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.nn.sigmoid(jax.random.normal(k[0], (bsz, s, w))).astype(dtype)
    b = (jax.random.normal(k[1], (bsz, s, w)) * 0.2).astype(dtype)
    h0 = (jax.random.normal(k[2], (bsz, w)) * 0.5).astype(dtype)
    got, hlast = rg_lru_scan(a, b, h0, block_s=bs, block_w=bw,
                             interpret=True)
    want, wlast = _lru_oracle(a.astype(jnp.float32),
                              b.astype(jnp.float32),
                              h0.astype(jnp.float32))
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **tol)
    np.testing.assert_allclose(np.asarray(hlast, np.float32),
                               np.asarray(wlast), **tol)


# --------------------------------------------------------------------------
# Paged ResidualAttention decode (block tables via scalar prefetch)
# --------------------------------------------------------------------------
def make_paged_inputs(key, *, bsz, hq, hkv, d, r, page, npages, pool,
                      kv_len=None):
    ks = jax.random.split(key, 8)
    kb_pool = jax.random.normal(ks[0], (pool, page, hkv, d))
    vb_pool = jax.random.normal(ks[1], (pool, page, hkv, d))
    kr_pool = jax.random.normal(ks[2], (pool, page, r)) * 0.3
    vr_pool = jax.random.normal(ks[3], (pool, page, r)) * 0.3
    q = jax.random.normal(ks[4], (bsz, hq, d))
    b_k = jax.random.normal(ks[5], (bsz, r, hkv * d)) * 0.3
    b_v = jax.random.normal(ks[6], (bsz, r, hkv * d)) * 0.3
    perm = np.stack([np.random.default_rng(i).permutation(pool)[:npages]
                     for i in range(bsz)])
    bt = jnp.asarray(perm, jnp.int32)
    s = npages * page
    if kv_len is None:
        kv_len = [s] + [max(1, s // (i + 2)) for i in range(bsz - 1)]
    kv_len = jnp.asarray(kv_len, jnp.int32)
    return q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, kv_len


def paged_dense_oracle(q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v,
                       bt, kv_len, *, use_rope=True):
    bsz, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    s = bt.shape[1] * page
    r = kr_pool.shape[-1]
    kb = kb_pool[bt].reshape(bsz, s, hkv, d)
    vb = vb_pool[bt].reshape(bsz, s, hkv, d)
    kr = kr_pool[bt].reshape(bsz, s, r)
    vr = vr_pool[bt].reshape(bsz, s, r)
    pos = jnp.broadcast_to(jnp.arange(s), (bsz, s))
    if use_rope:
        sin, cos = rope_lib.rope_sincos(pos, d)
    else:
        sin = jnp.zeros(pos.shape + (d // 2,), jnp.float32)
        cos = jnp.ones(pos.shape + (d // 2,), jnp.float32)
    return ref_mod.residual_attention_ref(
        q[:, None], kb, vb, kr, vr, b_k, b_v, sin, cos,
        qpos=(kv_len - 1)[:, None], kv_len=kv_len, scale=d ** -0.5)[:, 0]


@pytest.mark.parametrize("bsz,hq,hkv,d,r,page,npages,pool", [
    (3, 8, 2, 64, 16, 16, 8, 64),     # GQA group 4
    (2, 4, 4, 128, 8, 32, 4, 32),     # MHA, bigger pages, rank 8
])
def test_paged_decode_matches_dense_oracle(bsz, hq, hkv, d, r, page,
                                           npages, pool):
    from repro.kernels.paged_residual_attention import (
        paged_residual_attention_decode)
    inp = make_paged_inputs(jax.random.PRNGKey(0), bsz=bsz, hq=hq, hkv=hkv,
                            d=d, r=r, page=page, npages=npages, pool=pool)
    q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, kv_len = inp
    got = paged_residual_attention_decode(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, kv_len,
        scale=d ** -0.5, interpret=True)
    want = paged_dense_oracle(q, kb_pool, vb_pool, kr_pool, vr_pool,
                              b_k, b_v, bt, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bsz,hq,hkv,d,r,use_rope", [
    (2, 8, 1, 64, 16, True),          # MQA, group 8
    (2, 12, 4, 64, 8, True),          # GQA group 3, small rank
    (2, 8, 2, 64, 32, True),          # GQA group 4, large rank
    (2, 8, 2, 64, 16, False),         # RoPE disabled (whisper-style)
])
def test_paged_dispatcher_backends_agree(bsz, hq, hkv, d, r, use_rope):
    """ops.paged_residual_attention: the Pallas kernel (interpret) and the
    XLA gather mirror must agree — the serving executor swaps between them
    with one flag, so they must be interchangeable."""
    from repro.kernels import ops as kernel_ops
    page, npages, pool = 16, 4, 32
    inp = make_paged_inputs(jax.random.PRNGKey(1), bsz=bsz, hq=hq, hkv=hkv,
                            d=d, r=r, page=page, npages=npages, pool=pool)
    q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, kv_len = inp
    kw = dict(scale=d ** -0.5, use_rope=use_rope)
    got = kernel_ops.paged_residual_attention(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, kv_len,
        backend="pallas", interpret=True, **kw)
    want = kernel_ops.paged_residual_attention(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, kv_len,
        backend="ref", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    oracle = paged_dense_oracle(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                b_k, b_v, bt, kv_len, use_rope=use_rope)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_base_only_variant():
    """Base-only kernel == disaggregated kernel with zero residuals ==
    ref backend with kr_pool=None (unified caches / no-LoRA requests)."""
    from repro.kernels import ops as kernel_ops
    from repro.kernels.paged_residual_attention import (
        paged_attention_decode_base, paged_residual_attention_decode)
    bsz, hq, hkv, d, r, page, npages, pool = 3, 8, 2, 64, 16, 16, 4, 32
    inp = make_paged_inputs(jax.random.PRNGKey(2), bsz=bsz, hq=hq, hkv=hkv,
                            d=d, r=r, page=page, npages=npages, pool=pool)
    q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, kv_len = inp
    got = paged_attention_decode_base(q, kb_pool, vb_pool, bt, kv_len,
                                      scale=d ** -0.5, interpret=True)
    want_ref = kernel_ops.paged_residual_attention(
        q, kb_pool, vb_pool, None, None, None, None, bt, None, kv_len,
        backend="ref", scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=2e-5, atol=2e-5)
    z = jnp.zeros_like(kr_pool)
    want_zero = paged_residual_attention_decode(
        q, kb_pool, vb_pool, z, z, jnp.zeros_like(b_k), jnp.zeros_like(b_v),
        bt, bt, kv_len, scale=d ** -0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_zero),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_ragged_kv_len_page_skip():
    """Per-request page skipping: rows whose kv_len covers 1 page out of a
    wide table (the clamped index maps + pl.when guard) must still match
    the oracle exactly — including the kv_len=1 degenerate row."""
    from repro.kernels.paged_residual_attention import (
        paged_residual_attention_decode)
    bsz, hq, hkv, d, r, page, npages, pool = 4, 4, 2, 64, 16, 16, 8, 64
    s = npages * page
    inp = make_paged_inputs(jax.random.PRNGKey(3), bsz=bsz, hq=hq, hkv=hkv,
                            d=d, r=r, page=page, npages=npages, pool=pool,
                            kv_len=[1, page, page + 3, s])
    q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, kv_len = inp
    got = paged_residual_attention_decode(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, kv_len,
        scale=d ** -0.5, interpret=True)
    want = paged_dense_oracle(q, kb_pool, vb_pool, kr_pool, vr_pool,
                              b_k, b_v, bt, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# Paged chunked-prefill kernels (DESIGN.md §13): ragged chunk/window shapes
# --------------------------------------------------------------------------
def paged_prefill_dense_oracle(q, kb_pool, vb_pool, kr_pool, vr_pool, b_k,
                               b_v, bt, start, kv_len, *, window=0):
    """Independent oracle: gather pages -> contiguous views -> the dense
    residual_attention_ref with explicit qpos/kv_len/window masking."""
    bsz, sq, hq, d = q.shape
    page = kb_pool.shape[1]
    s = bt.shape[1] * page
    r = kr_pool.shape[-1]
    kb = kb_pool[bt].reshape(bsz, s, kb_pool.shape[2], d)
    vb = vb_pool[bt].reshape(bsz, s, kb_pool.shape[2], d)
    kr = kr_pool[bt].reshape(bsz, s, r)
    vr = vr_pool[bt].reshape(bsz, s, r)
    pos = jnp.broadcast_to(jnp.arange(s), (bsz, s))
    sin, cos = rope_lib.rope_sincos(pos, d)
    qpos = start[:, None] + jnp.arange(sq)[None]
    return ref_mod.residual_attention_ref(
        q, kb, vb, kr, vr, b_k, b_v, sin, cos, qpos=qpos, kv_len=kv_len,
        window=window, scale=d ** -0.5)


@pytest.mark.parametrize("sq,starts,window", [
    (27, (0, 5, 96), 0),       # chunk boundaries straddle pages, ragged
    (1, (0, 15, 63), 0),       # chunk == 1 degenerate case
    (16, (3, 48, 100), 5),     # window smaller than one page
    (24, (0, 20, 70), 24),     # window straddling a page boundary
])
def test_paged_prefill_matches_dense_oracle(sq, starts, window):
    """The chunked-prefill grid (running softmax across page steps, causal
    mask within the chunk, window-clamped page walk) must match the dense
    oracle for ragged starts/chunks — including rows mid-page."""
    from repro.kernels.paged_residual_attention import (
        paged_residual_attention_prefill)
    bsz, hq, hkv, d, r, page, npages, pool = len(starts), 8, 2, 64, 16, \
        16, 8, 64
    inp = make_paged_inputs(jax.random.PRNGKey(5), bsz=bsz, hq=hq, hkv=hkv,
                            d=d, r=r, page=page, npages=npages, pool=pool)
    _, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, _ = inp
    q = jax.random.normal(jax.random.PRNGKey(6), (bsz, sq, hq, d))
    start = jnp.asarray(starts, jnp.int32)
    kv_len = start + sq
    got = paged_residual_attention_prefill(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, start,
        kv_len, scale=d ** -0.5, window=window, interpret=True)
    want = paged_prefill_dense_oracle(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                      b_k, b_v, bt, start, kv_len,
                                      window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [0, 24])
def test_paged_prefill_dispatcher_backends_agree(window):
    """ops.paged_residual_attention_prefill: the Pallas kernel (interpret)
    and the XLA gather mirror must be interchangeable — the serving
    executor swaps them with one flag."""
    from repro.kernels import ops as kernel_ops
    bsz, sq, hq, hkv, d, r, page, npages, pool = 2, 20, 4, 1, 64, 8, 16, \
        4, 32
    inp = make_paged_inputs(jax.random.PRNGKey(7), bsz=bsz, hq=hq, hkv=hkv,
                            d=d, r=r, page=page, npages=npages, pool=pool)
    _, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, _ = inp
    q = jax.random.normal(jax.random.PRNGKey(8), (bsz, sq, hq, d))
    start = jnp.asarray([7, 30], jnp.int32)
    kv_len = start + sq
    kw = dict(scale=d ** -0.5, window=window)
    got = kernel_ops.paged_residual_attention_prefill(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, start,
        kv_len, backend="pallas", interpret=True, **kw)
    want = kernel_ops.paged_residual_attention_prefill(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, start,
        kv_len, backend="ref", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_base_only_variant():
    """Base-only prefill kernel == disaggregated kernel with zero
    residuals == ref backend with kr_pool=None == the dense oracle with a
    zero residual stream (unified caches / base-only prefill)."""
    from repro.kernels import ops as kernel_ops
    from repro.kernels.paged_residual_attention import (
        paged_attention_prefill_base, paged_residual_attention_prefill)
    bsz, sq, hq, hkv, d, r, page, npages, pool = 2, 18, 8, 2, 64, 16, 16, \
        6, 48
    inp = make_paged_inputs(jax.random.PRNGKey(9), bsz=bsz, hq=hq, hkv=hkv,
                            d=d, r=r, page=page, npages=npages, pool=pool)
    _, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, _ = inp
    q = jax.random.normal(jax.random.PRNGKey(10), (bsz, sq, hq, d))
    start = jnp.asarray([0, 41], jnp.int32)
    kv_len = start + sq
    got = paged_attention_prefill_base(q, kb_pool, vb_pool, bt, start,
                                       kv_len, scale=d ** -0.5,
                                       interpret=True)
    want_ref = kernel_ops.paged_residual_attention_prefill(
        q, kb_pool, vb_pool, None, None, None, None, bt, None, start,
        kv_len, backend="ref", scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=2e-5, atol=2e-5)
    z = jnp.zeros_like(kr_pool)
    want_zero = paged_residual_attention_prefill(
        q, kb_pool, vb_pool, z, z, jnp.zeros_like(b_k),
        jnp.zeros_like(b_v), bt, bt, start, kv_len, scale=d ** -0.5,
        interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_zero),
                               rtol=2e-5, atol=2e-5)
    want_oracle = paged_prefill_dense_oracle(
        q, kb_pool, vb_pool, z, z, jnp.zeros_like(b_k),
        jnp.zeros_like(b_v), bt, start, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_oracle),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [5, 32])
def test_paged_decode_sliding_window_matches_ref(window):
    """SWA decode through the paged kernels (window-clamped page walk +
    in-page masking) vs the gather mirror, across ragged kv_len including
    windows smaller than one page and kv_len < window."""
    from repro.kernels import ops as kernel_ops
    bsz, hq, hkv, d, r, page, npages, pool = 4, 8, 2, 64, 16, 16, 8, 64
    s = npages * page
    inp = make_paged_inputs(jax.random.PRNGKey(11), bsz=bsz, hq=hq,
                            hkv=hkv, d=d, r=r, page=page, npages=npages,
                            pool=pool, kv_len=[3, page, 77, s])
    q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, kv_len = inp
    kw = dict(scale=d ** -0.5, window=window)
    got = kernel_ops.paged_residual_attention(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, kv_len,
        backend="pallas", interpret=True, **kw)
    want = kernel_ops.paged_residual_attention(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt, bt, kv_len,
        backend="ref", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # base-only variant under the same window
    got_b = kernel_ops.paged_residual_attention(
        q, kb_pool, vb_pool, None, None, None, None, bt, None, kv_len,
        backend="pallas", interpret=True, **kw)
    want_b = kernel_ops.paged_residual_attention(
        q, kb_pool, vb_pool, None, None, None, None, bt, None, kv_len,
        backend="ref", **kw)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b),
                               rtol=2e-5, atol=2e-5)
