"""Config registry: ``--arch <id>`` resolution + input specs per shape.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given (architecture x input-shape) combination — the
weak-type-correct, shardable, allocation-free pattern the dry-run lowers
against.
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import INPUT_SHAPES, ModelConfig, ShapeConfig, shape_by_name

ARCH_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "dbrx-132b": "dbrx_132b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "starcoder2-3b": "starcoder2_3b",
    "mamba2-130m": "mamba2_130m",
    "internlm2-1.8b": "internlm2_1_8b",
    "llama3-405b": "llama3_405b",
    "whisper-large-v3": "whisper_large_v3",
}

ARCH_IDS = tuple(ARCH_MODULES)


def _module(arch_id: str):
    if arch_id not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_tiny_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).tiny()


# --------------------------------------------------------------------------
# Shape applicability (DESIGN.md section 5): long_500k requires sub-quadratic
# attention — run only for SSM / hybrid / SWA archs.
# --------------------------------------------------------------------------
SUB_QUADRATIC = ("mamba2-130m", "recurrentgemma-9b", "h2o-danube-3-4b")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.name in SUB_QUADRATIC or cfg.sliding_window > 0 or \
            cfg.family in ("ssm", "hybrid")
    return True


def applicable_pairs():
    """All (arch_id, shape) baseline pairs (33 of the 10x4=40)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES:
            if shape_applicable(cfg, shape):
                out.append((arch, shape.name))
    return out


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct, no allocation)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                n_adapters: int = 8) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch x shape).  Caches/params are built by the
    step builders in repro.launch; this covers the *per-step data* inputs."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = cfg.activation_dtype
    d = cfg.d_model

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.mode == "train":
        if cfg.frontend == "vision_stub":
            p = min(cfg.num_patches, S // 2)
            return {"tokens": sds((B, S - p)), "labels": sds((B, S - p)),
                    "extra_embeds": sds((B, p, d), f)}
        if cfg.frontend == "audio_stub":
            return {"tokens": sds((B, S)), "labels": sds((B, S)),
                    "extra_embeds": sds((B, cfg.encoder_seq, d), f)}
        return {"tokens": sds((B, S)), "labels": sds((B, S))}

    if shape.mode == "prefill":
        if cfg.frontend == "vision_stub":
            p = min(cfg.num_patches, S // 2)
            return {"tokens": sds((B, S - p)),
                    "extra_embeds": sds((B, p, d), f)}
        if cfg.frontend == "audio_stub":
            return {"tokens": sds((B, S)),
                    "extra_embeds": sds((B, cfg.encoder_seq, d), f)}
        return {"tokens": sds((B, S))}

    # decode: one token against a cache of length S
    return {"tokens": sds((B,)), "kv_len": sds((B,))}


def concrete_inputs(cfg: ModelConfig, shape: ShapeConfig, key=None):
    """Small concrete analogue of input_specs for smoke tests."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            if name == "kv_len":
                out[name] = jnp.full(s.shape, max(1, shape.seq_len - 1),
                                     s.dtype)
            else:
                out[name] = jax.random.randint(key, s.shape, 0,
                                               cfg.vocab_size).astype(s.dtype)
        else:
            out[name] = jax.random.normal(key, s.shape, s.dtype) * 0.02
    return out
