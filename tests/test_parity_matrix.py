"""Cross-mode parity test matrix — the canonical tier-1 serving gate.

One parametrized greedy token-parity suite over

    {forkkv, prefix, full_reuse} x {paged, gather} x {dense, GQA, MQA, SWA}
                                 x {mixed, phase-separated}
                                 x {speculative, plain}

through the public ``ForkServer`` API, replacing the ad-hoc per-PR parity
tests (PR 2's forkkv-vs-prefix check, PR 3's paged-vs-gather check): for
every serve mode and attention flavour, the page-native kernels
(decode AND chunked prefill, DESIGN.md §12/§13) must produce bit-identical
greedy tokens to the legacy gather-to-contiguous oracle path — and the
paged path must issue ZERO gather-to-contiguous copies, asserted via the
``fallback_gather_calls`` metric (the regression guard that SWA models can
never silently fall back again).

The ``mixed`` axis (DESIGN.md §14) is this matrix's iteration-level
continuous-batching gate: ``mixed_batching=True`` (the default — one
token-budget plan per step, decode + prefill rows through the unified
kernel grid) must produce the same greedy tokens as the legacy
phase-separated step loop, and the workload staggers its forks so at
least one iteration REALLY mixes decode and prefill rows
(``mixed_steps >= 1`` — without the stagger the parity would be vacuous).

The ``speculative`` axis (DESIGN.md §16) gates draft-free speculative
decoding the same way: speculation ON must be token-identical to OFF
while really proposing AND accepting drafts, and rejected-draft rollback
must leak zero KV pages (after eviction both pools return to baseline).

Backends: the suite runs under whichever kernel backend
``FORKKV_KERNEL_BACKEND`` / ``REPRO_ATTN_BACKEND`` selects (CI runs it
once with ``ref`` and once with ``pallas-interpret``).
"""
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams

import jax

PAGE = 16

# attention flavours: MHA, grouped-query, multi-query, sliding-window.
# The SWA window (24) deliberately straddles a page boundary and is
# shorter than the 40-token shared context, so out-of-window masking and
# the window-clamped page walk are both exercised.
ARCHS = {
    "dense": dict(num_heads=4, num_kv_heads=4),
    "gqa": dict(num_heads=8, num_kv_heads=2),
    "mqa": dict(num_heads=4, num_kv_heads=1),
    "swa": dict(num_heads=4, num_kv_heads=2, sliding_window=24),
}
MODES = ("forkkv", "prefix", "full_reuse")


@pytest.fixture(scope="module")
def models():
    """Lazily-built (cfg, params, lora) per attention flavour."""
    cache = {}

    def get(arch: str):
        if arch not in cache:
            cfg = tiny_serving_model(rank=8, num_layers=2, d_model=128,
                                     vocab_size=512, **ARCHS[arch])
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1),
                                        n_adapters=4)
            cache[arch] = (cfg, params, lora)
        return cache[arch]

    return get


def run_workload(model, mode: str, paged: bool, mixed: bool = True,
                 speculate: bool = False):
    """The shared workload: one pinned session context, two CoW forks
    under different adapters plus a third replaying the first, greedy
    decode.  Deterministic in everything but the
    (mode, paged, mixed, speculate, arch) cell under test.

    The forks are STAGGERED — the second is submitted only after a few
    polls, while the first is mid-decode — so the iteration scheduler
    must overlap one request's decode rows with the other's prefill
    chunks in the same plan (the mixed-grid case the §14 refactor
    exists for; legacy phase separation serves the exact same schedule
    through its two per-step calls).

    The instructions are PREFIXES of the context (agent traces re-quote
    their context), so the prompt-lookup proposer always has material,
    and the third fork repeats fork 1 verbatim so the ngram cache —
    warmed when fork 1 finished — replays its output (§16: speculation
    parity would be vacuous if nothing were ever accepted).  After the
    session closes the caches are fully evicted and both pools' free
    counts are recorded, so the speculation gate can assert zero leaked
    pages from rejected-draft rollback."""
    cfg, params, lora = model
    sc = ServeConfig(page_size=PAGE, max_pages=96, max_batch=4,
                     max_prefill_tokens=48, max_pages_per_req=8,
                     mode=mode, use_paged_kernel=paged,
                     mixed_batching=mixed, speculate=speculate,
                     spec_k=3, spec_proposer="ngram_cache")
    server = ForkServer(cfg, params, lora, sc)
    rng = np.random.default_rng(7)
    ctx = list(rng.integers(0, cfg.vocab_size, 40))
    with server.session(ctx, adapter_id=0) as sess:
        handles = [sess.fork(1, ctx[:5], SamplingParams(max_new_tokens=5))]
        for _ in range(3):       # first fork reaches decode...
            server.poll()
        handles.append(
            sess.fork(2, ctx[:6], SamplingParams(max_new_tokens=5)))
        outs = [o.tokens for o in server.wait(handles)]
        # replay fork 1: the ngram cache was warmed by its finish, so the
        # speculate cell gets high-acceptance verify rows here
        replay = [sess.fork(1, ctx[:5], SamplingParams(max_new_tokens=5))]
        outs += [o.tokens for o in server.wait(replay)]
    m = server.metrics()
    # drain every cache and record the pools' final free counts (leak gate)
    eng = server.engine
    eng._evict(eng.base_pool, eng.base_pool.num_pages)
    if mode == "forkkv":
        eng._evict(eng.res_pool, eng.res_pool.num_pages)
    m["drained_free_base"] = eng.base_pool.free_pages
    m["total_base"] = eng.base_pool.num_pages
    m["drained_free_res"] = eng.res_pool.free_pages
    m["total_res"] = eng.res_pool.num_pages
    return outs, m


# each (arch, mode, paged, mixed) cell is deterministic, and several test
# parametrizations share cells — memoize so the matrix costs one run per
# distinct cell instead of re-serving the workload per assertion
_CELLS = {}


def cell(models, arch: str, mode: str, paged: bool, mixed: bool,
         speculate: bool = False):
    key = (arch, mode, paged, mixed, speculate)
    if key not in _CELLS:
        _CELLS[key] = run_workload(models(arch), mode, paged, mixed,
                                   speculate)
    return _CELLS[key]


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mode", MODES)
def test_paged_vs_gather_token_parity(models, mode, arch):
    """Greedy outputs must be token-identical between the page-native
    kernels and the legacy gather path — same workload, same session/fork
    calls, only ``ServeConfig.use_paged_kernel`` flipped — and the paged
    run must never gather: ``fallback_gather_calls == 0``.  Runs under
    the mixed-batching default, so the unified grid is what's gated."""
    paged_out, paged_m = cell(models, arch, mode, paged=True, mixed=True)
    gather_out, gather_m = cell(models, arch, mode, paged=False,
                                mixed=True)
    assert all(len(t) == 5 for t in paged_out)
    assert paged_out == gather_out

    # the paged path is fully page-native — SWA included, no silent
    # fallback (the PR-5 regression guard)
    assert paged_m["use_paged_kernel"] is True
    assert paged_m["fallback_gather_calls"] == 0
    # and the gather path is VISIBLE from day one: every prefill/decode
    # executor call shows up in the metric
    assert gather_m["use_paged_kernel"] is False
    assert gather_m["fallback_gather_calls"] > 0


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mode", MODES)
def test_mixed_vs_phase_separated_token_parity(models, mode, arch):
    """The §14 gate: iteration-level continuous batching (the default)
    must generate the same greedy tokens as the legacy phase-separated
    step loop — same staggered workload, only
    ``ServeConfig.mixed_batching`` flipped — while REALLY mixing decode
    and prefill rows in at least one iteration, still without a single
    gather fallback."""
    mixed_out, mixed_m = cell(models, arch, mode, paged=True, mixed=True)
    legacy_out, legacy_m = cell(models, arch, mode, paged=True,
                                mixed=False)
    assert all(len(t) == 5 for t in mixed_out)
    assert mixed_out == legacy_out

    assert mixed_m["mixed_batching"] is True
    # the stagger guarantees overlap: without this the parity above would
    # only ever exercise pure-prefill / pure-decode plans
    assert mixed_m["mixed_steps"] >= 1
    assert mixed_m["fallback_gather_calls"] == 0
    assert legacy_m["mixed_batching"] is False
    assert legacy_m["mixed_steps"] == 0
    assert legacy_m["fallback_gather_calls"] == 0


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mode", MODES)
def test_speculative_vs_plain_token_parity(models, mode, arch):
    """The §16 gate: draft-free speculative decoding must generate the
    same greedy tokens as plain decode — same staggered workload, only
    ``ServeConfig.speculate`` flipped — while REALLY speculating
    (proposals made AND accepted), without a single gather fallback,
    and without leaking one KV page: after the session closes and the
    caches are fully evicted, both pools return to baseline (only the
    executor's dump page remains allocated), proving rejected-draft
    rollback is pure refcounting."""
    spec_out, spec_m = cell(models, arch, mode, paged=True, mixed=True,
                            speculate=True)
    plain_out, plain_m = cell(models, arch, mode, paged=True, mixed=True,
                              speculate=False)
    assert all(len(t) == 5 for t in spec_out)
    assert spec_out == plain_out

    # the speculation is real, not vacuous: drafts were proposed and the
    # fork-1 replay (ngram-cache warmed) got some accepted
    assert spec_m["speculate"] is True
    assert spec_m["spec_steps"] >= 1
    assert spec_m["spec_proposed_tokens"] > 0
    assert spec_m["spec_accepted_tokens"] > 0
    assert plain_m["spec_steps"] == 0
    # still fully page-native
    assert spec_m["fallback_gather_calls"] == 0
    # zero-leak rollback: everything evictable was freed; only the dump
    # page stays (allocated once at engine construction, held forever)
    assert spec_m["drained_free_base"] == spec_m["total_base"] - 1
    assert spec_m["drained_free_res"] == spec_m["total_res"] - 1
