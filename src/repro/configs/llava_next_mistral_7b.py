"""llava-next-mistral-7b [vlm]: mistral-7B language backbone, anyres vision
tiling (frontend stubbed to patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    frontend="vision_stub", num_patches=2880,   # anyres: base + 4 tiles x 576
    lora=LoRAConfig(rank=16), scan_layers=True,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llava-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512, num_patches=8,
        dtype="float32", remat=False)
