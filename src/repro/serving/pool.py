"""Refcounted paged-block allocator (bookkeeping side).

The actual cache tensors live in the executor as pooled jnp arrays of shape
(num_pages, page_size, ...); this class tracks allocation, sharing
(refcounts — the CoW substrate) and free lists.  Two instances exist in
ForkKV mode: one for the shared bCache, one for the per-agent rCache
(decoupled lifecycles, paper §5.2).

With tiered KV offload enabled (``ServeConfig.host_tier_bytes > 0``) the
engine wraps each device pool in a :class:`repro.serving.tiers.
TieredPagePool`, which re-exports this API unchanged and adds HBM→host
demotion/promotion (DESIGN.md §10); callers distinguish the two via the
``is_tiered`` class attribute.
"""
from __future__ import annotations

from typing import List, Optional, Sequence


class PagePool:
    is_tiered = False      # TieredPagePool overrides (DESIGN.md §10)

    def __init__(self, num_pages: int, page_size: int, name: str = "pool"):
        self.num_pages = num_pages
        self.page_size = page_size
        self.name = name
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref = [0] * num_pages
        # high-water / accounting
        self.alloc_count = 0
        self.oom_count = 0

    # -------------------------------------------------------------- alloc
    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            self.oom_count += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self._ref[p] == 0
            self._ref[p] = 1
        self.alloc_count += n
        return pages

    # ------------------------------------------------------------ sharing
    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            assert self._ref[p] > 0, f"{self.name}: incref on free page {p}"
            self._ref[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Returns pages that became free."""
        freed = []
        for p in pages:
            assert self._ref[p] > 0, f"{self.name}: decref on free page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def refcount(self, page: int) -> int:
        return self._ref[page]

    # ---------------------------------------------------------- metrics
    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_pages / max(1, self.num_pages)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)
