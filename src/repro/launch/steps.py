"""Step builders for the multi-pod dry-run and real launchers.

For each (architecture × input shape) this constructs:
  * abstract state (params / optimizer / LoRA stacks / KV caches) via
    ``jax.eval_shape`` — ShapeDtypeStructs only, no allocation,
  * NamedShardings from the model's logical axes + the rule table,
  * the jit'd step with in/out shardings ready to ``.lower().compile()``.

train_4k   -> train_step   (loss + grads + optimizer update)
prefill_32k-> prefill_step (populate disaggregated cache, argmax logits)
decode_*   -> serve_step   (ONE token against a seq_len cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.core.config import ModelConfig, ShapeConfig
from repro.launch import sharding as shd
from repro.models.registry import get_model
from repro.training import optimizer as opt_lib
from repro.training import train_loop

N_ADAPTERS = 8          # concurrent agents in the serving dry-run
# gradient-accumulation microbatches per train step: 16 keeps the local
# microbatch at 1 sequence per chip (256 global / 16 data shards / 16),
# bounding activation temps; see EXPERIMENTS.md §Perf for the trade-off
DEFAULT_ACCUM = 16
ACCUM_STEPS = {}


def accum_for(cfg, strategy: str = "baseline") -> int:
    # optimized strategy, small models: activations fit without microbatching
    # and every accumulation pass re-streams the (replicated) weights
    if strategy == "optimized" and cfg.num_params < 1e9:
        return 1
    return ACCUM_STEPS.get(cfg.name, DEFAULT_ACCUM)

_KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)


class BuiltStep(NamedTuple):
    step_fn: Any            # jit'd function (with shardings)
    abstract_args: tuple    # SDS pytrees to .lower() with
    description: str


def _opt_axes(cfg: ModelConfig, param_axes):
    inner = opt_lib.opt_state_logical_axes(cfg.optimizer, param_axes)
    return opt_lib.OptState(step=None, inner=inner)


def build_train_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                     strategy: str = "baseline") -> BuiltStep:
    api = get_model(cfg)
    accum = accum_for(cfg, strategy)
    init_opt, step = train_loop.make_train_step(cfg, accum_steps=accum)

    params_sds = jax.eval_shape(api.init_params, _KEY)
    opt_sds = jax.eval_shape(init_opt, params_sds)
    batch_sds = cfg_lib.input_specs(cfg, shape)

    p_axes = api.logical_axes()
    params_sh = shd.tree_shardings(mesh, params_sds, p_axes, cfg, "train",
                                   strategy)
    opt_sh = shd.tree_shardings(mesh, opt_sds, _opt_axes(cfg, p_axes), cfg,
                                "train", strategy)
    batch_sh = shd.input_shardings(mesh, batch_sds, cfg, "train", strategy)

    jit_step = jax.jit(step,
                       in_shardings=(params_sh, opt_sh, batch_sh),
                       out_shardings=(params_sh, opt_sh, None),
                       donate_argnums=(0, 1))
    return BuiltStep(jit_step, (params_sds, opt_sds, batch_sds),
                     f"train_step accum={accum} opt={cfg.optimizer}")


def _lora_state(cfg: ModelConfig, api, mesh, purpose: str,
                strategy: str = "baseline"):
    if api.init_lora_stacks is None:
        return None, None
    lora_sds = jax.eval_shape(
        functools.partial(api.init_lora_stacks, n=N_ADAPTERS), _KEY)
    lora_sh = shd.tree_shardings(mesh, lora_sds, api.lora_logical_axes(),
                                 cfg, purpose, strategy)
    return lora_sds, lora_sh


def build_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                       disagg: Optional[bool] = None,
                       strategy: str = "baseline") -> BuiltStep:
    api = get_model(cfg)
    disagg = api.supports_forkkv if disagg is None else disagg
    B, S = shape.global_batch, shape.seq_len

    params_sds = jax.eval_shape(api.init_params, _KEY)
    params_sh = shd.tree_shardings(mesh, params_sds, api.logical_axes(), cfg,
                                   "prefill", strategy)
    lora_sds, lora_sh = _lora_state(cfg, api, mesh, "prefill", strategy)
    batch_sds = cfg_lib.input_specs(cfg, shape)
    batch_sh = shd.input_shardings(mesh, batch_sds, cfg, "prefill", strategy)

    cache_sds = jax.eval_shape(
        functools.partial(api.init_cache, B, S, disagg=disagg))
    cache_sh = shd.tree_shardings(mesh, cache_sds,
                                  api.cache_logical_axes(disagg=disagg), cfg,
                                  "prefill", strategy)
    ids_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    ids_sh = shd.vector_sharding(mesh, B, cfg, "prefill", strategy)

    def prefill_step(params, lora, batch, adapter_ids):
        cache = api.init_cache(B, S, disagg=disagg)
        kwargs = {}
        if "extra_embeds" in batch:
            kwargs["extra_embeds"] = batch["extra_embeds"]
        if lora is not None:
            kwargs.update(lora=lora, adapter_ids=adapter_ids, disagg=disagg)
        logits, cache = api.prefill(params, batch["tokens"], cache, **kwargs)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    jit_step = jax.jit(prefill_step,
                       in_shardings=(params_sh, lora_sh, batch_sh, ids_sh),
                       out_shardings=(ids_sh, cache_sh))
    return BuiltStep(jit_step, (params_sds, lora_sds, batch_sds, ids_sds),
                     f"prefill_step disagg={disagg}")


def build_serve_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                     disagg: Optional[bool] = None,
                     strategy: str = "baseline") -> BuiltStep:
    """Decode: ONE new token with a KV cache of shape.seq_len."""
    api = get_model(cfg)
    disagg = api.supports_forkkv if disagg is None else disagg
    B, S = shape.global_batch, shape.seq_len

    params_sds = jax.eval_shape(api.init_params, _KEY)
    params_sh = shd.tree_shardings(mesh, params_sds, api.logical_axes(), cfg,
                                   "decode", strategy)
    lora_sds, lora_sh = _lora_state(cfg, api, mesh, "decode", strategy)

    cache_sds = jax.eval_shape(
        functools.partial(api.init_cache, B, S, disagg=disagg))
    cache_sh = shd.tree_shardings(mesh, cache_sds,
                                  api.cache_logical_axes(disagg=disagg), cfg,
                                  "decode", strategy)
    tok_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    len_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    vec_sh = shd.vector_sharding(mesh, B, cfg, "decode", strategy)

    def serve_step(params, lora, cache, tokens, kv_len, adapter_ids):
        kwargs = {}
        if lora is not None:
            kwargs.update(lora=lora, adapter_ids=adapter_ids, disagg=disagg)
        logits, cache = api.decode_step(params, tokens, cache, kv_len,
                                        **kwargs)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    jit_step = jax.jit(serve_step,
                       in_shardings=(params_sh, lora_sh, cache_sh, vec_sh,
                                     vec_sh, vec_sh),
                       out_shardings=(vec_sh, cache_sh),
                       donate_argnums=(2,))
    return BuiltStep(
        jit_step,
        (params_sds, lora_sds, cache_sds, tok_sds, len_sds, tok_sds),
        f"serve_step disagg={disagg} cache_len={S}")


def build_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
               **kw) -> BuiltStep:
    if shape.mode == "train":
        kw.pop("disagg", None)
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.mode == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_serve_step(cfg, mesh, shape, **kw)
