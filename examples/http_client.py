"""Agent-tree workflow over HTTP (DESIGN.md §15).

Spins up the HTTP frontend in-process (the same thing
``python -m repro.launch.serve --http`` serves), then drives a ReAct-style
agent tree through it with the stdlib :class:`ForkClient`:

  1. ``POST /v1/sessions`` prefills + pins a shared task context once;
  2. ``POST /v1/sessions/{id}/fork`` branches N agents off it — each
     fork inherits the pinned KV pages copy-on-write, so the shared
     context is never prefilled again;
  3. one agent streams its tokens over SSE while the rest run batch;
  4. ``GET /v1/metrics`` shows the cache hits and tenant accounting.

Run:  PYTHONPATH=src python examples/http_client.py [--port 8080]
With ``--connect``, skips the in-process server and talks to an
already-running ``serve.py --http`` instance instead.
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.serving.frontend import ForkClient  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--host", default="127.0.0.1")
ap.add_argument("--port", type=int, default=0,
                help="with --connect: port of a running server; "
                     "otherwise the in-process server's port (0 = any)")
ap.add_argument("--connect", action="store_true",
                help="talk to an already-running serve.py --http")
ap.add_argument("--agents", type=int, default=3)
args = ap.parse_args()

# retry on transient 429/503 (overload shed, draining replica) with
# jittered exponential backoff honoring the server's Retry-After hint
retry_kw = dict(max_retries=3, backoff_s=0.25, backoff_cap_s=4.0)

if args.connect:
    client = ForkClient(host=args.host, port=args.port, **retry_kw)
    fe = None
else:
    from repro.launch.serve import build_server
    from repro.serving.frontend import HttpFrontend
    server, _ = build_server("forkkv", max_pages=256,
                             admission="fairshare")
    fe = HttpFrontend(server, host=args.host,
                      port=args.port).start_background()
    client = ForkClient(host=args.host, port=fe.port, **retry_kw)
    print(f"in-process server on http://{args.host}:{fe.port}")

rng = np.random.default_rng(0)
context = [int(t) for t in rng.integers(0, 1000, 192)]

sid = client.create_session(context, adapter_id=0, tenant="demo")
print(f"session {sid}: pinned {len(context)}-token shared context")

# one agent streams over SSE...
print("agent 0 (streaming): ", end="", flush=True)
instruction = [int(t) for t in rng.integers(0, 1000, 8)]
for ev in client.stream_fork(sid, instruction, adapter_id=1,
                             max_new_tokens=12):
    if ev.get("finished"):
        print(f" [{ev['finish_reason']}]")
    else:
        print(ev["token"], end=" ", flush=True)

# ...the rest fork in batch, each with its own LoRA adapter
for i in range(1, args.agents):
    instruction = [int(t) for t in rng.integers(0, 1000, 8)]
    doc = client.fork(sid, instruction, adapter_id=1 + i,
                      max_new_tokens=12)
    print(f"agent {i} (adapter {1 + i}): {doc['tokens']} "
          f"[{doc['finish_reason']}] retries={doc['client_retries']}")

m = client.metrics()
print(f"\nhit_rate={m['hit_rate']:.2f} hit_kinds={m.get('hit_kinds')} "
      f"fallback_gather_calls={m['fallback_gather_calls']}")
print(f"tenants={m['tenants']}")
client.close_session(sid)
if fe is not None:
    fe.shutdown()
