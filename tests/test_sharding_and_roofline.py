"""Sharding rules (divisibility fallback) + roofline machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as cfg_lib
from repro.core.config import shape_by_name
from repro.launch import analytic, roofline, sharding as shd

MESH_SIZES = {"data": 16, "model": 16}
MESH_SIZES_MP = {"pod": 2, "data": 16, "model": 16}


def spec(axes, shape, sizes=None, cfg=None, purpose="train"):
    rules = shd.rules_for(cfg, purpose)
    return shd.spec_for_axes(axes, shape, sizes or MESH_SIZES, rules)


def test_basic_tp_fsdp_spec():
    assert spec(("embed", "q_out"), (4096, 4096)) == P("data", "model")
    assert spec(("layers", "embed", "ff"), (32, 4096, 14336)) == \
        P(None, "data", "model")


def test_divisibility_fallback_llama4_heads():
    # llama4: 40 q heads not divisible by 16 -> heads unsharded
    assert spec(("heads",), (40,)) == P()
    # kv_heads=8 indivisible -> model lands on head_dim instead
    s = spec(("layers", "batch", None, "kv_heads", "kv_head_dim"),
             (48, 128, 32768, 8, 128))
    assert s == P(None, "data", None, None, "model")


def test_batch_joint_pod_data():
    s = spec(("batch",), (256,), MESH_SIZES_MP)
    assert s == P(("pod", "data"))
    # batch=1 (long_500k): unshardable -> replicated
    assert spec(("batch",), (1,), MESH_SIZES_MP) == P()


def test_decode_big_model_2d_tp():
    cfg = cfg_lib.get_config("llama3-405b")
    s = spec(("embed", "q_out"), (16384, 16384), MESH_SIZES_MP, cfg,
             "decode")
    assert s == P(None, ("pod", "data", "model"))


def test_no_mesh_axis_used_twice():
    s = spec(("q_out", "kv_out", "ff"), (4096, 1024, 14336))
    used = [e for e in (s if isinstance(s, tuple) else ()) if e]
    flat = []
    for e in used:
        flat.extend(e if isinstance(e, tuple) else [e])
    assert len(flat) == len(set(flat))


# ------------------------------------------------------------- roofline
def test_collective_bytes_parser():
    hlo = """
  %ar = bf16[128,4096]{1,0} all-reduce(bf16[128,4096]{1,0} %add), replica_groups={}
  %ag = f32[64,1024]{1,0} all-gather(f32[32,1024]{1,0} %p), dimensions={0}
  %x = f32[8,8]{1,0} add(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 4096 * 2
    assert out["all-gather"] == 32 * 1024 * 4
    assert out["count"] == 2
    assert out["total"] == out["all-reduce"] + out["all-gather"]


def test_roofline_terms_dominant():
    t = roofline.roofline_terms(197e12, 819e9 * 2, 0, chips=1)
    assert t["dominant"] == "memory_s"
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["memory_s"] - 2.0) < 1e-6


def test_cost_analysis_counts_scan_once():
    """Documents WHY the analytic model exists: XLA cost_analysis counts
    while-loop bodies once, so scanned layers are invisible to it."""
    from repro.core.config import LoRAConfig, ModelConfig
    from repro.models import transformer as tfm

    def flops(L, scan):
        cfg = ModelConfig(name="t", family="dense", num_layers=L,
                          d_model=128, num_heads=4, num_kv_heads=2,
                          d_ff=256, vocab_size=512, dtype="float32",
                          lora=LoRAConfig(rank=8), scan_layers=scan,
                          remat=False)
        params = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        toks = jax.ShapeDtypeStruct((2, 64), jnp.int32)
        c = jax.jit(lambda p, t: tfm.forward(p, t, cfg)).lower(
            params, toks).compile()
        return roofline.hlo_cost_analysis(c)["flops"]

    assert flops(2, scan=True) == flops(6, scan=True)          # loop-once
    assert flops(6, scan=False) > 2 * flops(2, scan=False)     # unrolled ok


def test_analytic_matches_hlo_on_unrolled_probe():
    """Validate the analytic FLOPs model against XLA cost_analysis on a
    small UNROLLED dense model (agreement within 25%)."""
    import dataclasses

    from repro.core.config import LoRAConfig, ModelConfig, ShapeConfig
    from repro.models import transformer as tfm

    cfg = ModelConfig(name="probe", family="dense", num_layers=3,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=512, dtype="float32",
                      lora=LoRAConfig(rank=8), scan_layers=False,
                      remat=False)
    B, S = 2, 64
    params = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    c = jax.jit(lambda p, t: tfm.forward(p, t, cfg)).lower(
        params, toks).compile()
    hlo_flops = roofline.hlo_cost_analysis(c)["flops"]
    ana = analytic.forward_flops(cfg, B, S)
    assert 0.75 < ana / hlo_flops < 1.33, (ana, hlo_flops)


def test_analytic_costs_all_pairs_positive():
    for arch, shape_name in cfg_lib.applicable_pairs():
        cfg = cfg_lib.get_config(arch)
        shape = shape_by_name(shape_name)

        class FakeMesh:
            axis_names = ("data", "model")

            class devices:
                shape = (16, 16)
                size = 256

        out = analytic.analytic_costs(cfg, shape, FakeMesh)
        assert out["flops_dev"] > 0, (arch, shape_name)
        assert out["bytes_dev"] > 0, (arch, shape_name)


def test_memory_ratio_eq3():
    """Paper Eq. 3: M_R = 1/N + r/n."""
    from repro.core.disagg import memory_ratio
    assert abs(memory_ratio(16, 16, 1024) - (1 / 16 + 16 / 1024)) < 1e-12
    # as N grows the ratio approaches r/n
    assert abs(memory_ratio(10_000, 16, 1024) - 16 / 1024) < 2e-4
