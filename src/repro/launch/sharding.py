"""Logical-to-physical sharding rules (MaxText-style) with divisibility
fallback.

Every model exposes pytrees of *logical axis names* per parameter/cache dim
(``repro.models.*.logical_axes``).  ``tree_shardings`` turns those into
NamedShardings for a concrete mesh: each dim takes the first rule candidate
whose mesh axes (a) exist in the mesh, (b) are not already used by another
dim of the same tensor, and (c) divide the dim size.  Non-divisible dims
fall through — e.g. llama4's 40 q-heads are not divisible by model=16, so
the model axis lands on head_dim (or the ff dim) instead of failing.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Rule table: logical axis -> priority list of candidates; each candidate is
# a tuple of mesh axes that shard the dim jointly.
DEFAULT_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",)),
    "vocab": (("model",),),
    "q_out": (("model",),),
    "kv_out": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "kv_head_dim": (("model",),),        # fallback when kv_heads indivisible
    "ff": (("model",),),
    "expert_w": ((),),                   # tensor-parallel MoE: shard ff, not E
    "embed": (("data",),),               # FSDP
    "embed_pod": (("pod", "data"), ("data",)),   # 405B-class FSDP
    "rank": ((),),
    "inner": (("model",),),
    "inner_in": ((),),
    "inner_head": (("model",),),
    "state": ((),),
    "layers": ((),),
    None: ((),),
}


BIG_MODEL = 5e10      # params above which weights cannot replicate over data
SMALL_MODEL = 1e9     # params below which weights replicate on every chip

# Sharding strategy: "baseline" is the paper-faithful first implementation
# (FSDP over data + tensor parallel over model for train/prefill);
# "optimized" applies the §Perf hillclimbing results:
#   * small-model train: pure data parallelism over ALL mesh axes (the
#     16-way TP all-reduces dominated tiny models' rooflines),
#   * sub-50B prefill: FSDP over the model axis instead of TP (one weight
#     all-gather per layer amortizes over 32k tokens; activation
#     all-reduces do not).
STRATEGIES = ("baseline", "optimized")


def rules_for(cfg, purpose: str = "train",
              strategy: str = "baseline") -> Dict[
        Optional[str], Tuple[Tuple[str, ...], ...]]:
    """Sharding rule table per (model size, step purpose).

    train/prefill: FSDP — weights sharded on the embed dim over data
      (+pod for 405B-class) and on heads/ff over model; batch over pod+data.
      The per-layer weight all-gather amortizes over S tokens.
    decode small:  weights replicated over data (they fit), model-parallel
      over model; batch + KV cache over data.  No per-step param collectives.
    decode big:    2D tensor parallel — weight output dims sharded over
      (pod×data×model) jointly so 405B-class weights fit; batch replicated
      for weights, KV cache still batch-sharded over data (+head_dim over
      model).  Per-step collectives are small decode activations.
    """
    rules = dict(DEFAULT_RULES)
    big = cfg is not None and cfg.num_params > BIG_MODEL
    small = cfg is not None and cfg.num_params < SMALL_MODEL
    if strategy == "optimized" and cfg is not None:
        tp_axes = ("ff", "q_out", "kv_out", "inner", "inner_head", "vocab",
                   "kv_heads", "kv_head_dim")
        if purpose == "train" and small:
            for ax in tp_axes:
                rules[ax] = ((),)
            rules["embed"] = ((),)
            rules["batch"] = (("pod", "data", "model"), ("pod", "data"),
                              ("data", "model"), ("data",))
            return rules
        if purpose == "prefill" and not big:
            for ax in ("ff", "q_out", "kv_out", "inner", "inner_head"):
                rules[ax] = ((),)
            rules["vocab"] = (("model",),)     # keep logits sharded
            rules["embed"] = (("model",), ("data",))
            return rules
    if purpose == "decode":
        rules["embed"] = ((),)                      # no per-step FSDP gather
        if big:
            two_d = (("pod", "data", "model"), ("data", "model"), ("model",))
            for ax in ("q_out", "kv_out", "ff", "vocab", "inner"):
                rules[ax] = two_d
    else:
        if cfg is not None and cfg.num_params > 2e11:
            # 405B-class: fp32 optimizer state needs pod+data FSDP
            rules["embed"] = (("pod", "data"), ("data",))
    return rules


def spec_for_axes(axes: Optional[Sequence[Optional[str]]],
                  shape: Tuple[int, ...], mesh_sizes: Dict[str, int],
                  rules) -> P:
    if axes is None:
        return P()
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    entries = []
    for name, dim in zip(axes, shape):
        chosen = None
        for cand in rules.get(name, ((),)):
            if not cand:
                break
            if any(a not in mesh_sizes or a in used for a in cand):
                continue
            size = math.prod(mesh_sizes[a] for a in cand)
            if size > 1 and dim % size == 0:
                chosen = cand
                used.update(cand)
                break
        if chosen is None:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    # strip trailing Nones for tidiness
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(mesh: Mesh, shapes_tree, axes_tree, cfg=None,
                   purpose: str = "train", strategy: str = "baseline"):
    """NamedSharding pytree for (shapes_tree, axes_tree).

    shapes_tree: pytree of arrays or ShapeDtypeStructs.
    axes_tree: matching pytree whose leaves are tuples of logical axis
    names (or None).  Tuples are leaves here, so we flatten shapes_tree and
    pair leaves positionally.
    """
    rules = rules_for(cfg, purpose, strategy)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    shape_leaves, treedef = jax.tree_util.tree_flatten(shapes_tree)
    axes_leaves = treedef.flatten_up_to(axes_tree)
    out = []
    for leaf, axes in zip(shape_leaves, axes_leaves):
        spec = spec_for_axes(axes, tuple(leaf.shape), sizes, rules)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def vector_sharding(mesh: Mesh, batch: int, cfg=None,
                    purpose: str = "train",
                    strategy: str = "baseline") -> NamedSharding:
    """Sharding for (batch,)-shaped step vectors (tokens, kv_len, ids),
    respecting divisibility of the actual batch size."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = rules_for(cfg, purpose, strategy)
    spec = spec_for_axes(("batch",), (batch,), sizes, rules)
    return NamedSharding(mesh, spec)


def batch_sharding_for(mesh: Mesh, batch: int) -> Tuple[Any, ...]:
    """The mesh axes actually usable for a given global batch size."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for cand in (("pod", "data"), ("data",)):
        if all(a in sizes for a in cand):
            size = math.prod(sizes[a] for a in cand)
            if batch % size == 0 and size > 1:
                return cand
    return ()


def input_shardings(mesh: Mesh, specs: Dict[str, jax.ShapeDtypeStruct],
                    cfg=None, purpose: str = "train",
                    strategy: str = "baseline"):
    """Shardings for the per-step data inputs from configs.input_specs."""
    out = {}
    rules = rules_for(cfg, purpose, strategy)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name, s in specs.items():
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        out[name] = NamedSharding(
            mesh, spec_for_axes(axes, tuple(s.shape), sizes, rules))
    return out
