"""Iteration-level token-budget scheduler (DESIGN.md §14).

The phase-separated step loop (one batched prefill call, then one decode
call, per step) lets a long prompt head-of-line-block every in-flight
token stream: decode rows wait for the whole prefill call's wall clock
each step.  This module replaces that with Sarathi-style iteration-level
scheduling: :class:`IterationScheduler` plans ONE :class:`BatchPlan` per
engine step, packing

  1. every runnable decode row first (q=1 each — decode is never starved
     by prefill; the rows are cheap and they are the latency-critical
     ones), then
  2. chunked-prefill rows, FCFS, each taking ``min(remaining prompt,
     remaining budget, max_prefill_tokens)`` tokens of the iteration's
     ``token budget``,

and the executor runs the whole plan as a single mixed call through the
unified kernel grid (each row carries its q-length as a scalar-prefetch
input — see ``kernels/paged_residual_attention.py``).  Broadcast-fork
groups still take precedence: the engine runs the broadcast pass first
and the scheduler simply sees the group's advanced ``prefill_pos``.

The scheduler also owns the per-request latency timestamps: a request's
``first_scheduled_at`` is stamped the first time any plan includes it,
feeding the queueing-delay component of TTFT (``Engine.metrics()``
aggregates p50/p99 over finished requests).

Pure planning, no device work: the module never touches pools or jax, so
its invariants (budget never exceeded, decode priority, chunk caps) are
unit-testable without a model — see ``tests/test_scheduler.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.core.config import ServeConfig


@dataclasses.dataclass(frozen=True)
class RowPlan:
    """One row of an iteration: a request plus the q-slice it computes.

    ``kind == "decode"`` rows consume the request's last sampled token
    (q_len == 1, start == kv_len); ``kind == "prefill"`` rows compute the
    prompt slice ``[start, start + q_len)``; ``kind == "verify"`` rows
    are speculative decode rows (DESIGN.md §16) carrying the last sampled
    token plus ``draft`` guessed continuations (q_len == 1 + len(draft),
    start == kv_len) — the engine commits the accepted prefix and drops
    the rest via CoW.  All three are the same operation to the unified
    grid — write q_len tokens' KV at ``start`` and attend causally over
    ``start + q_len`` tokens — which is exactly why one kernel launch can
    serve the whole plan.
    """

    req: Any                    # serving.engine.Request (untyped: no cycle)
    q_len: int
    start: int
    kind: str                   # "decode" | "prefill" | "verify"
    draft: Tuple[int, ...] = ()  # speculated tokens (verify rows only)

    @property
    def end(self) -> int:
        return self.start + self.q_len


@dataclasses.dataclass
class BatchPlan:
    """The rows one engine iteration executes as a single mixed call."""

    rows: List[RowPlan]
    budget: int                 # the token budget this plan was packed under

    @property
    def decode_rows(self) -> List[RowPlan]:
        return [r for r in self.rows if r.kind == "decode"]

    @property
    def verify_rows(self) -> List[RowPlan]:
        return [r for r in self.rows if r.kind == "verify"]

    @property
    def prefill_rows(self) -> List[RowPlan]:
        return [r for r in self.rows if r.kind == "prefill"]

    @property
    def total_tokens(self) -> int:
        return sum(r.q_len for r in self.rows)

    @property
    def q_max(self) -> int:
        return max((r.q_len for r in self.rows), default=0)

    @property
    def is_mixed(self) -> bool:
        """True when decode(/verify) AND prefill rows share this
        iteration — the overlap case the unified grid exists for."""
        return bool(self.decode_rows or self.verify_rows) \
            and bool(self.prefill_rows)


class IterationScheduler:
    """Plans one token-budget iteration per engine step.

    Packing policy (DESIGN.md §14):

    * decode rows first, ALL runnable ones (capped at ``max_batch``) —
      the budget can bound prefill to zero but never drops a decode row,
      so token streams keep flowing no matter how much prompt is queued;
    * then prefill rows FCFS (capped at ``max_prefill_batch``), each
      chunk ``min(prompt remainder, budget remainder,
      max_prefill_tokens)`` — a long prompt streams in across iterations
      instead of monopolizing one.

    Consequently ``plan.total_tokens <= max(budget, len(decode_rows))``,
    the invariant ``tests/test_scheduler.py`` locks down.
    """

    def __init__(self, sc: ServeConfig):
        self.sc = sc
        self.plans = 0              # iterations planned (metrics)

    @property
    def budget(self) -> int:
        if self.sc.iteration_token_budget > 0:
            return self.sc.iteration_token_budget
        return self.sc.max_prefill_tokens + self.sc.max_batch

    def plan(self, running: Sequence[Any],
             now: Optional[float] = None,
             propose: Optional[Callable[[Any], Sequence[int]]] = None
             ) -> BatchPlan:
        """Pack one iteration from the ``running`` list.  Does not mutate
        request state beyond stamping ``first_scheduled_at``.

        ``propose`` (DESIGN.md §16) is the engine's speculation hook: per
        decode-ready request it returns up to k drafted tokens (empty =
        no speculation).  A non-empty draft turns the decode row into a
        ``verify`` row with q_len = 1 + len(draft); drafts are trimmed to
        the remaining token budget but the base decode token is never
        dropped — decode stays unstarvable under budget pressure.
        """
        budget = self.budget
        rows: List[RowPlan] = []
        used = 0
        # 1. decode rows — never starved, regardless of budget pressure
        for r in running:
            if len(rows) >= self.sc.max_batch:
                break
            if r.state == "decode" and \
                    len(r.output) < r.max_new_tokens + 1:
                draft: Tuple[int, ...] = ()
                if propose is not None:
                    draft = tuple(propose(r))[:max(0, budget - used - 1)]
                if draft:
                    rows.append(RowPlan(r, 1 + len(draft), r.kv_len,
                                        "verify", draft))
                else:
                    rows.append(RowPlan(r, 1, r.kv_len, "decode"))
                used += 1 + len(draft)
        # 2. chunked prefill fills what budget remains
        cap = self.sc.max_prefill_batch or len(running)
        n_prefill = 0
        for r in running:
            if r.state != "prefill" or n_prefill >= cap:
                continue
            if used >= budget:
                break
            # ptoks, not prompt: a restored request (DESIGN.md §17)
            # re-prefills its generated suffix like prompt tokens
            remainder = len(r.ptoks) - r.prefill_pos
            chunk = min(remainder, budget - used,
                        self.sc.max_prefill_tokens)
            if chunk <= 0:
                continue
            if chunk < remainder:
                # align mid-prompt chunks to a power of two: the executor
                # pads the batch's q tile to pow2(q_max), so a 48-token
                # chunk would compile and compute a 64-wide call at 33%
                # padding waste — clamping costs one extra iteration per
                # prompt at worst and keeps every mixed launch tight.
                # Final chunks keep their exact remainder (the tail pad
                # is unavoidable and paid once per prompt).
                chunk = 1 << (chunk.bit_length() - 1)
            rows.append(RowPlan(r, chunk, r.prefill_pos, "prefill"))
            used += chunk
            n_prefill += 1
        if rows:
            self.plans += 1
            stamp = now if now is not None else time.time()
            for rp in rows:
                if rp.req.first_scheduled_at == 0.0:
                    rp.req.first_scheduled_at = stamp
        return BatchPlan(rows, budget)
