"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, pattern 1:2.
[arXiv:2402.19427]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, d_ff=12288, vocab_size=256000,
    head_dim=256, block_pattern=("rglru", "rglru", "local"),
    local_window=2048, lru_width=4096, lora=LoRAConfig(rank=16),
    scan_layers=False, citation="arXiv:2402.19427")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-tiny", num_layers=3, d_model=128,
        num_heads=4, num_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512,
        local_window=16, lru_width=128, dtype="float32", remat=False)
