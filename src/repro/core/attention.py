"""Reference attention implementations (pure jnp).

These serve three purposes:
 1. oracle for the Pallas ResidualAttention kernels,
 2. fallback path for shapes the kernels do not cover,
 3. the attention used inside the jitted model steps when running on CPU.

All functions take (batch, seq, heads, head_dim)-shaped tensors ("BSHD").
GQA is handled by repeating KV heads logically via einsum grouping.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: (B, Sq, Hq, D), k: (B, Sk, Hkv, D) -> (B, Hq, Sq, Sk)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s.reshape(b, hq, sq, k.shape[1])


def _gqa_out(p: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """p: (B, Hq, Sq, Sk), v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    b, hq, sq, sk = p.shape
    hkv = v.shape[2]
    group = hq // hkv
    pg = p.reshape(b, hkv, group, sq, sk)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, v.shape[-1])


def attention_mask(sq: int, sk: int, *, causal: bool = True,
                   window: int = 0, q_offset: int = 0) -> jnp.ndarray:
    """Boolean (sq, sk) mask. ``q_offset`` = absolute position of q row 0
    minus that of k row 0 (for decode / chunked prefill).  ``window`` > 0
    restricts to a sliding window of that many past tokens (inclusive)."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return mask


FLASH_THRESHOLD = 1024


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, window: int = 0, q_offset: int = 0,
        kv_len: Optional[jnp.ndarray] = None,
        scale: Optional[float] = None) -> jnp.ndarray:
    """Masked (grouped-query) attention.

    kv_len: optional (batch,) valid KV lengths (padding mask for decode).
    Long sequences automatically take the blocked flash path so the HLO
    never materializes (Sq, Sk) score tensors.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    bsz, sq = q.shape[0], q.shape[1]
    sk = k.shape[1]
    if sq >= FLASH_THRESHOLD and sk >= FLASH_THRESHOLD:
        if window > 0 and causal and q_offset == 0 and kv_len is None \
                and sq == sk:
            # contiguous positions: banded path skips out-of-window blocks
            return banded_window_attention(q, k, v, window=window,
                                           scale=scale)
        qpos = jnp.broadcast_to(jnp.arange(sq) + q_offset, (bsz, sq))
        kpos = jnp.broadcast_to(jnp.arange(sk), (bsz, sk))
        if kv_len is not None:
            kpos = jnp.where(jnp.arange(sk)[None] < kv_len[:, None],
                             kpos, 1 << 30)
        return flash_attention(q, k, v, qpos=qpos, kpos=kpos, window=window,
                               causal=causal, scale=scale)
    s = _gqa_scores(q, k) * scale                      # (B, H, Sq, Sk)
    mask = attention_mask(q.shape[1], k.shape[1], causal=causal,
                          window=window, q_offset=q_offset)
    if kv_len is not None:
        valid = jnp.arange(k.shape[1])[None, :] < kv_len[:, None]  # (B, Sk)
        mask = mask[None, None] & valid[:, None, None, :]
    else:
        mask = mask[None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return _gqa_out(p, v).astype(q.dtype)


# --------------------------------------------------------------------------
# Chunked ("flash-style") attention in pure jnp
# --------------------------------------------------------------------------
# Long-sequence paths (prefill_32k, train_4k on big models, long_500k) must
# not materialize (Sq, Sk) score tensors in the HLO: this scans q-blocks
# (outer) and kv-blocks (inner, online softmax).  Supports GQA, causal +
# sliding-window masks, explicit q/k positions (ring buffers, chunked
# prefill) and optional on-the-fly disaggregated-KV reconstruction — the
# XLA-level mirror of the Pallas ResidualAttention kernel.

_FLASH_NEG = -1e30


def flash_attention(q, k, v, *, qpos, kpos, window: int = 0,
                    causal: bool = True, scale=None,
                    k_res=None, v_res=None, b_k=None, b_v=None,
                    rope_theta: float = 10_000.0, use_rope: bool = True,
                    q_block: int = 512, kv_block: int = 1024) -> jnp.ndarray:
    """Blocked masked attention.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D)
    qpos: (B, Sq) absolute positions; kpos: (B, Sk) absolute positions
      (entries >= 2**30 are treated as empty slots and masked out).
    k_res/v_res: (B, Sk, R) + b_k/b_v: (B, R, Hkv*D) enable disaggregated
      reconstruction per kv block (deferred RoPE on the K residual).
    """
    from repro.core import rope as rope_lib

    bsz, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qb = min(q_block, sq)
    kb = min(kv_block, sk)
    pq, pk = (-sq) % qb, (-sk) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pq)), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pk)), constant_values=1 << 30)
        if k_res is not None:
            k_res = jnp.pad(k_res, ((0, 0), (0, pk), (0, 0)))
            v_res = jnp.pad(v_res, ((0, 0), (0, pk), (0, 0)))
    nq, nk = (sq + pq) // qb, (sk + pk) // kb

    qr = q.reshape(bsz, nq, qb, hq, d).transpose(1, 0, 2, 3, 4)
    qpr = qpos.reshape(bsz, nq, qb).transpose(1, 0, 2)
    kr = k.reshape(bsz, nk, kb, hkv, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(bsz, nk, kb, hkv, d).transpose(1, 0, 2, 3, 4)
    kpr = kpos.reshape(bsz, nk, kb).transpose(1, 0, 2)
    if k_res is not None:
        krr = k_res.reshape(bsz, nk, kb, -1).transpose(1, 0, 2, 3)
        vrr = v_res.reshape(bsz, nk, kb, -1).transpose(1, 0, 2, 3)
    else:
        krr = vrr = None

    def reconstruct_block(kb_, vb_, kres_, vres_, kp_):
        k_lora = jnp.einsum("bsr,brn->bsn", kres_.astype(jnp.float32),
                            b_k.astype(jnp.float32))
        k_lora = k_lora.reshape(kb_.shape)
        if use_rope:
            sin, cos = rope_lib.rope_sincos(
                jnp.where(kp_ >= 1 << 30, 0, kp_), d, rope_theta)
            k_lora = rope_lib.apply_rope(k_lora, sin, cos)
        v_lora = jnp.einsum("bsr,brn->bsn", vres_.astype(jnp.float32),
                            b_v.astype(jnp.float32)).reshape(vb_.shape)
        return (kb_.astype(jnp.float32) + k_lora).astype(kb_.dtype), \
            (vb_.astype(jnp.float32) + v_lora).astype(vb_.dtype)

    def q_body(_, qx):
        q_blk, qp_blk = qx                                # (B,qb,Hq,D)

        def kv_body(carry, kx):
            m, l, acc = carry
            if krr is not None:
                k_blk, v_blk, kres_blk, vres_blk, kp_blk = kx
                k_blk, v_blk = reconstruct_block(k_blk, v_blk, kres_blk,
                                                 vres_blk, kp_blk)
            else:
                k_blk, v_blk, kp_blk = kx
            s = _gqa_scores(q_blk, k_blk) * scale          # (B,Hq,qb,kb)
            qp = qp_blk[:, None, :, None]
            kp = kp_blk[:, None, None, :]
            mask = jnp.ones(s.shape, bool)
            if causal:
                mask &= kp <= qp
            if window > 0:
                mask &= kp > qp - window
            mask &= kp < (1 << 30)
            s = jnp.where(mask, s, _FLASH_NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))    # (B,Hq,qb)
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None]) * mask
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + _gqa_out(
                p, v_blk).transpose(0, 2, 1, 3)            # (B,Hq,qb,D)
            return (m_new, l_new, acc), None

        m0 = jnp.full((bsz, hq, qb), _FLASH_NEG, jnp.float32)
        l0 = jnp.zeros((bsz, hq, qb), jnp.float32)
        a0 = jnp.zeros((bsz, hq, qb, d), jnp.float32)
        kv_xs = (kr, vr, krr, vrr, kpr) if krr is not None else (kr, vr, kpr)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), kv_xs)
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out.transpose(0, 2, 1, 3)             # (B,qb,Hq,D)

    _, outs = jax.lax.scan(q_body, None, (qr, qpr))        # (nq,B,qb,Hq,D)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(bsz, sq + pq, hq, d)
    return out[:, :sq].astype(q.dtype)


def banded_window_attention(q, k, v, *, window: int, scale=None,
                            k_res=None, v_res=None, b_k=None, b_v=None,
                            rope_theta: float = 10_000.0,
                            use_rope: bool = True,
                            q_block: int = 512) -> jnp.ndarray:
    """Causal sliding-window attention over CONTIGUOUS positions 0..S-1.

    §Perf optimization: the generic flash path iterates every kv block even
    when a window masks all but the diagonal band — for a 2048-token window
    in a 32k prefill that is ~13x wasted attention FLOPs.  Here each q block
    attends only to its (window + q_block) band, gathered by dynamic_slice.
    Supports the disaggregated-KV reconstruction like flash_attention.
    """
    from repro.core import rope as rope_lib

    bsz, sq, hq, d = q.shape
    hkv = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    qb = min(q_block, sq)
    pq = (-sq) % qb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    nq = (sq + pq) // qb
    band = window + qb
    # pad k/v left by `window` (absolute position of padded idx j = j-window)
    # and right so every band slice is in range
    pr = pq + window
    kp = jnp.pad(k, ((0, 0), (window, pr), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, pr), (0, 0), (0, 0)))
    if k_res is not None:
        krp = jnp.pad(k_res, ((0, 0), (window, pr), (0, 0)))
        vrp = jnp.pad(v_res, ((0, 0), (window, pr), (0, 0)))

    qr = q.reshape(bsz, nq, qb, hq, d).transpose(1, 0, 2, 3, 4)

    def q_body(_, iq):
        i, q_blk = iq                                   # (B,qb,Hq,D)
        start = i * qb                                  # padded band start
        k_band = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        kpos = start - window + jnp.arange(band)        # absolute positions
        if k_res is not None:
            kr_band = jax.lax.dynamic_slice_in_dim(krp, start, band, axis=1)
            vr_band = jax.lax.dynamic_slice_in_dim(vrp, start, band, axis=1)
            k_lora = jnp.einsum("bsr,brn->bsn", kr_band.astype(jnp.float32),
                                b_k.astype(jnp.float32)).reshape(
                                    k_band.shape)
            if use_rope:
                sin, cos = rope_lib.rope_sincos(
                    jnp.maximum(kpos, 0)[None], d, rope_theta)
                k_lora = rope_lib.apply_rope(k_lora, sin, cos)
            v_lora = jnp.einsum("bsr,brn->bsn", vr_band.astype(jnp.float32),
                                b_v.astype(jnp.float32)).reshape(
                                    v_band.shape)
            k_band = (k_band.astype(jnp.float32) + k_lora).astype(k.dtype)
            v_band = (v_band.astype(jnp.float32) + v_lora).astype(v.dtype)
        s = _gqa_scores(q_blk, k_band) * scale          # (B,Hq,qb,band)
        qpos = start + jnp.arange(qb)
        mask = (kpos[None, :] <= qpos[:, None]) & \
               (kpos[None, :] > qpos[:, None] - window) & (kpos >= 0)[None]
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
        return None, _gqa_out(p, v_band)                # (B,qb,Hq,D)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qr))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(bsz, sq + pq, hq, d)
    return out[:, :sq].astype(q.dtype)
