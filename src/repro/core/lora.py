"""LoRA runtime: parameter containers, initialization and batched application.

The serving engine hosts many adapters on one base model (multi-LoRA).  For a
batch whose rows may target *different* adapters we use a gather-then-einsum
formulation (the JAX/TPU analogue of Punica's BGMV): adapter weights for the
whole registry live in one stacked array, each row gathers its adapter id.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp


class LoRAWeights(NamedTuple):
    """One adapter for one linear projection: ``y = x @ A @ B * scaling``."""

    a: jnp.ndarray   # (d_in, r)
    b: jnp.ndarray   # (r, d_out)
    scaling: float


def init_lora(key: jax.Array, d_in: int, d_out: int, rank: int,
              alpha: float = 32.0, dtype=jnp.bfloat16) -> LoRAWeights:
    """Kaiming-init A, zero-init B (standard LoRA init)."""
    a = jax.random.normal(key, (d_in, rank), dtype=jnp.float32) / jnp.sqrt(d_in)
    b = jnp.zeros((rank, d_out), dtype=jnp.float32)
    return LoRAWeights(a.astype(dtype), b.astype(dtype), alpha / rank)


def init_lora_nonzero(key: jax.Array, d_in: int, d_out: int, rank: int,
                      alpha: float = 32.0, dtype=jnp.bfloat16,
                      scale: float = 0.05) -> LoRAWeights:
    """Non-degenerate init used by tests/benchmarks so adapters actually
    perturb activations (zero-init B makes ForkKV trivially exact)."""
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (d_in, rank), dtype=jnp.float32) / jnp.sqrt(d_in)
    b = jax.random.normal(kb, (rank, d_out), dtype=jnp.float32) * scale / jnp.sqrt(rank)
    return LoRAWeights(a.astype(dtype), b.astype(dtype), alpha / rank)


def lora_apply(x: jnp.ndarray, w: LoRAWeights) -> jnp.ndarray:
    """Full LoRA offset ``(x @ A) @ B * scaling``."""
    return (x @ w.a @ w.b) * w.scaling


def lora_down(x: jnp.ndarray, w: LoRAWeights) -> jnp.ndarray:
    """Down-projection only — this is the rCache entry ``x @ A`` (paper §5.1).

    The ``scaling`` factor is folded in here so the stored residual already
    carries it; reconstruction is then a plain ``rCache @ B``.
    """
    return (x @ w.a) * w.scaling


def lora_up(r: jnp.ndarray, w: LoRAWeights) -> jnp.ndarray:
    """Up-projection of a stored residual: ``rCache @ B``."""
    return r @ w.b


class AdapterStack(NamedTuple):
    """All adapters of a registry stacked for batched multi-LoRA execution."""

    a: jnp.ndarray        # (n_adapters, d_in, r)
    b: jnp.ndarray        # (n_adapters, r, d_out)
    scaling: jnp.ndarray  # (n_adapters,)


def stack_adapters(adapters: Dict[int, LoRAWeights]) -> AdapterStack:
    ids = sorted(adapters)
    assert ids == list(range(len(ids))), "adapter ids must be dense 0..n-1"
    a = jnp.stack([adapters[i].a for i in ids])
    b = jnp.stack([adapters[i].b for i in ids])
    s = jnp.asarray([adapters[i].scaling for i in ids], dtype=jnp.float32)
    return AdapterStack(a, b, s)


def bgmv_down(x: jnp.ndarray, stack: AdapterStack,
              adapter_ids: jnp.ndarray) -> jnp.ndarray:
    """Batched multi-adapter down-projection.

    x: (batch, seq, d_in); adapter_ids: (batch,) int32.
    Returns (batch, seq, r) residuals with per-row adapters (scaling folded).
    """
    a = stack.a[adapter_ids]                       # (batch, d_in, r)
    s = stack.scaling[adapter_ids]                 # (batch,)
    r = jnp.einsum("bsd,bdr->bsr", x, a.astype(x.dtype))
    return r * s[:, None, None].astype(x.dtype)


def bgmv_up(r: jnp.ndarray, stack: AdapterStack,
            adapter_ids: jnp.ndarray) -> jnp.ndarray:
    """Batched multi-adapter up-projection. r: (batch, seq, rank) -> d_out."""
    b = stack.b[adapter_ids]                       # (batch, r, d_out)
    return jnp.einsum("bsr,brd->bsd", r, b.astype(r.dtype))
