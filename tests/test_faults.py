"""Fault-tolerant serving gates (DESIGN.md §17).

Acceptance gates for this layer:
  * preempt–restore PARITY — a seeded fault plan forces >=1 preemption;
    the victim's greedy tokens must be identical to an undisturbed run
    in all three modes, with zero gather fallbacks;
  * quarantine ISOLATION — an injected NaN on one request in a mixed
    batch errors that request alone; co-batched requests finish with
    undisturbed tokens and every page is reclaimed afterwards;
  * graceful DRAIN — drain() refuses queued work terminally
    (``finish_reason="draining"`` / HTTP 503) while in-flight requests
    run to completion;
  * executor ISOLATION — a raising step call fails the affected
    requests terminally and the pump keeps serving;
  * tier IO fallback — a failing device→host export degrades to true
    eviction instead of crashing;
  * stuck-pump WATCHDOG — an injected pump stall trips the frontend
    watchdog counter.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer, SamplingParams
from repro.serving.faults import FaultInjector
from repro.serving.frontend import ForkClient, HttpError, HttpFrontend
from repro.serving.pool import PagePool
from repro.serving.radix import RadixTree
from repro.serving.tiers import HostTier, TieredPagePool

MODES = ["forkkv", "prefix", "full_reuse"]


@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def make_server(model, **kw):
    cfg, params, lora = model
    base = dict(page_size=16, max_pages=256, max_batch=4,
                max_prefill_tokens=64, mode="forkkv", max_pages_per_req=12)
    base.update(kw)
    return ForkServer(cfg, params, lora, ServeConfig(**base)), cfg


def prompt_tokens(cfg, n, seed=0):
    return [int(t) for t in
            np.random.default_rng(seed).integers(0, cfg.vocab_size, n)]


# ---------------------------------------------------------------- parity
@pytest.mark.parametrize("mode", MODES)
def test_preempt_restore_token_parity(model, mode):
    """THE §17 gate: a seeded fault plan denies the second request's
    page allocations until the preempt trigger fires, checkpointing the
    first request into the radix tree mid-decode; once restored, its
    greedy tokens must be identical to an undisturbed run."""
    cfg = model[0]
    p1 = prompt_tokens(cfg, 40, seed=21)
    p2 = prompt_tokens(cfg, 40, seed=22)

    undisturbed, _ = make_server(model, mode=mode)
    ref = [o.tokens for o in undisturbed.wait(
        [undisturbed.generate(1, p1, SamplingParams(max_new_tokens=16)),
         undisturbed.generate(2, p2, SamplingParams(max_new_tokens=8))])]

    # forkkv admission allocates from BOTH pools (base then residual);
    # fail the 8 pool_alloc calls after the first request's so the
    # second stays blocked long past preempt_after_steps
    pre = 2 if mode == "forkkv" else 1
    plan = "pool_alloc:" + ",".join(f"c{pre + i + 1}" for i in range(8))
    server, _ = make_server(model, mode=mode, fault_plan=plan,
                            preempt_after_steps=2)
    h1 = server.generate(1, p1, SamplingParams(max_new_tokens=16))
    h2 = server.generate(2, p2, SamplingParams(max_new_tokens=8))
    outs = server.wait([h1, h2])

    m = server.metrics()
    assert m["preempted_requests"] >= 1, m["faults_fired"]
    assert m["restored_requests"] >= 1
    assert m["faults_fired"]["fault_pool_alloc"] >= 2
    assert outs[0].finish_reason == "length" and \
        outs[1].finish_reason == "length"
    assert outs[0].tokens == ref[0], "victim tokens diverged after restore"
    assert outs[1].tokens == ref[1]
    assert m["fallback_gather_calls"] == 0


def test_preempt_restore_under_real_pressure(model):
    """Same gate without injection: a pool too small for both requests
    forces a real preemption, and the restore path re-prefills only the
    uncovered suffix (recompute_tokens accounting is exact-bounded)."""
    cfg = model[0]
    p1 = prompt_tokens(cfg, 40, seed=31)
    p2 = prompt_tokens(cfg, 40, seed=32)

    undisturbed, _ = make_server(model, mode="forkkv")
    ref = [o.tokens for o in undisturbed.wait(
        [undisturbed.generate(1, p1, SamplingParams(max_new_tokens=24)),
         undisturbed.generate(2, p2, SamplingParams(max_new_tokens=8))])]

    # 7 pages total - 1 dump: r1 takes 4 (40+24 tokens), leaving 2 < the
    # 3 r2 needs -> r2 blocks, preempt trigger fires
    server, _ = make_server(model, mode="forkkv", max_pages=7,
                            preempt_after_steps=1)
    h1 = server.generate(1, p1, SamplingParams(max_new_tokens=24))
    h2 = server.generate(2, p2, SamplingParams(max_new_tokens=8))
    outs = server.wait([h1, h2])
    m = server.metrics()
    assert m["preempted_requests"] >= 1
    assert m["restored_requests"] >= 1
    assert outs[0].tokens == ref[0]
    assert outs[1].tokens == ref[1]
    assert m["fallback_gather_calls"] == 0


# ------------------------------------------------------------ quarantine
def test_quarantine_isolates_one_row(model):
    """Injected NaN on one request in a mixed batch: that request alone
    finishes ``finish_reason="error"``; its co-batched peers finish with
    undisturbed tokens; every page is reclaimed afterwards."""
    cfg = model[0]
    prompts = [prompt_tokens(cfg, 36 + 2 * i, seed=40 + i)
               for i in range(3)]

    undisturbed, _ = make_server(model)
    ref = [o.tokens for o in undisturbed.wait(
        [undisturbed.generate(1 + i, p, SamplingParams(max_new_tokens=6))
         for i, p in enumerate(prompts)])]

    # rids are assigned 1.. in generate() order: poison request 2 only
    server, _ = make_server(model, fault_plan="nan_logits:r2")
    handles = [server.generate(1 + i, p, SamplingParams(max_new_tokens=6))
               for i, p in enumerate(prompts)]
    outs = server.wait(handles)

    assert outs[1].finish_reason == "error"
    assert "quarantined" in outs[1].error
    assert outs[0].finish_reason == "length" and outs[0].tokens == ref[0]
    assert outs[2].finish_reason == "length" and outs[2].tokens == ref[2]
    m = server.metrics()
    assert m["quarantined"] == 1
    assert m["fallback_gather_calls"] == 0

    # full page reclamation: drop every tree ref — all device pages must
    # come back except the reserved dump page in each pool
    eng = server.engine
    eng.dual.base.evict(eng.sc.max_pages)
    eng.dual.residual.evict(eng.res_pool.num_pages)
    assert eng.base_pool.free_pages == eng.sc.max_pages - 1
    assert eng.res_pool.free_pages == eng.res_pool.num_pages - 1


def test_quarantine_in_phase_separated_loop(model):
    """The isfinite guard rides the legacy decode/prefill paths too."""
    cfg = model[0]
    prompts = [prompt_tokens(cfg, 32, seed=51),
               prompt_tokens(cfg, 34, seed=52)]
    server, _ = make_server(model, mixed_batching=False,
                            fault_plan="nan_logits:r1")
    handles = [server.generate(1 + i, p, SamplingParams(max_new_tokens=5))
               for i, p in enumerate(prompts)]
    outs = server.wait(handles)
    assert outs[0].finish_reason == "error"
    assert outs[1].finish_reason == "length" and len(outs[1].tokens) == 5
    assert server.metrics()["quarantined"] == 1


# ----------------------------------------------------------------- drain
def test_engine_drain_refuses_queued_finishes_inflight(model):
    cfg = model[0]
    server, _ = make_server(model, max_batch=1)
    eng = server.engine
    h1 = server.generate(1, prompt_tokens(cfg, 40, seed=61),
                         SamplingParams(max_new_tokens=6))
    # admit + start h1 (batch slot 1), then drain with h2 still queued
    server.poll()
    h2 = server.generate(2, prompt_tokens(cfg, 40, seed=62),
                         SamplingParams(max_new_tokens=6))
    server.drain()
    outs = server.wait([h1, h2])
    assert outs[0].finish_reason == "length" and len(outs[0].tokens) == 6
    assert outs[1].finish_reason == "draining"
    assert server.drained
    m = server.metrics()
    assert m["draining"] and m["drained"]


def test_http_drain_503_and_inflight_completion(model):
    """HTTP drain gate: POST /v1/drain while a stream is mid-flight —
    the stream finishes normally, new requests get 503 + Retry-After,
    /healthz flips to draining (503), and the frontend reports drained."""
    server, cfg = make_server(model)
    fe = HttpFrontend(server).start_background()
    client = ForkClient(port=fe.port)
    prompt = prompt_tokens(cfg, 40, seed=71)
    try:
        stream = client.stream_completion(prompt, max_new_tokens=8)
        first = next(stream)            # in flight: >=1 token delivered
        assert not first.get("finished")
        assert client.drain()["draining"]
        with pytest.raises(HttpError) as ei:
            client.completion(prompt[:32], max_new_tokens=4)
        assert ei.value.status == 503
        assert float(ei.value.headers["retry-after"]) >= 1.0
        events = [first] + list(stream)
        assert events[-1]["finished"]
        assert events[-1]["finish_reason"] == "length"
        assert len(events[-1]["tokens"]) == 8
        status, _, doc = client._request("GET", "/healthz")
        assert status == 503 and doc["state"] == "draining"
        deadline = time.time() + 10
        while not fe.drained and time.time() < deadline:
            time.sleep(0.02)
        assert fe.drained
    finally:
        fe.shutdown()


def test_client_retry_backoff_on_503(model):
    """ForkClient retry satellite: 503s from a draining server are
    retried with jittered exponential backoff honoring Retry-After,
    then surfaced with the attempt count; a healthy server reports
    ``client_retries == 0``."""
    server, cfg = make_server(model)
    fe = HttpFrontend(server).start_background()
    prompt = prompt_tokens(cfg, 32, seed=81)
    try:
        ok_client = ForkClient(port=fe.port, max_retries=2)
        doc = ok_client.completion(prompt, max_new_tokens=4)
        assert doc["client_retries"] == 0 and len(doc["tokens"]) == 4

        fe.begin_drain()
        t0 = time.time()
        client = ForkClient(port=fe.port, max_retries=1, backoff_s=0.05)
        with pytest.raises(HttpError) as ei:
            client.completion(prompt[:24], max_new_tokens=4)
        assert ei.value.status == 503
        assert ei.value.retries == 1
        # Retry-After: 1 dominates the 0.05s backoff base
        assert time.time() - t0 >= 1.0
    finally:
        fe.shutdown()


def test_client_retry_delay_honors_retry_after():
    c = ForkClient(max_retries=3, backoff_s=0.25, backoff_cap_s=4.0,
                   retry_seed=7)
    d0 = c._retry_delay(0, {})
    assert 0.125 <= d0 < 0.25
    assert c._retry_delay(0, {"retry-after": "2.5"}) >= 2.5
    assert c._retry_delay(10, {}) <= 4.0      # capped


# ---------------------------------------------------- executor isolation
def test_executor_exception_fails_batch_not_pump(model):
    cfg = model[0]
    server, _ = make_server(model, fault_plan="executor:c3")
    h1 = server.generate(1, prompt_tokens(cfg, 40, seed=91),
                         SamplingParams(max_new_tokens=12))
    out1 = h1.result()
    assert out1.finish_reason == "error"
    assert "injected fault" in out1.error
    # the pump survives: a fresh request completes normally
    h2 = server.generate(2, prompt_tokens(cfg, 40, seed=92),
                         SamplingParams(max_new_tokens=4))
    out2 = h2.result()
    assert out2.finish_reason == "length" and len(out2.tokens) == 4
    m = server.metrics()
    assert m["exec_errors"] == 1
    assert m["faults_fired"]["fault_executor"] == 1


# ------------------------------------------------------ tier IO fallback
def test_tier_demote_io_error_falls_back_to_eviction():
    """A failing device→host export must degrade to the seed's
    destroy-on-evict: pages reclaimed, io_error counted, no crash."""
    host = HostTier(1 << 20)
    pool = TieredPagePool(PagePool(8, 4, "base"), host)

    def boom(pages):
        raise RuntimeError("injected export failure")

    pool.bind(export_fn=boom, import_fn=lambda p, b: None)
    tree = RadixTree(pool)
    pages = pool.alloc(2)
    tree.insert([1, 2, 3, 4, 5, 6, 7, 8], pages)
    pool.decref(pages)
    freed = tree.evict(2)
    assert freed == 2
    assert pool.stats()["tier_io_errors"] == 1
    assert pool.free_pages == 8
    assert host.used_bytes == 0


def test_tier_promote_io_error_keeps_host_node():
    """A failing host→device import leaves the node a valid host-tier
    node (the match truncates; the request recomputes the suffix)."""
    host = HostTier(1 << 20)
    pool = TieredPagePool(PagePool(8, 4, "base"), host)
    calls = {"n": 0}

    def export_fn(pages):
        return [{"d": np.zeros(4)} for _ in pages]

    def import_fn(pages, blobs):
        calls["n"] += 1
        raise RuntimeError("injected import failure")

    pool.bind(export_fn=export_fn, import_fn=import_fn)
    tree = RadixTree(pool)
    pages = pool.alloc(2)
    tree.insert([1, 2, 3, 4, 5, 6, 7, 8], pages)
    pool.decref(pages)
    assert tree.evict(2) == 2                  # demoted to host
    matched_pages, matched, _ = tree.match_prefix(
        [1, 2, 3, 4, 5, 6, 7, 8])
    assert calls["n"] == 1
    assert matched_pages == [] and matched == 0   # truncated, not crashed
    assert pool.stats()["tier_io_errors"] == 1
    assert host.used_bytes > 0                 # host copy survives


def test_engine_tier_fault_sites_wired(model):
    """tier_demote fires through the engine's bound export path and is
    isolated: the run completes, tier_io_errors lands in metrics."""
    cfg = model[0]
    server, _ = make_server(model, max_pages=10, host_tier_bytes=1 << 22,
                            fault_plan="tier_demote:c1")
    # distinct prompts so eviction pressure actually demotes
    for i in range(4):
        out = server.generate(
            1 + i, prompt_tokens(cfg, 48, seed=100 + i),
            SamplingParams(max_new_tokens=4)).result()
        assert out.finish_reason == "length"
    m = server.metrics()
    if m["faults_fired"].get("fault_tier_demote", 0):
        assert m["tier_io_errors"] >= 1


# -------------------------------------------------------------- watchdog
def test_watchdog_trips_on_injected_stall(model):
    server, cfg = make_server(model, fault_plan="pump_stall:c2,c3",
                              watchdog_s=0.05)
    server.engine.faults.stall_s = 0.3
    fe = HttpFrontend(server).start_background()
    client = ForkClient(port=fe.port)
    try:
        doc = client.completion(prompt_tokens(cfg, 40, seed=111),
                                max_new_tokens=8)
        assert len(doc["tokens"]) == 8       # stall delays, never corrupts
        assert client.metrics()["watchdog_trips"] >= 1
        assert client.healthz()              # recovered: healthy again
    finally:
        fe.shutdown()


def test_fault_plan_grammar():
    fi = FaultInjector("pool_alloc:c2,c4;nan_logits:r9;executor:*", seed=1)
    assert fi.active
    assert [fi.fire("pool_alloc") for _ in range(5)] == \
        [False, True, False, True, False]
    assert not fi.fire("nan_logits", key=8)
    assert fi.fire("nan_logits", key=9)
    assert fi.fire("executor") and fi.fire("executor")
    assert fi.stats() == {"fault_pool_alloc": 2, "fault_nan_logits": 1,
                          "fault_executor": 2}
    with pytest.raises(ValueError):
        FaultInjector("bogus_site:c1")
    with pytest.raises(ValueError):
        FaultInjector("pool_alloc:x9").fire("pool_alloc")
    # probabilistic triggers are seed-deterministic
    a = [FaultInjector("pool_alloc:p0.5", seed=3).fire("pool_alloc")
         for _ in range(1)]
    b = [FaultInjector("pool_alloc:p0.5", seed=3).fire("pool_alloc")
         for _ in range(1)]
    assert a == b
