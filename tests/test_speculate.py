"""Speculative decoding tests (DESIGN.md §16).

Proposer units (pure host-side, no model), the accept-rule reference,
adaptive-k backoff, and engine integration: token parity against plain
decode under an all-rejecting proposer, full acceptance (fewer engine
steps) under an oracle proposer, k=0 degeneration, stall detection with
speculation enabled, and fair-share billing of ACCEPTED — never merely
proposed — tokens.
"""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer, SamplingParams
from repro.serving.speculate import (AdaptiveK, NGramCacheProposer,
                                     PromptLookupProposer, Proposer,
                                     longest_accepted_prefix,
                                     make_proposer)


# ------------------------------------------------------------- proposers
def test_prompt_lookup_matches_most_recent_longest_ngram():
    p = PromptLookupProposer(max_ngram=3, min_ngram=2)
    # suffix (7, 8) occurred earlier, followed by 9, 1
    toks = [1, 7, 8, 9, 1, 5, 7, 8]
    assert p.propose(toks, 2) == [9, 1]
    # longest n wins: suffix (7, 8, 9) matches over the 2-gram site
    toks = [7, 8, 9, 4, 2, 7, 8, 9]
    assert p.propose(toks, 1) == [4]


def test_prompt_lookup_no_match_and_k0():
    p = PromptLookupProposer()
    assert p.propose([1, 2, 3, 4, 5], 4) == []     # no repeated n-gram
    assert p.propose([1, 2, 1, 2], 0) == []        # k=0 -> no proposal
    assert p.propose([1], 4) == []                 # too short


def test_ngram_cache_replays_observed_sequence():
    p = NGramCacheProposer(max_ngram=3, min_ngram=2, cont_len=8)
    seq = [10, 11, 12, 13, 14, 15, 16]
    p.observe(seq)
    # a fresh request reaching ...11, 12 continues as the observed one
    assert p.propose([40, 41, 11, 12], 3) == [13, 14, 15]
    assert p.stats()["hits"] == 1


def test_ngram_cache_bounded_memory_lru():
    p = NGramCacheProposer(max_ngram=2, min_ngram=2, max_entries=8)
    for i in range(100):
        p.observe([i, i + 1, i + 2])
    assert len(p) <= 8
    # oldest entries evicted, newest retained
    assert p.propose([99, 100], 1) == [101]
    assert p.propose([0, 1], 1) != [2]


def test_ngram_cache_falls_back_to_prompt_lookup():
    p = NGramCacheProposer(max_ngram=3, min_ngram=2)
    # cold cache, but the request's own tokens self-match
    assert p.propose([5, 6, 7, 1, 5, 6], 1) == [7]
    assert p.stats()["misses"] == 1


def test_make_proposer_dispatch():
    assert make_proposer(ServeConfig()).name == "prompt_lookup"
    assert make_proposer(
        ServeConfig(spec_proposer="ngram_cache")).name == "ngram_cache"
    with pytest.raises(ValueError):
        make_proposer(ServeConfig(spec_proposer="oracle"))


# ------------------------------------------------------- accept rule
def test_longest_accepted_prefix():
    assert longest_accepted_prefix([], []) == 0
    assert longest_accepted_prefix([1, 2, 3], [1, 2, 3]) == 3
    assert longest_accepted_prefix([1, 2, 3], [1, 9, 3]) == 1
    assert longest_accepted_prefix([9, 2], [1, 2]) == 0   # all rejected


# ------------------------------------------------------- adaptive k
def test_adaptive_k_backs_off_and_recovers():
    ctl = AdaptiveK(k_max=8)
    assert ctl.k == 8                         # optimistic start
    for _ in range(6):                        # garbage proposer
        ctl.update(8, 0)
    assert ctl.k == 1, "sustained rejection must converge to k_min"
    for _ in range(12):                       # replayed trace
        ctl.update(ctl.k, ctl.k)
    assert ctl.k == 8, "sustained acceptance must recover to k_max"


def test_adaptive_k_ignores_empty_steps():
    ctl = AdaptiveK(k_max=4)
    ctl.update(0, 0)                          # no proposal this step
    assert ctl.k == 4 and ctl.ema == 1.0


# -------------------------------------------------- engine integration
@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=8)
    return cfg, params, lora


def make_server(model, **kw):
    cfg, params, lora = model
    base = dict(page_size=16, max_pages=128, max_batch=4,
                max_prefill_tokens=64, mode="forkkv",
                max_pages_per_req=12)
    base.update(kw)
    return ForkServer(cfg, params, lora, ServeConfig(**base)), cfg


def prompt_tokens(cfg, n, seed=0):
    return list(np.random.default_rng(seed).integers(0, cfg.vocab_size, n))


class _StubProposer(Proposer):
    """Deterministic draft source for integration tests."""

    name = "stub"

    def __init__(self, fn):
        self._fn = fn

    def propose(self, tokens, k):
        return list(self._fn(list(tokens), k))


def _run(model, proposer_fn=None, speculate=True, **kw):
    server, cfg = make_server(model, speculate=speculate, spec_k=4,
                              spec_adaptive=False, **kw)
    if proposer_fn is not None:
        server.engine.proposer = _StubProposer(proposer_fn)
    prompt = prompt_tokens(cfg, 40, seed=3)
    out = server.generate(1, prompt,
                          SamplingParams(max_new_tokens=10)).result()
    return out, server


def test_all_rejected_drafts_keep_token_parity(model):
    """A proposer feeding pure garbage must cost steps, never tokens:
    the committed stream equals plain decode bit-for-bit and every
    rejected draft's KV is dropped via CoW (no gather fallbacks)."""
    base, _ = _run(model, speculate=False)
    spec, server = _run(model, proposer_fn=lambda t, k: [0] * k)
    assert spec.tokens == base.tokens
    m = server.metrics()
    assert m["spec_proposed_tokens"] > 0
    assert m["spec_accepted_tokens"] == 0
    assert m["fallback_gather_calls"] == 0
    # the bonus token still commits: a verify step is never slower than
    # a decode step in tokens
    assert spec.metrics["spec_proposed"] > 0
    assert spec.metrics["spec_accepted"] == 0


def test_oracle_proposer_accepts_everything_in_fewer_steps(model):
    """An oracle that proposes the true continuation gets every draft
    accepted and finishes in fewer engine steps than plain decode."""
    base, base_srv = _run(model, speculate=False)
    seq_prompt = prompt_tokens(model[0], 40, seed=3)
    full = seq_prompt + base.tokens

    def oracle(tokens, k):
        pos = len(tokens)
        return full[pos:pos + k]

    spec, server = _run(model, proposer_fn=oracle)
    assert spec.tokens == base.tokens
    m = server.metrics()
    assert m["spec_accepted_tokens"] == m["spec_proposed_tokens"] > 0
    assert m["spec_acceptance_rate"] == 1.0
    assert server.engine.steps < base_srv.engine.steps, \
        "full acceptance must compress the step count"


def test_k0_and_per_request_opt_out_degenerate_to_plain_decode(model):
    """spec_k clamped to zero budget and per-request speculate=False both
    produce plain decode rows — zero verify steps."""
    server, cfg = make_server(model, speculate=True, spec_k=4)
    prompt = prompt_tokens(cfg, 40, seed=5)
    out = server.generate(
        1, prompt, SamplingParams(max_new_tokens=6,
                                  speculate=False)).result()
    assert len(out.tokens) == 6
    assert server.metrics()["spec_steps"] == 0
    # sampled requests never speculate either (greedy-only rule)
    out2 = server.generate(
        1, prompt, SamplingParams(max_new_tokens=6, temperature=0.7,
                                  seed=9)).result()
    assert len(out2.tokens) == 6
    assert server.metrics()["spec_steps"] == 0


def test_per_request_opt_in_with_engine_default_off(model):
    server, cfg = make_server(model, speculate=False,
                              spec_proposer="ngram_cache")
    prompt = prompt_tokens(cfg, 40, seed=6)
    # warm: first request observed at finish; replay opts in per-request
    server.generate(1, prompt, SamplingParams(max_new_tokens=8)).result()
    out = server.generate(
        1, prompt, SamplingParams(max_new_tokens=8,
                                  speculate=True)).result()
    m = server.metrics()
    assert m["spec_steps"] > 0 and m["spec_accepted_tokens"] > 0
    assert len(out.tokens) == 8


def test_stall_detection_still_fires_with_speculation(model):
    """Speculation must not mask the no-progress stall detector: an
    impossible-to-admit request still fails loudly."""
    server, cfg = make_server(model, max_pages=12, stall_limit=8,
                              speculate=True)
    sess = server.session(prompt_tokens(cfg, 96, seed=6))  # pins 6 pages
    # disjoint prompt needing more pages than can ever be freed
    h = server.generate(1, prompt_tokens(cfg, 120, seed=7),
                        SamplingParams(max_new_tokens=4))
    out = h.result()
    assert out.finish_reason == "stalled"
    assert server.metrics()["stalled"] == 1
    sess.close()


def test_fairshare_bills_accepted_not_proposed_tokens(model):
    """Admission billing settles to the tokens actually generated:
    rejected drafts are never service, and a stop-token finish refunds
    the unused decode budget (speculation on or off)."""
    server, cfg = make_server(model, admission="fairshare",
                              speculate=True, spec_adaptive=False,
                              spec_k=4)
    server.engine.proposer = _StubProposer(lambda t, k: [0] * k)
    prompt = prompt_tokens(cfg, 32, seed=7)
    server.generate(1, prompt, SamplingParams(max_new_tokens=8),
                    tenant="a").result()
    st = server.engine.policy.tenant("a")
    m = server.metrics()
    assert m["spec_proposed_tokens"] > 0
    # service = prompt cost + tokens generated; the proposed-but-rejected
    # drafts (spec_proposed) must NOT appear
    assert st.service == pytest.approx(len(prompt) + 8)
    assert st.service < len(prompt) + 8 + m["spec_proposed_tokens"]
