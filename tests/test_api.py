"""Session/fork serving API tests (DESIGN.md §11).

Covers the public surface — ForkServer / AgentSession / GenerationHandle /
SamplingParams — plus the engine features it rides on: session pinning,
incremental streaming, seeded sampling, stop tokens, stall detection,
broadcast-fork accounting, and cross-policy greedy parity (the paper's
"negligible quality impact" claim at engine level).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer, SamplingParams


@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def make_server(model, mode="forkkv", max_pages=256, lora=None, **kw):
    cfg, params, default_lora = model
    base = dict(page_size=16, max_pages=max_pages, max_batch=4,
                max_prefill_tokens=64, mode=mode, max_pages_per_req=12)
    base.update(kw)
    sc = ServeConfig(**base)
    return ForkServer(cfg, params, lora or default_lora, sc), cfg


def prompt_tokens(cfg, n, seed=0):
    return list(np.random.default_rng(seed).integers(0, cfg.vocab_size, n))


# ------------------------------------------------------------ streaming
def test_stream_yields_tokens_before_completion(model):
    """Acceptance: .stream() is incremental — events are observed while
    the request is still in flight, and the streamed tokens equal the
    final result exactly."""
    server, cfg = make_server(model)
    session = server.session(prompt_tokens(cfg, 64))
    handle = session.fork(1, [1, 2, 3], SamplingParams(max_new_tokens=8))
    it = handle.stream()
    first = next(it)
    assert not handle.done, "first event must arrive mid-generation"
    assert first.token is not None and first.index == 0
    events = [first] + list(it)
    assert events[-1].finished and events[-1].finish_reason == "length"
    streamed = [e.token for e in events if not e.finished]
    assert streamed == handle.result().tokens
    assert len(streamed) == 8
    session.close()


def test_result_without_stream_and_metrics(model):
    server, cfg = make_server(model)
    handle = server.generate(2, prompt_tokens(cfg, 40),
                             SamplingParams(max_new_tokens=5))
    out = handle.result()
    assert out.finish_reason == "length" and out.error == ""
    assert len(out.tokens) == 5
    assert out.metrics["prompt_tokens"] == 40
    assert out.metrics["prefilled_tokens"] == 40
    assert out.metrics["latency_s"] >= 0


# ------------------------------------------------------------- sampling
def test_greedy_api_matches_direct_model(model):
    """Acceptance: greedy SamplingParams reproduce the seed's argmax path
    bit-for-bit — the paged engine output equals dense-cache decoding."""
    cfg, params, lora = model
    server, _ = make_server(model)
    prompt = prompt_tokens(cfg, 48, seed=2)
    out = server.generate(3, prompt,
                          SamplingParams(max_new_tokens=6)).result()

    ids = jnp.full((1,), 3, jnp.int32)
    tokens = jnp.asarray([prompt])
    cache = tfm.init_cache(cfg, 1, 128, disagg=True, dtype=jnp.float32)
    lg, cache = tfm.prefill(params, tokens, cache, cfg, lora=lora,
                            adapter_ids=ids, disagg=True)
    kv_len = jnp.full((1,), len(prompt), jnp.int32)
    direct = [int(jnp.argmax(lg[0, 0]))]
    last = jnp.asarray([direct[-1]])
    for _ in range(5):
        lg2, cache = tfm.decode_step(params, last, cache, kv_len, cfg,
                                     lora=lora, adapter_ids=ids, disagg=True)
        direct.append(int(jnp.argmax(lg2[0])))
        last = jnp.asarray([direct[-1]])
        kv_len = kv_len + 1
    assert out.tokens == direct


def test_sampling_seeded_and_divergent(model):
    """Same seed -> identical stream; different seeds -> (almost surely)
    different streams; all tokens stay in-vocab."""
    server, cfg = make_server(model)
    prompt = prompt_tokens(cfg, 40, seed=3)
    outs = {}
    for seed in (0, 0, 1, 2):
        sp = SamplingParams(temperature=0.9, top_k=64, top_p=0.95,
                            seed=seed, max_new_tokens=8)
        toks = server.generate(1, prompt, sp).result().tokens
        assert all(0 <= t < cfg.vocab_size for t in toks)
        outs.setdefault(seed, []).append(toks)
    assert outs[0][0] == outs[0][1], "same seed must reproduce exactly"
    assert len({tuple(v[0]) for v in outs.values()}) > 1, \
        "different seeds should explore different streams"


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-2)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_stop_token_finishes_early(model):
    """A produced stop token ends generation with reason "stop" and is
    not included in the returned tokens."""
    server, cfg = make_server(model)
    prompt = prompt_tokens(cfg, 40, seed=4)
    ref = server.generate(1, prompt,
                          SamplingParams(max_new_tokens=8)).result()
    assert len(ref.tokens) == 8
    stop = ref.tokens[3]
    server2, _ = make_server(model)
    out = server2.generate(
        1, prompt, SamplingParams(max_new_tokens=8,
                                  stop_token_ids=(stop,))).result()
    assert out.finish_reason == "stop"
    assert out.tokens == ref.tokens[:3]
    assert stop not in out.tokens[3:]


# ------------------------------------------------------------- sessions
def test_session_pins_context_against_eviction(model):
    """The session's context is immune to eviction while live: even an
    evict-everything sweep must not touch the pinned prefix, and re-forking
    it stays a cache hit.  After close() it becomes evictable."""
    server, cfg = make_server(model, max_pages=48)
    eng = server.engine
    ctx = prompt_tokens(cfg, 64, seed=5)
    session = server.session(ctx)
    # real serving alongside: a foreign request populates + then we sweep
    server.generate(3, prompt_tokens(cfg, 96, seed=13),
                    SamplingParams(max_new_tokens=4)).result()
    eng.dual.base.evict(10_000)          # evict every unpinned leaf
    eng.dual.residual.evict(10_000)
    assert eng.metrics()["evicted_pages"] > 0, "sweep must be real"
    fr = eng.dual.fork(ctx, adapter_id=0, lock=False)
    assert fr.base_len == 64, "pinned context was evicted"
    assert fr.res_len == 64, "pinned residual path was evicted"
    session.close()
    with pytest.raises(RuntimeError):
        session.fork(1, [1])
    # after unpin the context is evictable like any other cache entry
    freed = eng.dual.base.evict(10_000)
    assert freed >= 4
    fr = eng.dual.fork(ctx, adapter_id=0, lock=False)
    assert fr.base_len == 0


def test_session_context_excluded_from_tasks(model):
    server, cfg = make_server(model)
    with server.session(prompt_tokens(cfg, 64)) as session:
        session.fork(1, [5], SamplingParams(max_new_tokens=4)).result()
    m = server.metrics()
    assert m["tasks_done"] == 1
    assert m["context_prefills"] == 1
    assert m["live_sessions"] == 0


def test_fork_inherits_pinned_context(model):
    """Two forks with different adapters share the session's bCache pages
    (partial_res fork kind), the paper's core CoW mechanism, now via the
    public API."""
    server, cfg = make_server(model)
    with server.session(prompt_tokens(cfg, 64)) as session:
        for a in (1, 2):
            session.fork(a, [a], SamplingParams(max_new_tokens=4)).result()
    kinds = server.metrics()["hit_kinds"]
    assert kinds.get("partial_res", 0) >= 2, kinds


# ------------------------------------------------------- stall detection
def test_stall_detection_fails_head_request(model):
    """Regression (satellite): a waiting request that can never allocate —
    pool too small once the session pinned its context, running empty —
    must fail with a ``stalled`` error after stall_limit steps instead of
    silently burning the caller's whole step budget."""
    server, cfg = make_server(model, max_pages=12, stall_limit=8)
    eng = server.engine
    session = server.session(prompt_tokens(cfg, 96, seed=6))   # pins 6 pages
    # disjoint prompt needing more pages than can ever be freed
    handle = server.generate(5, prompt_tokens(cfg, 120, seed=7),
                             SamplingParams(max_new_tokens=4))
    out = handle.result()
    assert out.finish_reason == "stalled"
    assert "stalled" in out.error and out.tokens == []
    m = server.metrics()
    assert m["stalled"] == 1
    assert eng.steps < 8 + 20, "stall must trip promptly, not burn steps"
    # the engine keeps serving: closing the session frees the pool
    session.close()
    eng.dual.base.evict(6)
    ok = server.generate(5, prompt_tokens(cfg, 120, seed=7),
                         SamplingParams(max_new_tokens=4)).result()
    assert ok.finish_reason == "length" and len(ok.tokens) == 4


def test_overlong_request_rejected_via_api(model):
    server, cfg = make_server(model)
    out = server.generate(0, prompt_tokens(cfg, 400),
                          SamplingParams(max_new_tokens=4)).result()
    assert out.finish_reason == "rejected"
    assert "rejected" in out.error and out.tokens == []


# ------------------------------------------- broadcast fork accounting
def test_broadcast_amortized_share_accounting(model):
    """Satellite: the exact int counter attributes the one shared pass to
    its writer; the amortized float share is split across the group and
    feeds metrics()."""
    server, cfg = make_server(model, broadcast_fork=True, max_batch=6)
    shared = prompt_tokens(cfg, 64, seed=8)
    handles = [server.generate(a, list(shared),
                               SamplingParams(max_new_tokens=4))
               for a in range(3)]
    outs = server.wait(handles)
    # the broadcast covers the first 48 tokens (the final page is left to a
    # per-request prefill so the first output token comes from real logits);
    # each request then pays its own 16-token tail
    exact = sorted(int(o.metrics["prefilled_tokens"]) for o in outs)
    shares = [o.metrics["prefill_share"] for o in outs]
    assert exact == [16, 16, 48 + 16], exact   # writer-only pass, exact ints
    for s in shares:                            # amortized: 48/3 + own tail
        assert abs(s - (48 / 3 + 16)) < 1e-6, shares
    m = server.metrics()
    assert abs(m["prefilled_tokens"] - (48 + 3 * 16)) < 1e-6


# ------------------------------------------------- cross-policy parity
def test_greedy_parity_forkkv_vs_prefix(model):
    """Satellite: with greedy sampling, identical seeds, and numerically
    identical adapters (zero-B LoRA — cache sharing is then lossless, so
    any divergence exposes an engine bug: stale pages, wrong resume
    position, CoW misrouting), forkkv and prefix modes produce
    token-identical outputs for the same ReAct workload, driven entirely
    through the public API."""
    cfg, params, _ = model
    lora0 = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(9), n_adapters=8,
                                 nonzero=False)

    def react_outputs(mode):
        server, _ = make_server(model, mode=mode, max_pages=512, lora=lora0)
        rng = np.random.default_rng(42)
        shared = list(rng.integers(0, cfg.vocab_size, 96))
        outputs = []
        with server.session(shared) as session:
            dynamic = []
            for agent in range(3):          # sequential ReAct chain
                instr = dynamic + list(rng.integers(0, cfg.vocab_size, 8))
                out = session.fork(agent, instr,
                                   SamplingParams(max_new_tokens=4,
                                                  seed=0)).result()
                outputs.append(out.tokens)
                dynamic = dynamic + out.tokens + \
                    list(rng.integers(0, cfg.vocab_size, 12))
        return outputs

    fork_out = react_outputs("forkkv")
    prefix_out = react_outputs("prefix")
    assert fork_out == prefix_out, (fork_out, prefix_out)
