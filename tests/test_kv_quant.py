"""int8 bCache paging inside the kernels (DESIGN.md §18).

Three layers of gates:

  * cross-backend parity — the Pallas kernels (interpret mode) and the
    XLA ref mirror dequantize the SAME int8 pages, so their outputs must
    agree to float32 accumulation noise (tight atol), for decode,
    chunked prefill and the unified mixed grid, disaggregated and
    base-only;
  * quality bound — int8 per-(position, head) symmetric quantization is
    lossy; the documented tolerance is a 5% max-abs error against the
    full-precision output (quantization error per element is <= scale/2
    ~ 0.4% of the per-token amax; softmax mixing keeps the output error
    well under the bound in practice);
  * serving parity — a greedy engine run with ``kv_quant="int8"``
    produces identical tokens on the paged path and the legacy gather
    path (both read the same quantized pools) with
    ``fallback_gather_calls == 0`` on the paged side.

The suite runs under whichever backend ``FORKKV_KERNEL_BACKEND``
selects, like tests/test_parity_matrix.py; the kernel-level tests pin
both backends explicitly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.kernels import ops as kernel_ops
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams

PAGE = 16
P = 8          # pool pages
HKV = 2
HQ = 4
D = 64
R = 4
W = 3          # block-table width
ATOL_BACKEND = 1e-3   # same int8 pages, fp32 math: accumulation noise only
QUALITY_TOL = 0.05    # documented int8-vs-fp32 max-abs bound (DESIGN.md §18)


def _quant_pools(rng):
    """Full-precision pools + their int8 quantization (+ residuals)."""
    kb = jnp.asarray(rng.standard_normal((P, PAGE, HKV, D)), jnp.float32)
    vb = jnp.asarray(rng.standard_normal((P, PAGE, HKV, D)), jnp.float32)
    kq, ks = tfm.quantize_kv(kb)
    vq, vs = tfm.quantize_kv(vb)
    kr = jnp.asarray(rng.standard_normal((P, PAGE, R)), jnp.float32)
    vr = jnp.asarray(rng.standard_normal((P, PAGE, R)), jnp.float32)
    return kb, vb, kq, ks, vq, vs, kr, vr


def _tables(rng, bsz):
    bt = rng.permutation(P - 1)[: bsz * W].reshape(bsz, W)
    return jnp.asarray(bt, jnp.int32)


@pytest.mark.parametrize("disagg", [True, False],
                         ids=["disagg", "base-only"])
def test_int8_decode_backend_parity_and_quality(disagg):
    rng = np.random.default_rng(0)
    kb, vb, kq, ks, vq, vs, kr, vr = _quant_pools(rng)
    bsz = 2
    q = jnp.asarray(rng.standard_normal((bsz, HQ, D)), jnp.float32)
    bt_b = _tables(rng, bsz)
    bt_r = _tables(rng, bsz)
    kv_len = jnp.asarray([PAGE * W - 3, PAGE + 5], jnp.int32)
    if disagg:
        b_k = jnp.asarray(rng.standard_normal((bsz, R, HKV * D)) * 0.1,
                          jnp.float32)
        b_v = jnp.asarray(rng.standard_normal((bsz, R, HKV * D)) * 0.1,
                          jnp.float32)
        args = (q, kq, vq, kr, vr, b_k, b_v, bt_b, bt_r, kv_len)
        full = (q, kb, vb, kr, vr, b_k, b_v, bt_b, bt_r, kv_len)
    else:
        args = (q, kq, vq, None, None, None, None, bt_b, None, kv_len)
        full = (q, kb, vb, None, None, None, None, bt_b, None, kv_len)
    kw = dict(scale=D ** -0.5, kb_scale=ks, vb_scale=vs)
    o_ref = np.asarray(kernel_ops.paged_residual_attention(
        *args, backend="ref", **kw))
    o_pal = np.asarray(kernel_ops.paged_residual_attention(
        *args, backend="pallas", interpret=True, **kw))
    np.testing.assert_allclose(o_pal, o_ref, atol=ATOL_BACKEND,
                               rtol=ATOL_BACKEND)
    # quality: int8 vs full-precision within the documented bound
    o_fp = np.asarray(kernel_ops.paged_residual_attention(
        *full, backend="ref", scale=D ** -0.5))
    err = np.abs(o_ref - o_fp).max()
    assert err <= QUALITY_TOL * np.abs(o_fp).max(), err


@pytest.mark.parametrize("disagg", [True, False],
                         ids=["disagg", "base-only"])
def test_int8_prefill_backend_parity(disagg):
    rng = np.random.default_rng(1)
    kb, vb, kq, ks, vq, vs, kr, vr = _quant_pools(rng)
    bsz, chunk = 2, 8
    q = jnp.asarray(rng.standard_normal((bsz, chunk, HQ, D)), jnp.float32)
    bt_b = _tables(rng, bsz)
    bt_r = _tables(rng, bsz)
    start = jnp.asarray([PAGE, 4], jnp.int32)
    kv_len = start + chunk
    if disagg:
        b_k = jnp.asarray(rng.standard_normal((bsz, R, HKV * D)) * 0.1,
                          jnp.float32)
        b_v = jnp.asarray(rng.standard_normal((bsz, R, HKV * D)) * 0.1,
                          jnp.float32)
        args = (q, kq, vq, kr, vr, b_k, b_v, bt_b, bt_r, start, kv_len)
    else:
        args = (q, kq, vq, None, None, None, None, bt_b, None, start,
                kv_len)
    kw = dict(scale=D ** -0.5, kb_scale=ks, vb_scale=vs)
    o_ref = np.asarray(kernel_ops.paged_residual_attention_prefill(
        *args, backend="ref", **kw))
    o_pal = np.asarray(kernel_ops.paged_residual_attention_prefill(
        *args, backend="pallas", interpret=True, **kw))
    np.testing.assert_allclose(o_pal, o_ref, atol=ATOL_BACKEND,
                               rtol=ATOL_BACKEND)


@pytest.mark.parametrize("disagg", [True, False],
                         ids=["disagg", "base-only"])
def test_int8_mixed_backend_parity(disagg):
    """Mixed grid: a decode row (q_len=1) and a prefill row (q_len=chunk)
    share one launch; padding rows are exact zeros on both backends."""
    rng = np.random.default_rng(2)
    kb, vb, kq, ks, vq, vs, kr, vr = _quant_pools(rng)
    bsz, chunk = 2, 8
    q = jnp.asarray(rng.standard_normal((bsz, chunk, HQ, D)), jnp.float32)
    bt_b = _tables(rng, bsz)
    bt_r = _tables(rng, bsz)
    start = jnp.asarray([PAGE + 7, 4], jnp.int32)
    q_len = jnp.asarray([1, chunk], jnp.int32)
    kv_len = start + q_len
    if disagg:
        b_k = jnp.asarray(rng.standard_normal((bsz, R, HKV * D)) * 0.1,
                          jnp.float32)
        b_v = jnp.asarray(rng.standard_normal((bsz, R, HKV * D)) * 0.1,
                          jnp.float32)
        args = (q, kq, vq, kr, vr, b_k, b_v, bt_b, bt_r, start, q_len,
                kv_len)
    else:
        args = (q, kq, vq, None, None, None, None, bt_b, None, start,
                q_len, kv_len)
    kw = dict(scale=D ** -0.5, kb_scale=ks, vb_scale=vs)
    o_ref = np.asarray(kernel_ops.paged_residual_attention_mixed(
        *args, backend="ref", **kw))
    o_pal = np.asarray(kernel_ops.paged_residual_attention_mixed(
        *args, backend="pallas", interpret=True, **kw))
    np.testing.assert_allclose(o_pal, o_ref, atol=ATOL_BACKEND,
                               rtol=ATOL_BACKEND)
    # padding rows past q_len are exact zeros on both backends
    assert np.all(o_ref[0, 1:] == 0.0)
    assert np.all(o_pal[0, 1:] == 0.0)


# ---------------------------------------------------------------- serving
@pytest.fixture(scope="module")
def model_int8():
    cfg = dataclasses.replace(tiny_serving_model(rank=8), kv_quant="int8")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def _serve(model, mode, *, paged):
    cfg, params, lora = model
    sc = ServeConfig(page_size=16, max_pages=192, max_batch=4,
                     max_prefill_tokens=64, mode=mode,
                     max_pages_per_req=12, use_paged_kernel=paged)
    return ForkServer(cfg, params, lora, sc)


@pytest.mark.parametrize("mode", ["forkkv", "prefix"])
def test_int8_engine_paged_vs_gather_parity(model_int8, mode):
    """Greedy serving with int8 bCache pages: the paged kernels and the
    legacy gather path read the same quantized pools, so tokens must be
    IDENTICAL — and the paged side takes zero gather fallbacks."""
    cfg = model_int8[0]
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(0, cfg.vocab_size, 30 + 9 * i))
               for i in range(3)]
    outs = {}
    for paged in (True, False):
        server = _serve(model_int8, mode, paged=paged)
        hs = [server.generate(i + 1, p, SamplingParams(max_new_tokens=6))
              for i, p in enumerate(prompts)]
        outs[paged] = [o.tokens for o in server.wait(hs)]
        m = server.metrics()
        if paged:
            assert m["fallback_gather_calls"] == 0, m
        else:
            assert m["fallback_gather_calls"] > 0, m
    assert outs[True] == outs[False]


def test_int8_engine_fork_reuse(model_int8):
    """CoW forks over quantized shared pages still hit the radix cache:
    two agents forked off one shared context reuse its int8 pages."""
    cfg = model_int8[0]
    rng = np.random.default_rng(8)
    shared = list(rng.integers(0, cfg.vocab_size, 48))
    server = _serve(model_int8, "forkkv", paged=True)
    outs = []
    for i in range(2):       # sequential: the 2nd forks off the 1st's pages
        h = server.generate(i + 1, shared + list(
            rng.integers(0, cfg.vocab_size, 8)),
            SamplingParams(max_new_tokens=4))
        outs.append(server.wait([h])[0].tokens)
    assert all(len(t) == 4 for t in outs)
    assert server.metrics()["hit_tokens"] > 0
