"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  python -m benchmarks.run              # all benchmarks
  python -m benchmarks.run --only memory,throughput
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ("memory", "throughput", "internals", "quality", "sensitivity",
            "kernel", "roofline", "tiering", "decode", "prefill")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else list(SECTIONS)

    print("name,us_per_call,derived")
    for section in SECTIONS:
        if section not in wanted:
            continue
        mod = __import__(f"benchmarks.bench_{section}",
                         fromlist=["main"])
        t0 = time.time()
        try:
            mod.main()
        except Exception as e:   # keep the harness running
            print(f"bench_{section}.ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stdout)
        print(f"bench_{section}.total,{(time.time()-t0)*1e6:.0f},ok")


if __name__ == "__main__":
    main()
