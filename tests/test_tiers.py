"""Tiered KV offload (DESIGN.md §10): HostTier / TieredPagePool units +
engine-level demote/promote behaviour under device-memory pressure."""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine, Request
from repro.serving.pool import PagePool
from repro.serving.radix import RadixTree
from repro.serving.tiers import HostTier, TieredPagePool, blob_bytes
from repro.serving.workflows import WorkflowConfig, WorkflowDriver

PAGE = 4


# ---------------------------------------------------------------- HostTier
def blob(val, elems=8):
    return {"x": np.full(elems, val, np.float32)}


def test_host_tier_put_get_roundtrip_and_budget():
    host = HostTier(budget_bytes=3 * 32)      # room for three 8-float blobs
    h1 = host.put(blob(1.0))
    h2 = host.put(blob(2.0))
    assert h1 in host and host.used_bytes == 64
    np.testing.assert_array_equal(host.get(h1)["x"], blob(1.0)["x"])
    host.free(h1)
    assert h1 not in host and host.used_bytes == 32
    host.free(h1)                             # idempotent
    assert host.used_bytes == 32
    assert host.put(blob(9.0, elems=100)) is None   # larger than budget
    assert h2 in host


def test_host_tier_lru_eviction_order_and_touch():
    host = HostTier(budget_bytes=2 * 32)
    h1, h2 = host.put(blob(1.0)), host.put(blob(2.0))
    host.touch(h1)                            # h2 becomes LRU
    h3 = host.put(blob(3.0))
    assert h2 not in host and h1 in host and h3 in host
    assert host.evicted_entries == 1 and host.evicted_bytes == 32


# --------------------------------------------------- TieredPagePool + tree
class FakeDeviceStore:
    """Numpy stand-in for the executor's pooled device arrays."""

    def __init__(self, num_pages, elems=8):
        self.data = np.zeros((num_pages, elems), np.float32)

    def export(self, pages):
        return [{"x": self.data[p].copy()} for p in pages]

    def import_(self, pages, blobs):
        for p, b in zip(pages, blobs):
            self.data[p] = b["x"]


def make_tiered(num_pages=16, budget=1 << 20, promote_limit=0):
    store = FakeDeviceStore(num_pages)
    host = HostTier(budget)
    pool = TieredPagePool(PagePool(num_pages, PAGE), host,
                          export_fn=store.export, import_fn=store.import_,
                          promote_limit=promote_limit)
    tree = RadixTree(pool)
    pool.pressure_fn = tree.evict
    return tree, pool, store, host


def insert_seq(tree, pool, store, toks, fill):
    pages = pool.alloc(len(toks) // PAGE)
    for i, p in enumerate(pages):
        store.data[p] = fill * 100 + i
    tree.insert(toks, pages)
    pool.decref(pages)                        # tree becomes sole owner
    return pages


def test_demote_promote_roundtrip_bit_identical():
    tree, pool, store, host = make_tiered()
    toks = list(range(8))
    pages = insert_seq(tree, pool, store, toks, fill=7)
    snapshot = {p: store.data[p].copy() for p in pages}
    freed = tree.evict(2)
    assert freed == 2
    assert pool.used_pages == 0 and host.num_entries == 2
    assert tree.demoted_pages == 2 and tree.evicted_pages == 0
    store.data[:] = -1                        # scribble freed device memory
    got, matched, _ = tree.match_prefix(toks)
    assert matched == 8 and pool.tier_hits == 1
    for old, new in zip(pages, got):          # bytes came back exactly
        np.testing.assert_array_equal(store.data[new], snapshot[old])
        assert pool.refcount(new) == 1        # tree owns the promoted page
    assert host.num_entries == 0              # host copy consumed
    # a second demote→promote cycle still round-trips
    assert tree.evict(2) == 2
    got2, matched2, _ = tree.match_prefix(toks)
    assert matched2 == 8
    for old, new in zip(pages, got2):
        np.testing.assert_array_equal(store.data[new], snapshot[old])


def test_demote_requires_sole_ownership():
    tree, pool, store, host = make_tiered()
    toks = list(range(4))
    pages = pool.alloc(1)
    store.data[pages[0]] = 5.0
    tree.insert(toks, pages)                  # refcount 2: caller + tree
    # CoW guard → true eviction; the caller's ref keeps the page alive,
    # so ZERO pages actually become free (no phantom room reported)
    assert tree.evict(1) == 0
    assert tree.evicted_pages == 1 and tree.demoted_pages == 0
    assert host.num_entries == 0
    assert pool.refcount(pages[0]) == 1       # caller's ref survives


def test_host_budget_exhaustion_degrades_to_true_eviction():
    tree, pool, store, host = make_tiered(budget=0)
    toks = list(range(8))
    insert_seq(tree, pool, store, toks, fill=3)
    assert tree.evict(2) == 2
    assert tree.evicted_pages == 2 and tree.demoted_pages == 0
    assert pool.demote_failures > 0
    _, matched, _ = tree.match_prefix(toks)
    assert matched == 0                       # bytes are gone (seed path)
    assert pool.used_pages == 0


def test_doomed_demote_preserves_existing_host_entries():
    """A node that can NEVER fit the host budget must fail fast, not evict
    other nodes' host entries as collateral for a doomed demote."""
    tree, pool, store, host = make_tiered(budget=2 * 32)
    a = [9, 9, 9, 9, 8, 8, 8, 8]              # 2 pages: fills the budget
    insert_seq(tree, pool, store, a, fill=1)
    tree.evict(2)
    assert host.num_entries == 2
    b = list(range(12))                       # 3 pages: can never fit
    insert_seq(tree, pool, store, b, fill=2)
    tree.evict(3)                             # demote fails → true eviction
    assert tree.evicted_pages == 3 and pool.demote_failures == 1
    assert host.num_entries == 2              # a's entries survived intact
    assert tree.match_prefix(a)[1] == 8       # and still promote fine
    assert tree.match_prefix(b)[1] == 0


def test_host_lru_pressure_drops_oldest_node():
    # budget fits exactly two one-page blobs (8 floats = 32 bytes each)
    tree, pool, store, host = make_tiered(budget=2 * 32)
    a, b, c = [9, 9, 9, 9], [8, 8, 8, 8], [7, 7, 7, 7]
    insert_seq(tree, pool, store, a, fill=1)
    insert_seq(tree, pool, store, b, fill=2)
    tree.evict(2)                             # both demoted, host full
    insert_seq(tree, pool, store, c, fill=3)
    tree.evict(1)                             # demoting c evicts host-LRU a
    assert pool.host_evicted_pages == 1
    assert tree.match_prefix(a)[1] == 0       # a truly gone
    assert tree.match_prefix(b)[1] == 4       # b promoted fine
    assert tree.match_prefix(c)[1] == 4
    np.testing.assert_array_equal(store.data[tree.match_prefix(c)[0][0]],
                                  np.full(8, 300.0, np.float32))


def test_split_of_host_node_retargets_handles():
    tree, pool, store, host = make_tiered()
    toks = list(range(8))
    pages = insert_seq(tree, pool, store, toks, fill=4)
    snapshot = {p: store.data[p].copy() for p in pages}
    tree.evict(2)
    store.data[:] = -1
    got, matched, _ = tree.match_prefix(toks[:4])   # splits the host node
    assert matched == 4 and len(got) == 1
    np.testing.assert_array_equal(store.data[got[0]], snapshot[pages[0]])
    assert host.num_entries == 1              # tail half still on host
    got2, matched2, _ = tree.match_prefix(toks)
    assert matched2 == 8
    np.testing.assert_array_equal(store.data[got2[1]], snapshot[pages[1]])
    assert host.num_entries == 0


def test_promote_limit_truncates_match():
    tree, pool, store, host = make_tiered(promote_limit=1)
    insert_seq(tree, pool, store, list(range(4)), fill=1)
    insert_seq(tree, pool, store, list(range(4)) + [50, 51, 52, 53], fill=2)
    tree.evict(2)
    _, matched, _ = tree.match_prefix(list(range(4)) + [50, 51, 52, 53])
    assert matched == 4                       # second promote over budget
    assert pool.tier_hits == 1 and host.num_entries == 1
    # a fresh match gets a fresh budget and picks up the tail
    _, matched2, _ = tree.match_prefix(list(range(4)) + [50, 51, 52, 53])
    assert matched2 == 8 and host.num_entries == 0


def test_promote_limit_splits_oversized_host_node():
    """A host node LARGER than the whole per-match limit still promotes
    incrementally (split at the budget boundary), never starves."""
    tree, pool, store, host = make_tiered(promote_limit=1)
    toks = list(range(8))                     # one 2-page node
    pages = insert_seq(tree, pool, store, toks, fill=6)
    snapshot = {p: store.data[p].copy() for p in pages}
    tree.evict(2)
    store.data[:] = -1
    got, matched, _ = tree.match_prefix(toks)
    assert matched == 4 and len(got) == 1     # head promoted within budget
    np.testing.assert_array_equal(store.data[got[0]], snapshot[pages[0]])
    got2, matched2, _ = tree.match_prefix(toks)
    assert matched2 == 8                      # next match finishes the job
    np.testing.assert_array_equal(store.data[got2[1]], snapshot[pages[1]])


def test_insert_publishes_suffix_behind_demoted_prefix():
    """Commit-time insert traverses a demoted prefix position-only and
    still adopts the freshly computed suffix behind it."""
    tree, pool, store, host = make_tiered()
    s = list(range(8))
    insert_seq(tree, pool, store, s, fill=1)
    tree.evict(2)                             # prefix S now on host
    full = s + [50, 51, 52, 53]
    owned = pool.alloc(3)                     # a request recomputed S+T
    store.data[owned[2]] = 777.0
    adopted = tree.insert(full, owned)
    assert adopted == 1                       # suffix page published
    pool.decref(owned)                        # request finishes
    assert pool.refcount(owned[2]) == 1       # tree keeps the suffix
    got, matched, _ = tree.match_prefix(full)
    assert matched == 12                      # prefix promoted + suffix
    np.testing.assert_array_equal(store.data[got[2]],
                                  np.full(8, 777.0, np.float32))


def test_demote_under_full_host_with_host_ancestor_no_double_free():
    """Regression: demoting a device node that sits BELOW a host-tier node
    (insert publishes suffixes behind demoted prefixes) while the host
    budget is full must not let host-LRU eviction of the ancestor destroy
    the victim mid-demote (double decref).  The ancestor chain is pinned;
    the demote degrades to a plain eviction of the suffix only."""
    tree, pool, store, host = make_tiered(budget=2 * 32)
    s = list(range(8))
    insert_seq(tree, pool, store, s, fill=1)
    tree.evict(2)                             # prefix S on host, budget full
    full = s + [50, 51, 52, 53, 60, 61, 62, 63]
    owned = pool.alloc(4)
    tree.insert(full, owned)                  # device suffix under host node
    pool.decref(owned)
    freed = tree.evict(2)                     # must not AssertionError
    assert freed == 2 and pool.demote_failures == 1
    assert host.num_entries == 2              # ancestor's entries survived
    assert tree.match_prefix(s)[1] == 8       # prefix still promotes


def test_demote_blocked_by_pinned_entries_spares_collateral():
    """A demote that cannot complete because part of the budget is PINNED
    must fail up front — not destroy an unpinned node's entries first."""
    tree, pool, store, host = make_tiered(budget=3 * 32)
    a, c = [1, 1, 1, 1], [2, 2, 2, 2]
    insert_seq(tree, pool, store, a, fill=1)
    insert_seq(tree, pool, store, c, fill=2)
    tree.evict(2)                             # a and c on host, 32B free
    # pin c's host entry: a position-only locked match (no promotion)
    _, mc, path_c = tree.match_prefix(c, lock=True, promote=False)
    assert mc == 4 and host.num_entries == 2
    big = list(range(12))                     # 3 pages: needs 96B, but only
    insert_seq(tree, pool, store, big, fill=3)   # 32 free + 32 evictable
    assert tree.evict(3) == 3                 # demote impossible → destroy
    assert pool.demote_failures == 1
    assert host.num_entries == 2              # a survived as well as c
    tree.unlock_path(path_c)
    assert tree.match_prefix(a)[1] == 4       # a's bytes still promotable
    assert tree.match_prefix(big)[1] == 0     # big truly evicted


def test_shared_victim_with_host_children_is_skipped():
    """Eviction must not destroy a transiently shared node (refcount > 1)
    whose host-tier subtree would go with it — it skips to the next LRU
    candidate instead."""
    tree, pool, store, host = make_tiered()
    x = [1, 1, 1, 1, 2, 2, 2, 2]
    xp = insert_seq(tree, pool, store, x, fill=1)
    insert_seq(tree, pool, store, x + [3, 3, 3, 3], fill=2)
    # demote the deepest leaf so X has a host child, then share X's pages
    assert tree.evict(1) == 1 and host.num_entries == 1
    pool.incref(xp)                           # transient co-owner (running)
    y = [7, 7, 7, 7]
    insert_seq(tree, pool, store, y, fill=3)  # younger, unshared victim
    tree.match_prefix(y)                      # make X strictly LRU
    freed = tree.evict(1)
    assert freed == 1                         # Y demoted instead of X
    assert tree.match_prefix(x)[1] == 8       # X intact…
    assert host.num_entries >= 1              # …and so is its host child
    pool.decref(xp)


def test_promotion_applies_device_pressure():
    """Promoting with a full device pool demotes colder pages to make room."""
    tree, pool, store, host = make_tiered(num_pages=2)
    a, b = [1, 1, 1, 1], [2, 2, 2, 2]
    insert_seq(tree, pool, store, a, fill=1)
    insert_seq(tree, pool, store, b, fill=2)
    tree.evict(1)                             # LRU (a) demoted
    assert pool.used_pages == 1
    extra = pool.alloc(1)                     # device pool now full
    got, matched, _ = tree.match_prefix(a)    # promote a → must demote b
    assert matched == 4
    np.testing.assert_array_equal(store.data[got[0]],
                                  np.full(8, 100.0, np.float32))
    assert pool.demoted_pages == 2            # a earlier, b under pressure
    pool.decref(extra)
    _, mb, _ = tree.match_prefix(b)           # b survives on host
    assert mb == 4


# ------------------------------------------------------------ engine level
@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def run_one(engine, adapter, prompt, max_new=4):
    req = Request(rid=0, adapter_id=adapter, prompt=list(prompt),
                  max_new_tokens=max_new)
    engine.submit(req)
    while req.state != "done":
        engine.step()
    return req


def test_engine_demote_promote_bit_identical(model):
    """Acceptance: demoted pages promote back bit-identical through the
    real executor pools (bCache and rCache)."""
    cfg, params, lora = model
    sc = ServeConfig(page_size=16, max_pages=256, max_batch=4,
                     max_prefill_tokens=64, mode="forkkv",
                     max_pages_per_req=12, host_tier_bytes=64 << 20)
    eng = Engine(cfg, params, lora, sc)
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(0, cfg.vocab_size, 64))
    run_one(eng, adapter=3, prompt=prompt)
    fr = eng.dual.fork(prompt, 3, lock=False)
    bpages, rpages = list(fr.base_pages), list(fr.res_pages)
    assert bpages and rpages
    snap_kb = np.asarray(eng.executor.pools.kb[:, bpages])
    snap_vb = np.asarray(eng.executor.pools.vb[:, bpages])
    snap_kr = np.asarray(eng.executor.pools.kr[:, rpages])
    eng.dual.base.evict(len(bpages))
    eng.dual.residual.evict(len(rpages))
    assert eng.base_pool.demoted_pages >= len(bpages)
    assert eng.res_pool.demoted_pages >= len(rpages)
    fr2 = eng.dual.fork(prompt, 3, lock=False)     # promotes both caches
    assert fr2.reuse_len >= fr.reuse_len
    b2, r2 = list(fr2.base_pages), list(fr2.res_pages)
    np.testing.assert_array_equal(
        snap_kb, np.asarray(eng.executor.pools.kb[:, b2]))
    np.testing.assert_array_equal(
        snap_vb, np.asarray(eng.executor.pools.vb[:, b2]))
    np.testing.assert_array_equal(
        snap_kr, np.asarray(eng.executor.pools.kr[:, r2]))
    m = eng.metrics()
    assert m["tier_hits"] >= 2 and m["promoted_bytes"] > 0


def _react(model, host_tier_bytes):
    cfg, params, lora = model
    # device budget (26 pages) barely covers ONE request's footprint, so
    # every admission churns the whole base tree — far below the working
    # set of 6 agent contexts (~270-360 tokens each).  rounds=2 makes each
    # adapter re-fork its grown context, the reuse the tier preserves.
    sc = ServeConfig(page_size=16, max_pages=26, max_batch=4,
                     max_prefill_tokens=64, mode="forkkv",
                     max_pages_per_req=24,
                     host_tier_bytes=host_tier_bytes)
    eng = Engine(cfg, params, lora, sc)
    wf = WorkflowConfig(n_workflows=3, agents_per_workflow=2, rounds=2,
                        shared_context_len=256, instr_len=16,
                        tool_obs_len=24, max_new_tokens=4,
                        vocab=cfg.vocab_size, seed=0)
    rep = WorkflowDriver(eng, wf).run_react()
    assert eng.base_pool.free_pages + eng.base_pool.used_pages == 26
    return rep


def test_engine_tier_hits_beat_recompute_under_pressure(model):
    """Acceptance: with a device page budget too small for the ReAct
    working set, the tiered engine gets tier hits instead of recomputing —
    strictly fewer prefilled tokens than the same run with the tier off."""
    off = _react(model, host_tier_bytes=0)
    on = _react(model, host_tier_bytes=64 << 20)
    assert off["tier_hits"] == 0 and off["demoted_pages"] == 0
    assert off["evicted_pages"] > 0           # pressure really happened
    assert on["tier_hits"] > 0 and on["demoted_pages"] > 0
    assert on["preemptions"] > 0              # demote-under-pressure events
    assert on["tasks_done"] == off["tasks_done"] == 12
    # base evictions truncated the off-run's reuse (partial_base); the
    # tiered run promoted those pages back instead of recomputing them
    assert off["hit_kinds"].get("partial_base", 0) > 0
    assert on["prefilled_tokens"] < off["prefilled_tokens"]
    assert on["prefill_saved_frac"] > off["prefill_saved_frac"]
