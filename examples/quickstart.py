"""Quickstart: the ForkKV disaggregated KV cache in 60 lines.

Builds a tiny llama-family model with two LoRA agents, shows
  1. the disaggregated projection (bCache + rCache, deferred RoPE),
  2. that reconstruction is EXACT on a single trajectory,
  3. serving two agents over one shared context with a shared bCache.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.config import LoRAConfig, ModelConfig, ServeConfig
from repro.core.disagg import memory_ratio
from repro.models import transformer as tfm
from repro.serving.api import ForkServer, SamplingParams

cfg = ModelConfig(name="demo", family="dense", num_layers=2, d_model=128,
                  num_heads=8, num_kv_heads=4, d_ff=256, vocab_size=512,
                  dtype="float32", lora=LoRAConfig(rank=8), remat=False)
params = tfm.init_params(cfg, jax.random.PRNGKey(0))
lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=2)

# --- 1+2: disaggregated == unified on one trajectory ----------------------
tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 32), 0, 512)
ids = jnp.zeros((1,), jnp.int32)
unified = tfm.forward(params, tokens, cfg, lora=lora, adapter_ids=ids)
disagg = tfm.forward(params, tokens, cfg, lora=lora, adapter_ids=ids,
                     disagg=True)
print(f"max |unified - disagg| = {float(jnp.abs(unified - disagg).max()):.2e}"
      "  (exact: lossiness only appears when bCache is SHARED)")

# Eq. 3: memory ratio for N agents
for n in (4, 16, 64):
    print(f"N={n:3d} agents: disagg/unified memory = "
          f"{memory_ratio(n, cfg.lora.rank, cfg.kv_dim):.3f}")

# --- 3: serve two agents over one shared context --------------------------
sc = ServeConfig(page_size=16, max_pages=128, max_batch=4,
                 max_prefill_tokens=64, mode="forkkv", max_pages_per_req=8)
server = ForkServer(cfg, params, lora, sc)
shared = [int(t) for t in jax.random.randint(jax.random.PRNGKey(3), (48,),
                                             0, 512)]
# one session prefills + pins the shared context; each agent is a fork
with server.session(shared) as session:
    for agent in (0, 1):
        handle = session.fork(agent, [agent],
                              SamplingParams(max_new_tokens=8))
        print(f"agent {agent}: generated {handle.result().tokens}")

m = server.metrics()
print(f"fork kinds: {m['hit_kinds']}  (agent 1 inherited agent 0's bCache)")
print(f"bCache hit rate: {m['hit_rate']:.2f}, "
      f"peak base pages: {m['peak_base_pages']}, "
      f"peak residual pages: {m['peak_res_pages']}")
