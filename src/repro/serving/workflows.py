"""Agentic workflow generators + driver (paper §7.1 methodology).

ReAct: sequential pipeline — each agent's context = shared static prefix +
all previous agents' outputs + mock tool observations + its own instruction.
MapReduce: N agents fork the same shared context in parallel with distinct
instructions; a reduce agent consumes their concatenated outputs.

Tool calls are simulated exactly as in the paper: a constant latency and a
mock observation of random tokens (synthetic ids here — no tokenizer ships
offline).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import Engine, Request


@dataclasses.dataclass
class WorkflowConfig:
    n_workflows: int = 4
    agents_per_workflow: int = 4
    rounds: int = 1               # ReAct rounds: each agent revisits its
                                  # (grown) context every round — the
                                  # paper's sustained multi-turn load
    shared_context_len: int = 512     # paper: 32K-64K; scaled for CPU
    instr_len: int = 24               # paper Table 1: ~24 dynamic tokens
    tool_obs_len: int = 50            # paper: 100 mock tool tokens
    max_new_tokens: int = 16          # paper: 256; scaled for CPU
    tool_latency_s: float = 0.0       # simulated (recorded, not slept)
    vocab: int = 1024
    seed: int = 0


class WorkflowDriver:
    """Drives ReAct / MapReduce workflows through an Engine."""

    def __init__(self, engine: Engine, wf: WorkflowConfig):
        self.engine = engine
        self.wf = wf
        self.rng = np.random.default_rng(wf.seed)
        self._rid = 0
        # one shared static context per workflow "project"; workflows within
        # a run share it (the paper's massive static part)
        self.shared = list(self.rng.integers(
            0, wf.vocab, size=wf.shared_context_len).astype(int))
        self.tool_time = 0.0

    def _tokens(self, n: int) -> List[int]:
        return list(self.rng.integers(0, self.wf.vocab, size=n).astype(int))

    def _request(self, adapter_id: int, context: List[int]) -> Request:
        self._rid += 1
        return Request(rid=self._rid, adapter_id=adapter_id,
                       prompt=list(context),
                       max_new_tokens=self.wf.max_new_tokens)

    def _run_request(self, req: Request) -> List[int]:
        self.engine.submit(req)
        while req.state != "done":
            self.engine.step()
        return req.output[:-1]

    def _run_batch(self, reqs: List[Request]) -> List[List[int]]:
        for r in reqs:
            self.engine.submit(r)
        while any(r.state != "done" for r in reqs):
            self.engine.step()
        return [r.output[:-1] for r in reqs]

    # ------------------------------------------------------------- ReAct
    def run_react(self) -> Dict:
        """CONCURRENT sequential workflows (paper §7.1: N workflows run at
        once; within a workflow agents chain).  Agent i of workflow w uses
        adapter w*agents+i (completely non-overlapping adapters, Fig. 3).
        Concurrency is what creates the memory pressure + decode batching
        the paper measures."""
        wf = self.wf
        t0 = time.time()
        tasks = 0
        total_steps = wf.agents_per_workflow * wf.rounds
        state = [{"dynamic": [], "agent": 0, "req": None}
                 for _ in range(wf.n_workflows)]

        def unfinished():
            return any(s["agent"] < total_steps or
                       s["req"] is not None for s in state)

        while unfinished():
            for w, s in enumerate(state):
                if s["req"] is None and s["agent"] < total_steps:
                    # agents cycle across rounds: same adapter re-extends
                    # the same (grown) context -> residual-tree hits
                    adapter = w * wf.agents_per_workflow + \
                        (s["agent"] % wf.agents_per_workflow)
                    ctx = self.shared + s["dynamic"] + \
                        self._tokens(wf.instr_len)
                    s["req"] = self._request(adapter, ctx)
                    self.engine.submit(s["req"])
            self.engine.step()
            for s in state:
                r = s["req"]
                if r is not None and r.state == "done":
                    out = r.output[:-1]
                    s["dynamic"] = s["dynamic"] + out + \
                        self._tokens(wf.tool_obs_len)
                    s["agent"] += 1
                    s["req"] = None
                    self.tool_time += wf.tool_latency_s
                    tasks += 1
        wall = time.time() - t0
        return self._report("react", tasks, wall)

    # --------------------------------------------------------- MapReduce
    def run_mapreduce(self) -> Dict:
        """Parallel map agents fork the shared context simultaneously."""
        wf = self.wf
        t0 = time.time()
        tasks = 0
        for w in range(wf.n_workflows):
            reqs = []
            for a in range(wf.agents_per_workflow):
                adapter = w * wf.agents_per_workflow + a
                ctx = self.shared + self._tokens(wf.instr_len)
                reqs.append(self._request(adapter, ctx))
            outs = self._run_batch(reqs)
            tasks += len(reqs)
            # reduce step: one agent over concatenated outputs
            reduce_ctx = self.shared + [t for o in outs for t in o] + \
                self._tokens(wf.instr_len)
            self._run_request(self._request(
                wf.n_workflows * wf.agents_per_workflow + w, reduce_ctx))
            tasks += 1
        wall = time.time() - t0
        return self._report("mapreduce", tasks, wall)

    def _report(self, kind: str, tasks: int, wall: float) -> Dict:
        m = self.engine.metrics()
        m.update(workflow=kind, tasks=tasks, wall_s=wall,
                 tool_latency_s=self.tool_time,
                 throughput_tasks_per_s=tasks / max(wall, 1e-9))
        return m
