"""Pure-jnp oracle for ResidualAttention (paper §5.3, Algorithm 1).

Computes attention over a *disaggregated* KV cache:

    K = K_base + RoPE(K_res @ B_k)
    V = V_base + V_res @ B_v
    O = softmax(Q K^T / sqrt(d)) V

The kernel implements this with on-chip reconstruction and a dual
accumulator; the oracle materializes everything, which is exactly the
"naive HBM reconstruction" the paper argues against — perfect as a
correctness reference.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import attention as attn_lib
from repro.core import rope as rope_lib


def reconstruct(k_base, v_base, k_res, v_res, b_k, b_v, sin, cos):
    """Materialize full K, V from disaggregated parts.

    k_base/v_base: (B, Sk, Hkv, D); k_res/v_res: (B, Sk, R)
    b_k/b_v: (B, R, Hkv*D) per-request adapter up-projections
    sin/cos: (B, Sk, D//2)
    """
    bsz, sk, hkv, d = k_base.shape
    k_lora = jnp.einsum("bsr,brn->bsn", k_res.astype(jnp.float32),
                        b_k.astype(jnp.float32)).reshape(bsz, sk, hkv, d)
    k_lora = rope_lib.apply_rope(k_lora, sin, cos)
    v_lora = jnp.einsum("bsr,brn->bsn", v_res.astype(jnp.float32),
                        b_v.astype(jnp.float32)).reshape(bsz, sk, hkv, d)
    k = k_base.astype(jnp.float32) + k_lora
    v = v_base.astype(jnp.float32) + v_lora
    return k.astype(k_base.dtype), v.astype(v_base.dtype)


def _gather_paged_kv(q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v,
                     bt_b, bt_r, *, rope_theta: float, use_rope: bool,
                     kb_scale=None, vb_scale=None):
    """Gather block-table pages into contiguous (B, Sk, ...) views and, for
    the disaggregated layout, reconstruct full K/V.  Shared by the paged
    decode and prefill oracles.  ``kb_scale``/``vb_scale`` ((P, page,
    Hkv) f32, or None) mark the base pools as int8: pages are dequantized
    right after the gather, BEFORE reconstruction, mirroring the kernels'
    in-VMEM dequant (DESIGN.md §18)."""
    bsz, d = q.shape[0], q.shape[-1]
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    sk = bt_b.shape[1] * page
    kb = kb_pool[bt_b].reshape(bsz, sk, hkv, d)
    vb = vb_pool[bt_b].reshape(bsz, sk, hkv, d)
    if kb_scale is not None:
        ks = kb_scale[bt_b].reshape(bsz, sk, hkv)[..., None]
        vs = vb_scale[bt_b].reshape(bsz, sk, hkv)[..., None]
        kb = (kb.astype(jnp.float32) * ks).astype(q.dtype)
        vb = (vb.astype(jnp.float32) * vs).astype(q.dtype)
    if kr_pool is None:
        return kb, vb
    kr = kr_pool[bt_r].reshape(bsz, sk, -1)
    vr = vr_pool[bt_r].reshape(bsz, sk, -1)
    kpos = jnp.broadcast_to(jnp.arange(sk), (bsz, sk))
    if use_rope:
        sin, cos = rope_lib.rope_sincos(kpos, d, rope_theta)
    else:
        sin = jnp.zeros(kpos.shape + (d // 2,), jnp.float32)
        cos = jnp.ones(kpos.shape + (d // 2,), jnp.float32)
    return reconstruct(kb, vb, kr, vr, b_k, b_v,
                       sin.astype(q.dtype), cos.astype(q.dtype))


def _masked_softmax_attention(q, k, v, mask, scale):
    """Numerically-stable masked attention.  q: (B, Sq, Hq, D);
    k/v: (B, Sk, Hkv, D); mask: broadcastable to (B, Hq, Sq, Sk)."""
    s = attn_lib._gqa_scores(q, k) * scale
    s = jnp.where(mask, s, attn_lib.NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
    return attn_lib._gqa_out(p, v).astype(q.dtype)


def paged_residual_attention_ref(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                 b_k, b_v, bt_b, bt_r, kv_len, *,
                                 scale: Optional[float] = None,
                                 window: int = 0,
                                 rope_theta: float = 10_000.0,
                                 use_rope: bool = True,
                                 kb_scale=None,
                                 vb_scale=None) -> jnp.ndarray:
    """XLA mirror of the paged decode kernels: gather the block-table pages
    into contiguous views, then run the dense oracle.  Same interface as
    :func:`repro.kernels.paged_residual_attention.
    paged_residual_attention_decode` (pass ``kr_pool=None`` for the
    base-only variant), so the ``ops`` dispatcher can swap backends.

    The gather touches only ``bt_b.shape[1]`` pages per request — the
    serving executor crops/buckets block tables to the live page count, so
    even this fallback's HBM traffic scales with actual ``kv_len`` rather
    than the engine-wide ``smax`` (DESIGN.md §12).

    q: (B, Hq, D); kb/vb: (P, page, Hkv, D); kr/vr: (Pr, page, R) or None;
    b_k/b_v: (B, R, Hkv*D) or None; bt_b/bt_r: (B, W); kv_len: (B,) —
    the query row sits at position ``kv_len - 1``; ``window > 0`` keeps
    only the trailing ``window`` positions (SWA).  Returns (B, Hq, D).
    """
    bsz, hq, d = q.shape
    sk = bt_b.shape[1] * kb_pool.shape[1]
    if scale is None:
        scale = d ** -0.5
    k, v = _gather_paged_kv(q, kb_pool, vb_pool, kr_pool, vr_pool, b_k,
                            b_v, bt_b, bt_r, rope_theta=rope_theta,
                            use_rope=use_rope, kb_scale=kb_scale,
                            vb_scale=vb_scale)
    kp = jnp.arange(sk)[None, None, None, :]
    # the query sits at kv_len - 1, so the causal bound and the validity
    # bound coincide: one mask term covers both
    kvl = kv_len[:, None, None, None]
    mask = kp < kvl
    if window > 0:
        mask = mask & (kp > kvl - 1 - window)
    return _masked_softmax_attention(q[:, None], k, v, mask, scale)[:, 0]


def paged_residual_attention_prefill_ref(q, kb_pool, vb_pool, kr_pool,
                                         vr_pool, b_k, b_v, bt_b, bt_r,
                                         start, kv_len, *,
                                         scale: Optional[float] = None,
                                         window: int = 0,
                                         rope_theta: float = 10_000.0,
                                         use_rope: bool = True,
                                         kb_scale=None, vb_scale=None
                                         ) -> jnp.ndarray:
    """XLA mirror of the paged chunked-prefill kernels (DESIGN.md §13):
    gather block-table pages into contiguous views, reconstruct (disagg)
    and attend with the causal-within-chunk + window + validity mask.

    q: (B, chunk, Hq, D); start: (B,) absolute position of each chunk's
    first query row; kv_len: (B,) valid tokens incl. the chunk's writes.
    Pass ``kr_pool=None`` for the base-only variant.
    Returns (B, chunk, Hq, D).
    """
    bsz, sq, hq, d = q.shape
    sk = bt_b.shape[1] * kb_pool.shape[1]
    if scale is None:
        scale = d ** -0.5
    k, v = _gather_paged_kv(q, kb_pool, vb_pool, kr_pool, vr_pool, b_k,
                            b_v, bt_b, bt_r, rope_theta=rope_theta,
                            use_rope=use_rope, kb_scale=kb_scale,
                            vb_scale=vb_scale)
    qpos = start[:, None] + jnp.arange(sq)[None]          # (B, Sq)
    qp = qpos[:, None, :, None]
    kp = jnp.arange(sk)[None, None, None, :]
    mask = (kp <= qp) & (kp < kv_len[:, None, None, None])
    if window > 0:
        mask = mask & (kp > qp - window)
    return _masked_softmax_attention(q, k, v, mask, scale)


def paged_residual_attention_mixed_ref(q, kb_pool, vb_pool, kr_pool,
                                       vr_pool, b_k, b_v, bt_b, bt_r,
                                       start, q_len, kv_len, *,
                                       scale: Optional[float] = None,
                                       window: int = 0,
                                       rope_theta: float = 10_000.0,
                                       use_rope: bool = True,
                                       kb_scale=None, vb_scale=None
                                       ) -> jnp.ndarray:
    """XLA mirror of the unified mixed prefill/decode kernels
    (DESIGN.md §14): the prefill oracle generalized with a per-row
    ``q_len`` — rows past it are masked out AND explicitly zeroed in the
    output, matching the Pallas kernels' deterministic zero padding (a
    fully-masked softmax row would otherwise average V instead of
    vanishing).

    q: (B, chunk, Hq, D); start/q_len/kv_len: (B,) with
    ``kv_len = start + q_len``.  Pass ``kr_pool=None`` for the base-only
    variant.  Returns (B, chunk, Hq, D).
    """
    bsz, sq, hq, d = q.shape
    sk = bt_b.shape[1] * kb_pool.shape[1]
    if scale is None:
        scale = d ** -0.5
    k, v = _gather_paged_kv(q, kb_pool, vb_pool, kr_pool, vr_pool, b_k,
                            b_v, bt_b, bt_r, rope_theta=rope_theta,
                            use_rope=use_rope, kb_scale=kb_scale,
                            vb_scale=vb_scale)
    rowidx = jnp.arange(sq)[None]                       # (1, Sq)
    rowvalid = rowidx < q_len[:, None]                  # (B, Sq)
    qpos = start[:, None] + rowidx
    qp = qpos[:, None, :, None]
    kp = jnp.arange(sk)[None, None, None, :]
    mask = (kp <= qp) & (kp < kv_len[:, None, None, None]) & \
        rowvalid[:, None, :, None]
    if window > 0:
        mask = mask & (kp > qp - window)
    out = _masked_softmax_attention(q, k, v, mask, scale)
    return jnp.where(rowvalid[:, :, None, None], out,
                     jnp.zeros_like(out))


def residual_attention_ref(q, k_base, v_base, k_res, v_res, b_k, b_v,
                           sin, cos, *, qpos: jnp.ndarray,
                           kv_len: Optional[jnp.ndarray] = None,
                           window: int = 0, causal: bool = True,
                           scale: Optional[float] = None) -> jnp.ndarray:
    """Reference residual attention.

    q: (B, Sq, Hq, D) — RoPE already applied (queries are computed fresh).
    qpos: (B, Sq) absolute positions of the query rows.
    kv_len: (B,) valid cache lengths (<= Sk).
    Returns (B, Sq, Hq, D).
    """
    k, v = reconstruct(k_base, v_base, k_res, v_res, b_k, b_v, sin, cos)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    s = attn_lib._gqa_scores(q, k) * scale          # (B, Hq, Sq, Sk)
    kpos = jnp.arange(k.shape[1])[None, None, None, :]
    qp = qpos[:, None, :, None]
    mask = jnp.ones(s.shape, dtype=bool)
    if causal:
        mask &= kpos <= qp
    if window > 0:
        mask &= kpos > qp - window
    if kv_len is not None:
        mask &= kpos < kv_len[:, None, None, None]
    s = jnp.where(mask, s, attn_lib.NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return attn_lib._gqa_out(p, v).astype(q.dtype)
