"""Roofline-term derivation from compiled dry-run artifacts.

  compute    = HLO_FLOPs   / (chips * PEAK_FLOPS_BF16)
  memory     = HLO_bytes   / (chips * HBM_BW)
  collective = coll_bytes  / (chips * ICI_BW)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
reported there, so we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.core.config import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# matches e.g. "bf16[128,4096]{1,0}" (layout suffix optional)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from (S)HLO text."""
    out = {k: 0 for k in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find("= ")
        if eq < 0:
            continue
        rhs = s[eq + 2:]
        kind = None
        for op in _COLL_OPS:
            # op name appears as "<shape> <op>(" or "<op>-start("
            if f" {op}(" in rhs or f" {op}-start(" in rhs:
                kind = op
                break
        if kind is None:
            continue
        # operand list between the first '(' and matching ')'
        lp = rhs.find("(")
        rp = rhs.rfind(")")
        operands = rhs[lp + 1:rp]
        nbytes = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(operands))
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLL_OPS)
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int) -> Dict[str, float]:
    """All inputs are PER-PARTITION quantities: XLA's cost_analysis() on an
    SPMD-partitioned module reports the per-device module, and the parsed
    HLO shapes are per-device shards.  Per-chip terms therefore divide by
    one chip's peak; global = per-chip x chips when balanced (equivalent to
    the global/(chips*peak) formulation)."""
    compute = flops / PEAK_FLOPS_BF16
    memory = bytes_accessed / HBM_BW
    collective = coll_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


def hlo_cost_analysis(compiled) -> Dict:
    """``compiled.cost_analysis()`` normalized across jax versions: some
    return one dict, others a one-element list of dicts.  Shape-only
    normalization: an empty/None result becomes ``{}`` (as the seed's
    ``or {}`` did) while exceptions propagate to the caller."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def analyze_compiled(lowered, compiled, chips: int,
                     model_flops: Optional[float] = None) -> Dict:
    cost = hlo_cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    terms = roofline_terms(flops, nbytes, coll["total"], chips)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem[k] = int(getattr(ma, k, 0))
    except Exception:
        pass
    result = {
        "flops": flops,
        "bytes_accessed": nbytes,
        "collectives": coll,
        "terms": terms,
        "memory": mem,
    }
    if model_flops:
        result["model_flops"] = model_flops
        hlo_global = flops * chips
        result["useful_fraction"] = model_flops / hlo_global \
            if hlo_global else 0.0
    return result
