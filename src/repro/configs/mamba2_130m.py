"""mamba2-130m [ssm]: SSD (state-space duality), attention-free.
ForkKV N/A for this family (DESIGN.md §5). [arXiv:2405.21060]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_conv=4, ssm_heads=24, ssm_expand=2,
    lora=LoRAConfig(rank=16), scan_layers=True,
    citation="arXiv:2405.21060")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-tiny", num_layers=2, d_model=128,
        vocab_size=512, ssm_state=16, ssm_heads=4, dtype="float32",
        remat=False)
