"""Tiered KV offload benchmark (DESIGN.md §10).

ReAct under device-memory pressure — the device page budget barely covers
one request's footprint, so the seed engine's destroy-on-evict forces
re-prefills.  Rows compare the tier disabled / enabled on the identical
workload: ``prefilled_tokens`` drops and ``tier_hits`` appear when demoted
pages are promoted instead of recomputed.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, run_workflow

# device budget of 26 pages vs a working set of ~6 live agent contexts;
# rounds=2 lets each adapter re-fork its grown context (the reuse the
# host tier preserves across evictions).
_PRESSURE = dict(n_workflows=3, agents=2, rounds=2, context=256,
                 max_new=4, max_pages=26, max_pages_per_req=24,
                 max_batch=4, instr_len=16, tool_obs_len=24)


def main() -> None:
    for label, host_bytes in (("off", 0), ("on", 64 << 20)):
        t0 = time.time()
        m = run_workflow("forkkv", "react", host_tier_bytes=host_bytes,
                         **_PRESSURE)
        wall_us = (time.time() - t0) * 1e6
        emit(f"tiering.react.tier_{label}.prefilled_tokens", wall_us,
             f"{m['prefilled_tokens']}")
        emit(f"tiering.react.tier_{label}.prefill_saved_frac", wall_us,
             f"{m['prefill_saved_frac']:.4f}")
        emit(f"tiering.react.tier_{label}.tier_hits", 0,
             f"{m['tier_hits']}")
        emit(f"tiering.react.tier_{label}.demoted_pages", 0,
             f"{m['demoted_pages']}")
        emit(f"tiering.react.tier_{label}.evicted_pages", 0,
             f"{m['evicted_pages']}")
        emit(f"tiering.react.tier_{label}.promoted_bytes", 0,
             f"{m['promoted_bytes']}")
        emit(f"tiering.react.tier_{label}.preemptions", 0,
             f"{m['preemptions']}")


if __name__ == "__main__":
    main()
