"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The audio frontend (mel-spectrogram + conv feature extractor) is a STUB per
the assignment: ``input_specs()`` provides precomputed frame embeddings
(B, encoder_seq, d).  We implement the transformer backbone: bidirectional
encoder, causal decoder with self- + cross-attention, learned positions.

ForkKV applies to the decoder *self*-attention (LoRA'd K/V projections).
Cross-attention K/V derive from the encoder output — shared per audio clip
and adapter-independent when cross-attn carries no adapter, a natural,
lossless bCache (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import attention as attn_lib
from repro.core.config import ModelConfig
from repro.models import base
from repro.models import transformer as tfm

Params = Dict[str, Any]


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = cfg.activation_dtype
    d = cfg.d_model
    Le, Ld = cfg.num_encoder_layers, cfg.num_layers
    ks = base.split_keys(key, 24)

    def attn_block(k, L):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {"wq": base.dense_init(k1, (L, d, cfg.q_dim), dt),
                "wk": base.dense_init(k2, (L, d, cfg.kv_dim), dt),
                "wv": base.dense_init(k3, (L, d, cfg.kv_dim), dt),
                "wo": base.dense_init(k4, (L, cfg.q_dim, d), dt)}

    def mlp_block(k, L):
        k1, k2 = jax.random.split(k)
        return {"w_up": base.dense_init(k1, (L, d, cfg.d_ff), dt),
                "w_down": base.dense_init(k2, (L, cfg.d_ff, d), dt)}

    enc = {"ln1": jnp.zeros((Le, d), dt), "ln2": jnp.zeros((Le, d), dt)}
    enc.update(attn_block(ks[0], Le))
    enc.update(mlp_block(ks[1], Le))
    dec = {"ln1": jnp.zeros((Ld, d), dt), "ln2": jnp.zeros((Ld, d), dt),
           "ln3": jnp.zeros((Ld, d), dt)}
    dec.update(attn_block(ks[2], Ld))
    dec.update({"x_" + k: v for k, v in attn_block(ks[3], Ld).items()})
    dec.update(mlp_block(ks[4], Ld))
    return {
        "enc_pos": base.dense_init(ks[5], (cfg.encoder_seq, d), dt),
        # decoder positions are SINUSOIDAL (computed on the fly): the real
        # whisper decoder's learned table caps at 448 tokens, far below the
        # assigned 32k/500k decode shapes -- adaptation noted in DESIGN.md §8
        "embed": base.dense_init(ks[7], (cfg.vocab_size, d), dt),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": jnp.zeros((d,), dt),
        "final_norm": jnp.zeros((d,), dt),
    }


def logical_axes(cfg: ModelConfig) -> Params:
    def attn(prefix=""):
        return {prefix + "wq": ("layers", "embed", "q_out"),
                prefix + "wk": ("layers", "embed", "kv_out"),
                prefix + "wv": ("layers", "embed", "kv_out"),
                prefix + "wo": ("layers", "q_out", "embed")}

    mlp = {"w_up": ("layers", "embed", "ff"), "w_down": ("layers", "ff", "embed")}
    enc = {"ln1": ("layers", "embed"), "ln2": ("layers", "embed")}
    enc.update(attn())
    enc.update(mlp)
    dec = {"ln1": ("layers", "embed"), "ln2": ("layers", "embed"),
           "ln3": ("layers", "embed")}
    dec.update(attn())
    dec.update(attn("x_"))
    dec.update(mlp)
    return {"enc_pos": (None, "embed"),
            "embed": ("vocab", "embed"), "enc_layers": enc,
            "dec_layers": dec, "enc_norm": ("embed",),
            "final_norm": ("embed",)}


def _sinusoid(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Standard sinusoidal position embedding; positions: (...,) -> (..., d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) *
                    (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, frame_embeds, cfg: ModelConfig) -> jnp.ndarray:
    """Bidirectional encoder over stubbed frame embeddings (B, Se, d)."""
    x = frame_embeds + params["enc_pos"][None, :frame_embeds.shape[1]]
    hd = cfg.resolved_head_dim

    def body(carry, p_l):
        xc = carry
        h = base.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        q = (h @ p_l["wq"]).reshape(h.shape[:2] + (cfg.num_heads, hd))
        k = (h @ p_l["wk"]).reshape(h.shape[:2] + (cfg.num_kv_heads, hd))
        v = (h @ p_l["wv"]).reshape(h.shape[:2] + (cfg.num_kv_heads, hd))
        a = attn_lib.mha(q, k, v, causal=False)
        xc = xc + a.reshape(h.shape[:2] + (-1,)) @ p_l["wo"]
        h = base.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        xc = xc + jax.nn.gelu(h @ p_l["w_up"]) @ p_l["w_down"]
        return xc, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return base.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(p_l, x, cfg, *, positions, mode, cache_l, kv_len, lora_l,
               adapter_ids, disagg):
    """Decoder layer: causal self-attn (cached, ForkKV-capable) + cross-attn."""
    hd = cfg.resolved_head_dim
    h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
    self_cache = None
    if cache_l is not None:
        self_cache = {k: v for k, v in cache_l.items()
                      if k in ("k", "v", "k_res", "v_res")}
    attn_out, new_self = tfm.attention(
        p_l, h, cfg, positions=positions, mode=mode, cache=self_cache,
        kv_len=kv_len, lora=lora_l, adapter_ids=adapter_ids, disagg=disagg)
    x = x + attn_out.reshape(x.shape[0], x.shape[1], -1) @ p_l["wo"]

    # cross attention against cached encoder K/V
    h = base.rms_norm(x, p_l["ln3"], cfg.norm_eps)
    q = (h @ p_l["x_wq"]).reshape(h.shape[:2] + (cfg.num_heads, hd))
    xk, xv = cache_l["xk"], cache_l["xv"]
    a = attn_lib.mha(q, xk, xv, causal=False)
    x = x + a.reshape(h.shape[:2] + (-1,)) @ p_l["x_wo"]

    h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
    x = x + jax.nn.gelu(h @ p_l["w_up"]) @ p_l["w_down"]
    new_cache = None
    if cache_l is not None:
        new_cache = dict(new_self)
        new_cache["xk"], new_cache["xv"] = xk, xv
    return x, new_cache


def _apply_decoder(params, x, cfg, *, positions, mode, cache, kv_len, lora,
                   adapter_ids, disagg):
    def body(carry, xs):
        p_l, c_l = xs
        out, nc = _dec_layer(p_l, carry, cfg, positions=positions, mode=mode,
                             cache_l=c_l, kv_len=kv_len, lora_l=None,
                             adapter_ids=adapter_ids, disagg=disagg)
        return out, nc

    # lora handled inside xs when provided
    if lora is not None:
        def body(carry, xs):     # noqa: F811
            p_l, c_l, l_l = xs
            out, nc = _dec_layer(p_l, carry, cfg, positions=positions,
                                 mode=mode, cache_l=c_l, kv_len=kv_len,
                                 lora_l=l_l, adapter_ids=adapter_ids,
                                 disagg=disagg)
            return out, nc
        xs = (params["dec_layers"], cache, lora)
    else:
        xs = (params["dec_layers"], cache)
    fn = jax.checkpoint(body) if (cfg.remat and mode == "full") else body
    x, new_cache = jax.lax.scan(fn, x, xs)
    return x, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               disagg: bool = False, dtype=None) -> Params:
    dt = dtype or cfg.activation_dtype
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    cache = {
        "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), dt),
        "xk": jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_kv_heads, hd), dt),
        "xv": jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_kv_heads, hd), dt),
    }
    if disagg:
        cache["k_res"] = jnp.zeros((L, batch, max_len, cfg.lora.rank), dt)
        cache["v_res"] = jnp.zeros((L, batch, max_len, cfg.lora.rank), dt)
    return cache


def cache_logical_axes(cfg: ModelConfig, disagg: bool = False) -> Params:
    axes = {"k": ("layers", "batch", None, "kv_heads", "kv_head_dim"),
            "v": ("layers", "batch", None, "kv_heads", "kv_head_dim"),
            "xk": ("layers", "batch", None, "kv_heads", "kv_head_dim"),
            "xv": ("layers", "batch", None, "kv_heads", "kv_head_dim")}
    if disagg:
        axes["k_res"] = ("layers", "batch", None, "rank")
        axes["v_res"] = ("layers", "batch", None, "rank")
    return axes


def fill_cross_cache(params, enc_out, cache, cfg: ModelConfig) -> Params:
    """Project encoder output into per-layer cross K/V (once per request)."""
    hd = cfg.resolved_head_dim

    def proj(p_l):
        k = (enc_out @ p_l["x_wk"]).reshape(
            enc_out.shape[:2] + (cfg.num_kv_heads, hd))
        v = (enc_out @ p_l["x_wv"]).reshape(
            enc_out.shape[:2] + (cfg.num_kv_heads, hd))
        return k, v

    ks, vs = jax.lax.map(proj, params["dec_layers"])
    cache = dict(cache)
    cache["xk"], cache["xv"] = ks.astype(cache["xk"].dtype), \
        vs.astype(cache["xv"].dtype)
    return cache


def forward(params, tokens, cfg: ModelConfig, *, extra_embeds=None,
            lora=None, adapter_ids=None, disagg=False) -> jnp.ndarray:
    """Teacher-forced full pass.  extra_embeds = encoder frame embeddings."""
    assert extra_embeds is not None, "whisper needs frame embeddings"
    enc_out = encode(params, extra_embeds, cfg)
    bsz, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (bsz, s))
    x = params["embed"][tokens] + \
        _sinusoid(positions, cfg.d_model).astype(params["embed"].dtype)
    # full mode still needs cross K/V: build a lightweight cache dict
    cache = init_cache(cfg, bsz, 1, disagg=False, dtype=x.dtype)
    cache = fill_cross_cache(params, enc_out, cache, cfg)
    # run decoder in "full" mode with cross cache only
    hd = cfg.resolved_head_dim

    def body(carry, xs):
        p_l, xk, xv = xs
        xc = carry
        h = base.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        q = (h @ p_l["wq"]).reshape(h.shape[:2] + (cfg.num_heads, hd))
        k = (h @ p_l["wk"]).reshape(h.shape[:2] + (cfg.num_kv_heads, hd))
        v = (h @ p_l["wv"]).reshape(h.shape[:2] + (cfg.num_kv_heads, hd))
        a = attn_lib.mha(q, k, v, causal=True)
        xc = xc + a.reshape(h.shape[:2] + (-1,)) @ p_l["wo"]
        h = base.rms_norm(xc, p_l["ln3"], cfg.norm_eps)
        q = (h @ p_l["x_wq"]).reshape(h.shape[:2] + (cfg.num_heads, hd))
        a = attn_lib.mha(q, xk, xv, causal=False)
        xc = xc + a.reshape(h.shape[:2] + (-1,)) @ p_l["x_wo"]
        h = base.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        xc = xc + jax.nn.gelu(h @ p_l["w_up"]) @ p_l["w_down"]
        return xc, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, (params["dec_layers"], cache["xk"],
                                cache["xv"]))
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T                     # tied unembedding


def prefill(params, tokens, cache, cfg: ModelConfig, *, start: int = 0,
            extra_embeds=None, lora=None, adapter_ids=None, disagg=False):
    if extra_embeds is not None:                     # first chunk: run encoder
        enc_out = encode(params, extra_embeds, cfg)
        cache = fill_cross_cache(params, enc_out, cache, cfg)
    bsz, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(start, start + s), (bsz, s))
    x = params["embed"][tokens] + \
        _sinusoid(positions, cfg.d_model).astype(params["embed"].dtype)
    x, cache = _apply_decoder(params, x, cfg, positions=positions,
                              mode="prefill", cache=cache, kv_len=None,
                              lora=lora, adapter_ids=adapter_ids,
                              disagg=disagg)
    x = base.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T, cache


def decode_step(params, tokens, cache, kv_len, cfg: ModelConfig, *,
                lora=None, adapter_ids=None, disagg=False):
    pos_emb = _sinusoid(kv_len, cfg.d_model).astype(params["embed"].dtype)
    x = (params["embed"][tokens] + pos_emb)[:, None]
    x, cache = _apply_decoder(params, x, cfg, positions=kv_len,
                              mode="decode", cache=cache, kv_len=kv_len,
                              lora=lora, adapter_ids=adapter_ids,
                              disagg=disagg)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["embed"].T)[:, 0], cache
