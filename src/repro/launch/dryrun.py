import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (device count locks on
# first backend init) — this module is the only place that forces 512
# placeholder devices; tests and benchmarks see the real single CPU device.

# Multi-pod dry-run driver.
#
# For every (architecture x input shape x mesh) combination this lowers and
# compiles the corresponding step (train / prefill / serve) against the
# production mesh — 16x16 single-pod and 2x16x16 multi-pod — using
# ShapeDtypeStruct inputs (no allocation), then records
# ``memory_analysis()`` / ``cost_analysis()`` and the roofline terms.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
#       --mesh single --out experiments/dryrun_single.json
# (no `from __future__` here: the XLA_FLAGS lines must be the first
#  statements in the file, which rules out __future__ imports)

import argparse
import json
import time
import traceback

import jax

from repro import configs as cfg_lib
from repro.core.config import INPUT_SHAPES, shape_by_name
from repro.launch import roofline as rf
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens/step."""
    n = cfg.active_params
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/request


def run_pair(arch: str, shape_name: str, mesh, chips: int,
             verbose: bool = True, strategy: str = "baseline") -> dict:
    cfg = cfg_lib.get_config(arch)
    shape = shape_by_name(shape_name)
    rec = {"arch": arch, "shape": shape_name, "chips": chips,
           "mode": shape.mode}
    if not cfg_lib.shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch; long_500k requires " \
                        "sub-quadratic attention (DESIGN.md §5)"
        return rec
    if strategy == "optimized" and shape.mode == "decode" and \
            cfg.family in ("dense", "moe", "vlm"):
        # beyond-paper: int8 bCache halves the decode memory term
        # (accuracy validated in tests/test_models.py::test_int8_kv_cache)
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_quant="int8")
    t0 = time.time()
    try:
        built = steps_lib.build_step(cfg, mesh, shape, strategy=strategy)
        with mesh:
            lowered = built.step_fn.lower(*built.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        analysis = rf.analyze_compiled(lowered, compiled, chips,
                                       model_flops_for(cfg, shape))
        # analytic model: primary roofline source (HLO cost_analysis counts
        # scan bodies once — see launch/analytic.py docstring)
        from repro.launch import analytic as ana_lib
        ana = ana_lib.analytic_costs(cfg, shape, mesh, strategy=strategy)
        ana_terms = rf.roofline_terms(ana["flops_dev"], ana["bytes_dev"],
                                      ana["coll_bytes_dev"], chips)
        mf = model_flops_for(cfg, shape)
        ana["useful_fraction"] = mf / ana["flops_global"] \
            if ana["flops_global"] else 0.0
        analysis["analytic"] = {**ana, "terms": ana_terms}
        mem = analysis.get("memory", {})
        if verbose:
            print(f"  memory_analysis: {mem}")
            print(f"  cost_analysis: flops={analysis['flops']:.3e} "
                  f"bytes={analysis['bytes_accessed']:.3e} "
                  f"coll={analysis['collectives']['total']:.3e}")
        rec.update(status="ok", description=built.description,
                   lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                   **analysis)
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="")
    ap.add_argument("--strategy", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    archs = list(cfg_lib.ARCH_IDS) if args.arch == "all" else \
        args.arch.split(",")
    shapes = [s.name for s in INPUT_SHAPES] if args.shape == "all" else \
        args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        chips = mesh.devices.size
        for arch in archs:
            for shape in shapes:
                tag = f"[{'multi' if multi else 'single'}-pod] {arch} × {shape}"
                print(f"== {tag}", flush=True)
                rec = run_pair(arch, shape, mesh, chips,
                               strategy=args.strategy)
                rec["mesh"] = "multi" if multi else "single"
                rec["strategy"] = args.strategy
                if rec["status"] == "ok":
                    t = rec["analytic"]["terms"]
                    print(f"  OK lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"analytic: dominant={t['dominant']} "
                          f"compute={t['compute_s']:.2e}s "
                          f"memory={t['memory_s']:.2e}s "
                          f"collective={t['collective_s']:.2e}s", flush=True)
                elif rec["status"] == "skipped":
                    print(f"  SKIP: {rec['reason']}", flush=True)
                else:
                    print(f"  FAIL: {rec['error']}", flush=True)
                results.append(rec)

    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n== done: {ok} ok, {sk} skipped, {err} failed")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        slim = [{k: v for k, v in r.items() if k != "traceback"}
                for r in results]
        with open(args.out, "w") as f:
            json.dump(slim, f, indent=1, default=str)
        print(f"wrote {args.out}")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
