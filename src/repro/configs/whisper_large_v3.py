"""whisper-large-v3 [audio]: enc-dec transformer backbone; conv/mel frontend
stubbed to frame embeddings. MHA (kv=20 == heads). [arXiv:2212.04356]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
    use_rope=False, is_encoder_decoder=True, num_encoder_layers=32,
    encoder_seq=1500, frontend="audio_stub", mlp_activation="gelu",
    tie_embeddings=True, lora=LoRAConfig(rank=16), scan_layers=True,
    citation="arXiv:2212.04356")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, num_encoder_layers=2,
        encoder_seq=24, dtype="float32", remat=False)
