"""Configuration system for the ForkKV framework.

Every architecture in the zoo (dense / moe / ssm / hybrid / vlm / audio) is
described by a single :class:`ModelConfig`.  Input shapes are described by
:class:`ShapeConfig` and the production meshes by :class:`MeshConfig`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

Dtype = jnp.dtype


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    """LoRA adapter configuration (paper §2.2)."""

    rank: int = 16
    alpha: float = 32.0
    # Which projections carry adapters.  ForkKV disaggregates the KV cache,
    # so k/v adapters are the interesting ones; q is applied on the fly.
    targets: Tuple[str, ...] = ("q", "k", "v")

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering all six assigned families."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attn-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention flavour -------------------------------------------------
    sliding_window: int = 0          # >0 -> sliding-window attention (SWA)
    rope_theta: float = 10_000.0
    use_rope: bool = True            # whisper uses learned abs. positions
    max_position: int = 1_048_576
    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                # per-expert hidden (0 -> d_ff)
    moe_interleave: int = 1          # every Nth layer is MoE (llama4: 2)
    moe_shared_expert: bool = False  # always-on shared expert (llama4)
    moe_capacity_factor: float = 1.25
    # --- SSM (mamba2 SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_heads: int = 0               # mamba2 value heads
    ssm_expand: int = 2
    # --- hybrid (griffin / recurrentgemma) ----------------------------------
    # block pattern, e.g. ("rglru", "rglru", "local") repeated.
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0               # RG-LRU recurrent width (0 -> d_model)
    local_window: int = 0            # local attention window for hybrid
    # --- enc-dec (whisper) ---------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30s audio -> 1500 frames
    # --- modality frontend stub ----------------------------------------------
    frontend: str = "none"           # none | vision_stub | audio_stub
    num_patches: int = 0             # vlm: patch embeddings per image
    # --- misc ----------------------------------------------------------------
    mlp_activation: str = "silu"     # silu (swiglu) | gelu (plain 2-matmul)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    lora: LoRAConfig = dataclasses.field(default_factory=LoRAConfig)
    # KV-cache quantization (beyond-paper, §Perf): "none" | "int8".
    # int8 halves bCache bytes (the decode roofline's dominant term);
    # rCache stays in model dtype (it is rank-r, ~1.5% of the cache).
    kv_quant: str = "none"
    # scan configuration for deep stacks: layers are scanned in
    # (outer, inner) groups with remat on the inner scan.
    scan_layers: bool = True
    scan_groups: int = 0             # 0 -> single-level scan
    optimizer: str = "adamw"         # adamw | adafactor
    remat: bool = True
    citation: str = ""

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def activation_dtype(self) -> Dtype:
        return jnp.dtype(self.dtype)

    @property
    def num_params(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, L = self.d_model, self.num_layers
        attn = d * (self.q_dim + 2 * self.kv_dim + self.q_dim)
        if self.family == "ssm":
            inner = self.ssm_expand * d
            per_layer = d * (2 * inner + inner) + inner * self.ssm_state * 2
            mlp = 0
            attn = 0
            per_layer += mlp
            body = L * per_layer
        else:
            eff_ff = self.moe_d_ff or self.d_ff
            n_mats = 3 if self.mlp_activation == "silu" else 2
            if self.num_experts:
                L_moe = L // self.moe_interleave
                L_dense = L - L_moe
                moe = self.num_experts * n_mats * d * eff_ff + \
                    d * self.num_experts
                if self.moe_shared_expert:
                    moe += n_mats * d * eff_ff
                mlp_total = L_moe * moe + L_dense * n_mats * d * self.d_ff
                body = L * attn + mlp_total
            else:
                mlp = n_mats * d * self.d_ff
                per_layer = attn + mlp
                body = L * per_layer
            if self.is_encoder_decoder:
                # encoder layers + decoder cross-attention
                body += self.num_encoder_layers * (attn + 2 * d * self.d_ff)
                body += L * attn  # cross attn
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return body + embed

    @property
    def active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.num_params
        d, L = self.d_model, self.num_layers
        L_moe = L // self.moe_interleave
        eff_ff = self.moe_d_ff or self.d_ff
        n_mats = 3 if self.mlp_activation == "silu" else 2
        dense_moe = self.num_experts * n_mats * d * eff_ff
        active_moe = self.num_experts_per_tok * n_mats * d * eff_ff
        return self.num_params - L_moe * (dense_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}")


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Production mesh description (TPU v5e pods)."""

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# TPU v5e roofline constants (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving engine configuration (paper §6/§7)."""

    page_size: int = 16              # tokens per KV block
    max_pages: int = 4096            # pool capacity (per cache kind)
    max_pages_per_req: int = 64      # block-table length (Smax/page)
    max_batch: int = 64              # decode batch upper bound
    max_prefill_tokens: int = 8192   # chunked-prefill budget per step
    # batched prefill: max requests co-scheduled into one padded (B, chunk)
    # prefill call; the token budget above is split across the
    # power-of-two-padded batch (0 = no cap beyond the budget)
    max_prefill_batch: int = 8
    # page-native serving (DESIGN.md §12/§13): hand pools + block tables to
    # the paged ResidualAttention kernel dispatcher — decode AND chunked
    # prefill — with batch/width bucketing.  Sliding-window (SWA) models
    # serve through the same kernels (window clamping skips out-of-window
    # page DMAs).  False keeps the legacy gather-to-contiguous paths for
    # bit-parity testing (same tokens, O(B·smax) HBM traffic; every such
    # executor call increments the ``fallback_gather_calls`` metric).
    use_paged_kernel: bool = True
    # floor for the bucketed block-table width, in pages (decode and
    # prefill): keeps the compiled-variant count small for short contexts
    # without giving up the kv_len-proportional HBM scaling.
    min_table_pages: int = 4
    # iteration-level continuous batching (DESIGN.md §14): each engine step
    # runs ONE token-budget batch plan — all runnable decode rows first
    # (q=1 each), then chunked-prefill rows filling the remaining budget —
    # executed as a single mixed executor call through the unified kernel
    # grid, so a long prompt can never head-of-line-block in-flight token
    # streams.  False keeps the legacy phase-separated step loop (one
    # batched prefill call + one decode call per step) for parity testing,
    # mirroring how ``use_paged_kernel`` gates the paged kernels.
    mixed_batching: bool = True
    # total tokens one iteration may compute (decode rows cost 1 each,
    # prefill rows their chunk length).  0 derives
    # ``max_prefill_tokens + max_batch`` — a full decode batch ON TOP of
    # the full legacy prefill budget, so flipping ``mixed_batching`` on
    # never shrinks per-step throughput relative to the old phase loop.
    iteration_token_budget: int = 0
    mode: str = "forkkv"             # forkkv | prefix | full_reuse
    # beyond-paper features (DESIGN.md §9); defaults are paper-faithful.
    broadcast_fork: bool = False
    adaptive_fallback: bool = False
    adaptive_high_watermark: float = 0.85
    # tiered KV offload (DESIGN.md §10): > 0 enables HBM→host demotion with
    # this many bytes of host budget; 0 keeps destroy-on-evict.
    host_tier_bytes: int = 0
    # policy knob: max pages promoted host→device per prefix match
    # (0 = unlimited) — bounds the H2D copy burst a single admission pays.
    tier_promote_limit: int = 0
    # blob codec applied on demote / reversed on promote (DESIGN.md §18):
    # "identity" (bit-identical), "int8" (per-row-scale quantization,
    # ~4x smaller host/disk footprint, bounded error), "zstd" (lossless
    # compression; falls back to zlib when zstandard is not installed).
    kv_codec: str = "identity"
    # disk tier below the host tier: > 0 adds a file-backed third tier of
    # this many bytes — host-LRU pressure SPILLS nodes to disk instead of
    # destroying them, and matches promote disk-tier nodes straight back.
    disk_tier_bytes: int = 0
    # directory holding disk-tier blob files, and — when set — the
    # persist()/restore() manifest: a server restarted with the same
    # ``persist_dir`` rehydrates its radix trees from the manifest into
    # the host tier instead of re-prefilling shared agent context.
    # Empty with disk_tier_bytes > 0 uses a temp directory (non-persistent).
    persist_dir: str = ""
    # stall detection: after this many consecutive engine steps with work
    # waiting but nothing admitted, prefilled, or decoded, the head waiting
    # request is failed with a ``stalled`` error instead of the engine
    # silently spinning until the caller's step budget runs out.
    stall_limit: int = 64
    # ---- multi-tenant admission (DESIGN.md §15) ----------------------------
    # admission-order policy: "fifo" (the seed behaviour — strict arrival
    # order) or "fairshare" (weighted fair queuing across tenants + SRPT
    # bias + aging + prefix-hit discount; serving/fairshare.py).
    admission: str = "fifo"
    # per-tenant WFQ weights as ((tenant, weight), ...); unnamed tenants
    # get weight 1.0.  Higher weight = more service before the tenant's
    # virtual clock catches up.
    tenant_weights: Tuple[Tuple[str, float], ...] = ()
    # per-tenant budgets, each 0 = unlimited: admitted-but-unfinished
    # requests; prompt+max_new tokens of those requests; device pages held
    # pinned by the tenant's live AgentSessions.
    tenant_max_concurrent: int = 0
    tenant_max_tokens_in_flight: int = 0
    tenant_max_pinned_pages: int = 0
    # fair-share score terms (see the formula in serving/fairshare.py):
    # SRPT bias multiplier on the request's expected compute, and the
    # aging credit in cost-tokens per waiting second (bounds starvation).
    fair_srpt_weight: float = 1.0
    fair_aging_tokens_per_s: float = 50.0
    # overload shedding, each 0 = unbounded: waiting-queue depth and
    # wait-time bounds past which requests are rejected with
    # ``finish_reason="rejected"`` + a retry-after hint (HTTP 429).
    max_queue_depth: int = 0
    max_queue_wait_s: float = 0.0
    # ---- speculative decoding on CoW forks (DESIGN.md §16) ----------------
    # draft-free speculation: propose up to spec_k tokens per decode step
    # (prompt-lookup / n-gram cache), verify them in ONE mixed-grid pass
    # (a q_len=k+1 row), commit the accepted prefix, drop the rest via CoW
    # refcounts.  Greedy requests only — accepted tokens are bit-identical
    # to the non-speculative stream.  Per-request override via
    # ``SamplingParams.speculate``/``spec_k``.
    speculate: bool = False
    spec_k: int = 4                  # max drafted tokens per verify step
    spec_proposer: str = "prompt_lookup"   # prompt_lookup | ngram_cache
    # adaptive draft length: per-request EMA acceptance controller backs
    # the draft cap off toward 1 when acceptance drops (speculate.py)
    spec_adaptive: bool = True
    spec_min_ngram: int = 2          # shortest suffix n-gram matched
    spec_cache_entries: int = 8192   # ngram_cache bound (LRU-evicted)
    # ---- fault tolerance (DESIGN.md §17) -----------------------------------
    # preempt–restore under pool pressure: when admission has been blocked
    # on pages for ``preempt_after_steps`` consecutive steps, the policy
    # picks a victim among running requests (worst fair-share score; never
    # a broadcast-fork writer), checkpoints its computed KV into the radix
    # tree (demotable to the host tier, or recomputed if that's full too)
    # and requeues it; on re-admission match_prefix restores the prefix and
    # only the uncovered suffix re-prefills.
    preempt: bool = True
    preempt_after_steps: int = 4
    # request quarantine: an in-jit isfinite guard on final logits rides
    # the existing single host sync; poisoned rows finish with
    # ``finish_reason="error"`` and their pages are reclaimed while the
    # rest of the batch continues.
    quarantine: bool = True
    # deterministic fault injection (serving/faults.py): plan grammar
    # "site:trigger,trigger;site2:trigger" with sites pool_alloc /
    # tier_demote / tier_promote / nan_logits / pump_stall / executor and
    # triggers cN (Nth call), rKEY (key match), pX (seeded probability),
    # * (always).  Empty string = no injection (env FORKKV_FAULT_PLAN /
    # FORKKV_FAULT_SEED are the fallback wiring for smoke/CI).
    fault_plan: str = ""
    fault_seed: int = 0
    # pump watchdog: the frontend trips (and counts) when the engine has
    # pending work but its step loop hasn't advanced for this many
    # seconds; 0 disables the watchdog thread.
    watchdog_s: float = 10.0
