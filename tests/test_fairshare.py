"""Admission-policy unit tests: WFQ ordering, SRPT bias, aging, budgets,
deterministic shedding (DESIGN.md §15).  Pure control plane — no model,
no jax arrays."""
import dataclasses

import pytest

from repro.core.config import ServeConfig
from repro.serving.fairshare import (FairShareAdmission, FIFOAdmission,
                                     make_policy)


@dataclasses.dataclass
class FakeReq:
    rid: int
    tenant: str = "default"
    prompt: tuple = tuple(range(32))
    max_new_tokens: int = 8
    arrival: float = 0.0


def sc(**kw) -> ServeConfig:
    return ServeConfig(page_size=16, max_pages=64, max_batch=4, **kw)


def test_make_policy_dispatch():
    assert isinstance(make_policy(sc()), FIFOAdmission)
    assert isinstance(make_policy(sc(admission="fairshare")),
                      FairShareAdmission)
    with pytest.raises(ValueError):
        make_policy(sc(admission="lottery"))


def test_fifo_is_arrival_order():
    pol = make_policy(sc())
    waiting = [FakeReq(rid=1, arrival=0.0), FakeReq(rid=2, arrival=1.0)]
    assert pol.select(waiting, now=2.0).rid == 1


def test_fifo_head_of_line_blocks_on_budget():
    pol = make_policy(sc(tenant_max_concurrent=1))
    pol.tenant("hog").concurrent = 1
    waiting = [FakeReq(rid=1, tenant="hog"),
               FakeReq(rid=2, tenant="light")]
    # FIFO is FIFO: the over-budget head blocks everyone behind it
    assert pol.select(waiting, now=0.0) is None


def test_fairshare_skips_over_budget_tenant():
    pol = make_policy(sc(admission="fairshare", tenant_max_concurrent=1))
    pol.tenant("hog").concurrent = 1
    waiting = [FakeReq(rid=1, tenant="hog"),
               FakeReq(rid=2, tenant="light")]
    assert pol.select(waiting, now=0.0).rid == 2


def test_wfq_prefers_underserved_tenant():
    pol = make_policy(sc(admission="fairshare"))
    pol.tenant("hog").service = 10_000.0       # hog has eaten a lot
    waiting = [FakeReq(rid=1, tenant="hog", arrival=0.0),
               FakeReq(rid=2, tenant="light", arrival=5.0)]
    # light arrived later but has zero virtual time -> wins
    assert pol.select(waiting, now=5.0).rid == 2


def test_weights_scale_virtual_time():
    pol = make_policy(sc(admission="fairshare",
                         tenant_weights=(("premium", 4.0),)))
    pol.tenant("premium").service = 400.0      # vtime 100
    pol.tenant("basic").service = 200.0        # vtime 200
    waiting = [FakeReq(rid=1, tenant="basic"),
               FakeReq(rid=2, tenant="premium")]
    assert pol.select(waiting, now=0.0).rid == 2


def test_srpt_prefers_short_request_within_tenant():
    pol = make_policy(sc(admission="fairshare", fair_aging_tokens_per_s=0))
    waiting = [FakeReq(rid=1, prompt=tuple(range(100)), max_new_tokens=64),
               FakeReq(rid=2, prompt=tuple(range(8)), max_new_tokens=4)]
    assert pol.select(waiting, now=0.0).rid == 2


def test_prefix_hit_discounts_cost():
    # identical requests except rid=2's prompt is fully cached
    pol = FairShareAdmission(sc(admission="fairshare"),
                             probe_hit=lambda r: 1.0 if r.rid == 2 else 0.0)
    waiting = [FakeReq(rid=1), FakeReq(rid=2)]
    assert pol.cost(waiting[1]) < pol.cost(waiting[0])
    assert pol.select(waiting, now=0.0).rid == 2


def test_aging_bounds_starvation():
    pol = make_policy(sc(admission="fairshare", fair_srpt_weight=1.0,
                         fair_aging_tokens_per_s=50.0))
    old_big = FakeReq(rid=1, prompt=tuple(range(500)),
                      max_new_tokens=100, arrival=0.0)
    # a stream of fresh small requests (cost 8, zero wait) would starve
    # the big one under pure SRPT; aging credit (50 tokens/s) closes the
    # 592-token gap after ~12s of waiting.
    assert pol.select([old_big, FakeReq(rid=2, prompt=(1, 2, 3, 4),
                                        max_new_tokens=4, arrival=5.0)],
                      now=5.0).rid == 2
    assert pol.select([old_big, FakeReq(rid=3, prompt=(1, 2, 3, 4),
                                        max_new_tokens=4, arrival=13.0)],
                      now=13.0).rid == 1


def test_admit_finish_accounting():
    pol = make_policy(sc(admission="fairshare"))
    req = FakeReq(rid=1, tenant="t", prompt=tuple(range(10)),
                  max_new_tokens=6)
    pol.on_admit(req, now=0.0)
    st = pol.tenant("t")
    assert (st.concurrent, st.tokens_in_flight, st.accepted) == (1, 16, 1)
    assert st.service == pytest.approx(16.0)   # zero hit prob -> full cost
    pol.on_finish(req, now=1.0)
    assert (st.concurrent, st.tokens_in_flight) == (0, 0)
    snap = pol.snapshot()["t"]
    assert snap["accepted"] == 1 and snap["vtime"] == pytest.approx(16.0)


def test_shed_wait_bound():
    pol = make_policy(sc(max_queue_wait_s=2.0))
    waiting = [FakeReq(rid=1, arrival=0.0), FakeReq(rid=2, arrival=9.0)]
    victims = pol.shed(waiting, now=10.0)
    assert [r.rid for r, _ in victims] == [1]
    assert all(ra >= 1.0 for _, ra in victims)


def test_shed_depth_bound_fifo_newest_first():
    pol = make_policy(sc(max_queue_depth=2))
    waiting = [FakeReq(rid=i, arrival=float(i)) for i in range(1, 6)]
    victims = pol.shed(waiting, now=10.0)
    # 5 waiting, bound 2 -> shed 3 victims, newest arrivals first
    assert [r.rid for r, _ in victims] == [5, 4, 3]
    # deterministic: same queue, same clock, same victims
    assert [r.rid for r, _ in pol.shed(waiting, now=10.0)] == [5, 4, 3]


def test_shed_depth_bound_fairshare_worst_score_first():
    pol = make_policy(sc(admission="fairshare", max_queue_depth=1,
                         fair_aging_tokens_per_s=0))
    cheap = FakeReq(rid=1, prompt=tuple(range(4)), max_new_tokens=2)
    dear = FakeReq(rid=2, prompt=tuple(range(400)), max_new_tokens=64)
    victims = pol.shed([cheap, dear], now=0.0)
    # the request fair share would admit LAST is shed first
    assert [r.rid for r, _ in victims] == [2]


def test_retry_after_scales_with_excess_depth():
    pol = make_policy(sc(max_queue_depth=2))
    waiting = [FakeReq(rid=i, arrival=float(i)) for i in range(1, 13)]
    victims = pol.shed(waiting, now=20.0)
    # first victim sees the full backlog (depth 12, bound 2 -> 5s)
    assert victims[0][1] == pytest.approx(0.5 * (12 - 2))
    # hints shrink as the queue drains and never drop below 1s
    assert victims[-1][1] >= 1.0
    hints = [ra for _, ra in victims]
    assert hints == sorted(hints, reverse=True)


def test_reject_counters_split_timeouts():
    pol = make_policy(sc())
    pol.on_reject(FakeReq(rid=1, tenant="t"), now=0.0)
    pol.on_reject(FakeReq(rid=2, tenant="t"), now=0.0, timeout=True)
    st = pol.tenant("t")
    assert (st.rejected, st.timeouts) == (1, 1)
