"""End-to-end serving engine tests: modes, CoW invariants, eviction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine, Request
from repro.serving.workflows import WorkflowConfig, WorkflowDriver


@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def make_engine(model, mode, max_pages=256):
    cfg, params, lora = model
    sc = ServeConfig(page_size=16, max_pages=max_pages, max_batch=4,
                     max_prefill_tokens=64, mode=mode, max_pages_per_req=12)
    return Engine(cfg, params, lora, sc), cfg


def run_one(engine, cfg, adapter, prompt, max_new=6):
    req = Request(rid=0, adapter_id=adapter, prompt=prompt,
                  max_new_tokens=max_new)
    engine.submit(req)
    while req.state != "done":
        engine.step()
    return req


def test_single_request_generates(model):
    eng, cfg = make_engine(model, "forkkv")
    prompt = list(np.random.default_rng(0).integers(0, cfg.vocab_size, 40))
    req = run_one(eng, cfg, adapter=1, prompt=prompt)
    assert len(req.output) == 7            # max_new + the final unconsumed
    assert all(0 <= t < cfg.vocab_size for t in req.output)


def test_forkkv_base_cache_shared_across_adapters(model):
    eng, cfg = make_engine(model, "forkkv")
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, 64))
    run_one(eng, cfg, 0, shared + list(rng.integers(0, cfg.vocab_size, 8)))
    base_after_1 = eng.base_pool.used_pages
    res_after_1 = eng.res_pool.used_pages
    # second agent, DIFFERENT adapter, same shared context
    run_one(eng, cfg, 1, shared + list(rng.integers(0, cfg.vocab_size, 8)))
    fr_kinds = eng.dual.hit_kinds
    assert fr_kinds.get("partial_res", 0) >= 1   # bCache inherited via fork
    # base pool grew by much less than a full context's worth
    base_growth = eng.base_pool.used_pages - base_after_1
    res_growth = eng.res_pool.used_pages - res_after_1
    assert base_growth < res_growth, (base_growth, res_growth)


def test_forkkv_same_agent_full_hit_skips_prefill(model):
    eng, cfg = make_engine(model, "forkkv")
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, 64))
    r1 = run_one(eng, cfg, 2, shared)
    r2 = run_one(eng, cfg, 2, shared)      # identical request, same adapter
    assert eng.dual.hit_kinds.get("full", 0) >= 1
    assert r2.prefilled_tokens < r1.prefilled_tokens


def test_prefix_mode_no_cross_adapter_sharing(model):
    eng, cfg = make_engine(model, "prefix")
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, 64))
    run_one(eng, cfg, 0, shared)
    before = eng.base_pool.used_pages
    run_one(eng, cfg, 1, shared)
    growth = eng.base_pool.used_pages - before
    assert growth >= len(shared) // 16     # full duplicate cache

    m = eng.metrics()
    assert m["hit_rate"] == 0.0


def test_cow_shared_pages_not_written(model):
    """CoW invariant: after a second agent forks, the first agent's cached
    base pages must be byte-identical (read-only parent pages)."""
    eng, cfg = make_engine(model, "forkkv")
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, 64))
    run_one(eng, cfg, 0, shared)
    fr = eng.dual.fork(shared, 99, lock=False)
    pages = list(fr.base_pages)
    snapshot = np.asarray(eng.executor.pools.kb[:, pages])
    run_one(eng, cfg, 1, shared + [5, 6, 7])
    after = np.asarray(eng.executor.pools.kb[:, pages])
    np.testing.assert_array_equal(snapshot, after)


def test_eviction_under_pressure_and_partial_hit(model):
    eng, cfg = make_engine(model, "forkkv", max_pages=16)
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, 48))
    for a in range(6):
        extra = list(rng.integers(0, cfg.vocab_size, 32))
        run_one(eng, cfg, a, shared + extra, max_new=4)
    m = eng.metrics()
    assert m["tasks_done"] == 6
    # pool is tiny (16 pages = 256 tokens/kind): evictions must happen
    assert m["evicted_pages"] > 0
    # refcount sanity: every free page has ref 0 (checked via allocation)
    assert eng.base_pool.free_pages + eng.base_pool.used_pages == 16


def test_full_reuse_shares_everything(model):
    eng, cfg = make_engine(model, "full_reuse")
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, 64))
    run_one(eng, cfg, 0, shared)
    before = eng.base_pool.used_pages
    run_one(eng, cfg, 7, shared)           # different adapter still shares
    growth = eng.base_pool.used_pages - before
    assert growth <= 2


def test_memory_ordering_forkkv_beats_prefix(model):
    """The paper's core claim at engine level: with N agents over one shared
    context, ForkKV peak memory << prefix caching peak memory."""
    rng = np.random.default_rng(0)
    cfg = model[0]
    shared = list(rng.integers(0, cfg.vocab_size, 96))
    peaks = {}
    for mode in ("forkkv", "prefix"):
        eng, _ = make_engine(model, mode, max_pages=512)
        for a in range(4):
            run_one(eng, cfg, a,
                    shared + list(rng.integers(0, cfg.vocab_size, 8)),
                    max_new=4)
        m = eng.metrics()
        peaks[mode] = m["peak_cache_bytes"]
    assert peaks["forkkv"] < peaks["prefix"]


def test_mapreduce_workflow_runs(model):
    eng, cfg = make_engine(model, "forkkv", max_pages=512)
    wf = WorkflowConfig(n_workflows=1, agents_per_workflow=3,
                        shared_context_len=64, max_new_tokens=4,
                        vocab=cfg.vocab_size)
    rep = WorkflowDriver(eng, wf).run_mapreduce()
    assert rep["tasks"] == 4
    assert rep["tasks_done"] == 4


def test_broadcast_fork(model):
    """Beyond-paper broadcast fork: N simultaneous agents over one context
    prefill it ONCE (amortized), outputs stay finite, pages consistent."""
    cfg, params, lora = model
    from repro.core.config import ServeConfig
    sc = ServeConfig(page_size=16, max_pages=256, max_batch=6,
                     max_prefill_tokens=64, mode="forkkv",
                     max_pages_per_req=12, broadcast_fork=True)
    eng = Engine(cfg, params, lora, sc)
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, 64))
    reqs = [Request(rid=i, adapter_id=i, prompt=list(shared),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    while any(r.state != "done" for r in reqs):
        eng.step()
    # amortization: each agent accounts ~1/3 of the shared prefill
    total_prefilled = sum(r.prefilled_tokens for r in reqs)
    assert total_prefilled < 2.0 * len(shared), total_prefilled
    for r in reqs:
        assert len(r.output) == 5
    # pool invariant: no leaked/negative refs after completion
    assert eng.base_pool.free_pages + eng.base_pool.used_pages == 256


def test_overlong_request_rejected_gracefully(model):
    """Regression: an over-long request must be rejected (state=done with
    an error note) instead of raising from inside the admit loop — and the
    engine must keep serving the rest of the queue."""
    eng, cfg = make_engine(model, "forkkv")   # max_pages_per_req=12 → 192 tok
    rng = np.random.default_rng(0)
    too_long = Request(rid=1, adapter_id=0,
                       prompt=list(rng.integers(0, cfg.vocab_size, 400)),
                       max_new_tokens=4)
    ok = Request(rid=2, adapter_id=1,
                 prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                 max_new_tokens=4)
    eng.submit(too_long)
    eng.submit(ok)
    eng.run()
    assert too_long.state == "done"
    assert "rejected" in too_long.error and too_long.output == []
    assert ok.state == "done" and ok.error == ""
    assert len(ok.output) == 5
    m = eng.metrics()
    assert m["rejected"] == 1 and m["tasks_done"] == 2


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # minimal env: keep deterministic tests running
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3),       # adapter id
                              st.integers(2, 5),       # shared-prefix pages
                              st.integers(0, 24),      # extra prompt tokens
                              st.integers(1, 4)),      # max_new
                    min_size=1, max_size=5),
           st.sampled_from(["forkkv", "prefix", "full_reuse"]))
    def test_property_engine_invariants(model, reqs_spec, mode):
        """Any workload, any mode: every request completes with the right
        output length; page pools conserve pages; no negative refcounts."""
        cfg, params, lora = model
        sc = ServeConfig(page_size=16, max_pages=96, max_batch=4,
                         max_prefill_tokens=64, mode=mode,
                         max_pages_per_req=10)
        eng = Engine(cfg, params, lora, sc)
        rng = np.random.default_rng(0)
        shared = list(rng.integers(0, cfg.vocab_size, 48))
        reqs = []
        for i, (aid, _, extra, max_new) in enumerate(reqs_spec):
            prompt = shared + list(rng.integers(0, cfg.vocab_size, extra))
            reqs.append(Request(rid=i, adapter_id=aid, prompt=prompt,
                                max_new_tokens=max_new))
        for r in reqs:
            eng.submit(r)
        for _ in range(5000):
            if not eng.waiting and not eng.running:
                break
            eng.step()
        for r in reqs:
            assert r.state == "done"
            assert len(r.output) == r.max_new_tokens + 1
            assert all(0 <= t < cfg.vocab_size for t in r.output)
        assert eng.base_pool.free_pages + eng.base_pool.used_pages == 96
        assert eng.res_pool.free_pages + eng.res_pool.used_pages == \
            eng.res_pool.num_pages
else:
    def test_property_engine_skipped_without_hypothesis():
        pytest.importorskip("hypothesis")


# ------------------------------------------------ admission control (§15)
def make_admission_engine(model, **kw):
    from repro.core.config import ServeConfig
    cfg, params, lora = model
    base = dict(page_size=16, max_pages=256, max_batch=4,
                max_prefill_tokens=64, mode="forkkv", max_pages_per_req=12)
    base.update(kw)
    return Engine(cfg, params, lora, ServeConfig(**base)), cfg


def test_deadline_times_out_waiting_request(model):
    """Regression: a request still waiting past its deadline finishes
    with finish_reason="timeout"; admitted work is untouched."""
    eng, cfg = make_admission_engine(model, max_batch=1)
    rng = np.random.default_rng(0)
    a = Request(rid=1, adapter_id=0,
                prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                max_new_tokens=4)
    b = Request(rid=2, adapter_id=1,
                prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                max_new_tokens=4, deadline_s=0.5)
    eng.submit(a)
    eng.submit(b)
    eng.step()                      # admits a (batch slot 1 of 1)
    assert a in eng.running and b in eng.waiting
    b.arrival -= 1.0                # age b past its 0.5s deadline
    eng.step()
    assert b.state == "done" and b.finish_reason == "timeout"
    assert b.error.startswith("timeout") and eng.timeouts == 1
    while a.state != "done":
        eng.step()
    assert a.finish_reason == "length"
    m = eng.metrics()
    assert m["timeouts"] == 1 and m["tenants"]["default"]["timeouts"] == 1


def test_shedding_fires_deterministically_at_queue_bound(model):
    """Overload: with max_queue_depth=2, a burst of 6 sheds exactly the
    newest arrivals beyond the bound — same queue, same victims."""
    eng, cfg = make_admission_engine(model, max_batch=1, max_queue_depth=2)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, adapter_id=0,
                    prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                    max_new_tokens=2)
            for i in range(1, 7)]
    for i, r in enumerate(reqs):
        eng.submit(r)
        r.arrival = float(i)        # explicit arrival order (no clock ties)
    eng.step()
    # depth 6 > bound 2 -> shed the 4 newest BEFORE admitting, leaving
    # one admitted + two waiting
    shed = [r for r in reqs if r.finish_reason == "rejected"]
    assert sorted(r.rid for r in shed) == [3, 4, 5, 6]
    assert eng.shed == 4 and eng.rejected == 4
    assert all(r.retry_after_s >= 1.0 for r in shed)
    assert all("overloaded" in r.error for r in shed)
    survivors = {r.rid for r in eng.running} | {r.rid for r in eng.waiting}
    assert survivors == {1, 2}
    while any(r.state != "done" for r in reqs):
        eng.step()
    assert [r.finish_reason for r in reqs[:2]] == ["length", "length"]
    assert eng.metrics()["shed"] == 4


def test_fairshare_light_tenant_admission_not_starved(model):
    """A hog burst must not starve a light tenant under fair share:
    WFQ admits the light request within the first batch, while FIFO
    makes it wait for the whole hog backlog."""
    waits = {}
    for admission in ("fifo", "fairshare"):
        eng, cfg = make_admission_engine(model, max_batch=2,
                                         admission=admission)
        rng = np.random.default_rng(2)
        hogs = [Request(rid=i, adapter_id=0, tenant="hog",
                        prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                        max_new_tokens=2)
                for i in range(1, 7)]
        light = Request(rid=9, adapter_id=1, tenant="light",
                        prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                        max_new_tokens=2)
        for r in hogs + [light]:    # submission order: hogs, then light
            eng.submit(r)
        while any(r.state != "done" for r in hogs + [light]):
            eng.step()
        admitted_before_light = sum(
            1 for r in hogs if r.admitted_at < light.admitted_at)
        waits[admission] = admitted_before_light
        snap = eng.metrics()["tenants"]
        assert snap["light"]["accepted"] == 1
        assert snap["hog"]["accepted"] == 6
    # FIFO admits light only after every hog; under fair share the hog's
    # first admission raises its virtual time, so light (vtime 0) wins
    # the very next admission slot.
    assert waits["fifo"] == 6
    assert waits["fairshare"] <= 1
