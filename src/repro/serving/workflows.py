"""Agentic workflow generators + driver (paper §7.1 methodology).

ReAct: sequential pipeline — each agent's context = shared static prefix +
all previous agents' outputs + mock tool observations + its own instruction.
MapReduce: N agents fork the same shared context in parallel with distinct
instructions; a reduce agent consumes their concatenated outputs.

Tool calls are simulated exactly as in the paper: a constant latency and a
mock observation of random tokens (synthetic ids here — no tokenizer ships
offline).

The driver runs entirely on the session/fork API (DESIGN.md §11): one
:class:`~repro.serving.api.AgentSession` pins the shared static context,
every agent step is a ``session.fork()``, and the engine is pumped through
``server.poll()`` — no ``Request`` construction or ``engine.step()`` busy
loops here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.api import AgentSession, ForkServer, GenerationHandle
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class WorkflowConfig:
    n_workflows: int = 4
    agents_per_workflow: int = 4
    rounds: int = 1               # ReAct rounds: each agent revisits its
                                  # (grown) context every round — the
                                  # paper's sustained multi-turn load
    shared_context_len: int = 512     # paper: 32K-64K; scaled for CPU
    instr_len: int = 24               # paper Table 1: ~24 dynamic tokens
    tool_obs_len: int = 50            # paper: 100 mock tool tokens
    max_new_tokens: int = 16          # paper: 256; scaled for CPU
    tool_latency_s: float = 0.0       # simulated (recorded, not slept)
    vocab: int = 1024
    seed: int = 0
    # token-selection policy for every agent; None -> greedy argmax with
    # this config's max_new_tokens budget
    sampling: Optional[SamplingParams] = None


class WorkflowDriver:
    """Drives ReAct / MapReduce workflows through a :class:`ForkServer`.

    Accepts a bare :class:`Engine` too (wrapped via ``from_engine``) so
    engine-level tests and older callers keep working.
    """

    def __init__(self, server, wf: WorkflowConfig):
        if isinstance(server, Engine):
            server = ForkServer.from_engine(server)
        self.server: ForkServer = server
        self.engine = server.engine        # metrics convenience
        self.wf = wf
        self.rng = np.random.default_rng(wf.seed)
        # one shared static context per workflow "project"; workflows within
        # a run share it (the paper's massive static part)
        self.shared = list(self.rng.integers(
            0, wf.vocab, size=wf.shared_context_len).astype(int))
        self.tool_time = 0.0

    def _tokens(self, n: int) -> List[int]:
        return list(self.rng.integers(0, self.wf.vocab, size=n).astype(int))

    def _sampling(self) -> SamplingParams:
        if self.wf.sampling is not None:
            return self.wf.sampling
        return SamplingParams(max_new_tokens=self.wf.max_new_tokens)

    # ------------------------------------------------------------- ReAct
    def run_react(self) -> Dict:
        """CONCURRENT sequential workflows (paper §7.1: N workflows run at
        once; within a workflow agents chain).  Agent i of workflow w uses
        adapter w*agents+i (completely non-overlapping adapters, Fig. 3).
        Concurrency is what creates the memory pressure + decode batching
        the paper measures."""
        wf = self.wf
        t0 = time.time()
        tasks = 0
        total_steps = wf.agents_per_workflow * wf.rounds
        session = self.server.session(self.shared)
        state = [{"dynamic": [], "agent": 0, "handle": None}
                 for _ in range(wf.n_workflows)]

        def unfinished():
            return any(s["agent"] < total_steps or
                       s["handle"] is not None for s in state)

        while unfinished():
            for w, s in enumerate(state):
                if s["handle"] is None and s["agent"] < total_steps:
                    # agents cycle across rounds: same adapter re-extends
                    # the same (grown) context -> residual-tree hits
                    adapter = w * wf.agents_per_workflow + \
                        (s["agent"] % wf.agents_per_workflow)
                    instr = s["dynamic"] + self._tokens(wf.instr_len)
                    s["handle"] = session.fork(adapter, instr,
                                               self._sampling())
            self.server.poll()
            for s in state:
                h: Optional[GenerationHandle] = s["handle"]
                if h is not None and h.done:
                    out = h.result().tokens
                    s["dynamic"] = s["dynamic"] + out + \
                        self._tokens(wf.tool_obs_len)
                    s["agent"] += 1
                    s["handle"] = None
                    self.tool_time += wf.tool_latency_s
                    tasks += 1
        session.close()
        wall = time.time() - t0
        return self._report("react", tasks, wall)

    # --------------------------------------------------------- MapReduce
    def run_mapreduce(self) -> Dict:
        """Parallel map agents fork the shared context simultaneously."""
        wf = self.wf
        t0 = time.time()
        tasks = 0
        session = self.server.session(self.shared)
        for w in range(wf.n_workflows):
            handles = []
            for a in range(wf.agents_per_workflow):
                adapter = w * wf.agents_per_workflow + a
                handles.append(session.fork(
                    adapter, self._tokens(wf.instr_len), self._sampling()))
            outs = [r.tokens for r in self.server.wait(handles)]
            tasks += len(handles)
            # reduce step: one agent over concatenated outputs
            reduce_instr = [t for o in outs for t in o] + \
                self._tokens(wf.instr_len)
            session.fork(wf.n_workflows * wf.agents_per_workflow + w,
                         reduce_instr, self._sampling()).result()
            tasks += 1
        session.close()
        wall = time.time() - t0
        return self._report("mapreduce", tasks, wall)

    def _report(self, kind: str, tasks: int, wall: float) -> Dict:
        m = self.server.metrics()
        m.update(workflow=kind, tasks=tasks, wall_s=wall,
                 tool_latency_s=self.tool_time,
                 throughput_tasks_per_s=tasks / max(wall, 1e-9))
        return m
