"""Sampling parameters + the jit-stable categorical sampling path.

The seed executor hard-coded ``argmax`` inside the compiled decode/prefill
functions.  This module factors token selection out into one function,
:func:`sample_tokens`, that runs INSIDE the jitted executor bodies with
fixed shapes:

  * greedy rows (``temperature <= 0``) take ``argmax`` over the RAW logits
    — bit-for-bit identical to the seed's behaviour, so greedy
    :class:`SamplingParams` reproduce the old outputs exactly;
  * sampled rows apply temperature, then top-k, then top-p masking, and
    draw from ``jax.random.categorical``.  Randomness is derived per row
    from ``fold_in(PRNGKey(seed), position)`` — fully deterministic given
    (seed, #tokens generated so far) and independent of batch composition,
    so a request's stream never changes because another request joined the
    decode batch.

Everything is branch-free over traced values (``jnp.where`` masks, gather
with dynamic indices), so one compiled executor serves every mix of greedy
and sampled requests in a batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request token-selection policy (frozen: safe to share/hash).

    temperature  0.0 (default) = greedy argmax; > 0 = categorical sampling
    top_k        keep only the k highest logits (0 = disabled)
    top_p        nucleus sampling: keep the smallest prefix of the sorted
                 distribution with cumulative probability >= top_p
                 (1.0 = disabled)
    seed         PRNG seed for this request's stream (ignored when greedy)
    max_new_tokens  generation budget
    stop_token_ids  generation finishes (reason "stop") when one of these
                 is produced; the stop token itself is not returned
    speculate    per-request speculative-decoding override (DESIGN.md
                 §16): None defers to ``ServeConfig.speculate``; True/
                 False forces it on/off for this request.  Greedy
                 requests only — sampled rows always run plain decode.
    spec_k       per-request draft-length cap (0 = ``ServeConfig.spec_k``)
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new_tokens: int = 16
    stop_token_ids: Tuple[int, ...] = ()
    speculate: Optional[bool] = None
    spec_k: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")
        # tolerate lists from CLI / JSON callers
        object.__setattr__(self, "stop_token_ids",
                           tuple(self.stop_token_ids))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def sample_tokens(logits: jnp.ndarray, temps: jnp.ndarray,
                  top_ks: jnp.ndarray, top_ps: jnp.ndarray,
                  seeds: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Select one token per row of ``logits``.  Jit-stable; runs inside the
    compiled executor bodies.

    logits: (B, V); temps/top_ps: (B,) float; top_ks/seeds/positions: (B,)
    int32.  ``positions`` is the number of tokens the row's request has
    generated so far — folded into the key so successive steps draw fresh
    randomness deterministically.
    """
    vocab = logits.shape[-1]
    greedy = temps <= 0.0
    # --- temperature (guard greedy rows against /0; their value is unused)
    scaled = logits.astype(jnp.float32) / \
        jnp.where(greedy, 1.0, temps)[:, None]
    # --- top-k: threshold at the k-th largest logit (k dynamic per row)
    sort_desc = -jnp.sort(-scaled, axis=-1)
    k = jnp.clip(jnp.where(top_ks <= 0, vocab, top_ks), 1, vocab)
    kth = jnp.take_along_axis(sort_desc, (k - 1)[:, None].astype(jnp.int32),
                              axis=-1)
    masked = jnp.where(scaled < kth, -jnp.inf, scaled)
    # --- top-p over the top-k-masked distribution: keep the smallest
    # prefix of the sorted probs whose EXCLUSIVE cumsum is < top_p (the
    # most-probable token is always kept)
    sort_m = -jnp.sort(-masked, axis=-1)
    probs = jax.nn.softmax(sort_m, axis=-1)      # -inf rows -> prob 0
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_ps[:, None]
    last = jnp.maximum(jnp.sum(keep, axis=-1) - 1, 0).astype(jnp.int32)
    pth = jnp.take_along_axis(sort_m, last[:, None], axis=-1)
    masked = jnp.where(masked < pth, -jnp.inf, masked)

    def draw(seed, pos, row):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
        return jax.random.categorical(key, row)

    sampled = jax.vmap(draw)(seeds, positions, masked)
    # greedy rows: argmax over RAW logits — the seed's exact path
    return jnp.where(greedy, jnp.argmax(logits, axis=-1),
                     sampled).astype(jnp.int32)
