"""Public entry points for ResidualAttention.

``residual_attention(...)`` dispatches between the Pallas kernel (TPU target,
validated on CPU via ``interpret=True``) and the pure-jnp oracle in
:mod:`repro.kernels.ref`.  The jitted model code calls these wrappers so the
backend can be swapped with one flag.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels import residual_attention as ra

# Backend selection: "pallas" (interpret on CPU, compiled on TPU) or "ref".
_BACKEND = os.environ.get("REPRO_ATTN_BACKEND", "ref")


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("pallas", "ref"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def residual_attention(q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
                       *, qpos, kv_len, window: int = 0, causal: bool = True,
                       scale: Optional[float] = None,
                       backend: Optional[str] = None,
                       interpret: bool = True) -> jnp.ndarray:
    """Attention over a disaggregated KV cache.  Shapes as in ref.py."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    be = backend or _BACKEND
    if be == "ref":
        return ref_mod.residual_attention_ref(
            q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
            qpos=qpos, kv_len=kv_len, window=window, causal=causal,
            scale=scale)
    if q.shape[1] == 1:   # decode fast path
        out = ra.residual_attention_decode(
            q[:, 0], k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
            kv_len, scale=scale, window=window, interpret=interpret)
        return out[:, None]
    return ra.residual_attention_prefill(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len,
        scale=scale, causal=causal, window=window, interpret=interpret)
