"""llama4-maverick-400b-a17b [moe]: 128 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
    d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
    vocab_size=202048, num_experts=128, num_experts_per_tok=1,
    moe_d_ff=8192, moe_interleave=2, moe_shared_expert=True,
    lora=LoRAConfig(rank=16), scan_layers=True,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama4-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, moe_d_ff=256, vocab_size=512,
        num_experts=4, num_experts_per_tok=1, dtype="float32",
        moe_capacity_factor=8.0,
        scan_groups=0, remat=False)
