"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=24, d_model=3840,
    num_heads=32, num_kv_heads=8, d_ff=10240, vocab_size=32000,
    sliding_window=4096, lora=LoRAConfig(rank=16), scan_layers=True,
    citation="arXiv:2401.16818")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="danube-tiny", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512, sliding_window=16,
        dtype="float32", remat=False)
