#!/usr/bin/env bash
# Smoke check: tier-1 test suite + one tiny tiered-engine workflow
# end-to-end (HBM→host demotion under pressure, DESIGN.md §10) + the
# session/fork API example in all three cache-sharing modes (§11).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== tiered-engine workflow e2e =="
python - <<'PY'
import jax
from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.workflows import WorkflowConfig, WorkflowDriver

cfg = tiny_serving_model(rank=8)
params = tfm.init_params(cfg, jax.random.PRNGKey(0))
lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=8)
sc = ServeConfig(page_size=16, max_pages=26, max_batch=4,
                 max_prefill_tokens=64, mode="forkkv",
                 max_pages_per_req=24, host_tier_bytes=64 << 20)
server = ForkServer(cfg, params, lora, sc)
wf = WorkflowConfig(n_workflows=3, agents_per_workflow=2, rounds=2,
                    shared_context_len=256, instr_len=16, tool_obs_len=24,
                    max_new_tokens=4, vocab=cfg.vocab_size, seed=0)
rep = WorkflowDriver(server, wf).run_react()
assert rep["tasks_done"] == 12, rep["tasks_done"]
assert rep["demoted_pages"] > 0, "expected demotions under pressure"
assert rep["tier_hits"] > 0, "expected host-tier promotions"
eng = server.engine
assert eng.base_pool.free_pages + eng.base_pool.used_pages == 26
print(f"tiered e2e OK: tasks={rep['tasks_done']} "
      f"tier_hits={rep['tier_hits']} demoted={rep['demoted_pages']} "
      f"promoted_bytes={rep['promoted_bytes']} "
      f"prefill_saved={rep['prefill_saved_frac']:.3f}")
PY

echo "== session/fork API example, all three modes =="
for mode in forkkv prefix full_reuse; do
  python examples/react_agent_tree.py --mode "$mode" --temperature 0.8
done

echo "== decode-step benchmark smoke (paged vs gather, DESIGN.md §12) =="
python -m benchmarks.bench_decode --smoke --out BENCH_decode.smoke.json
test -s BENCH_decode.smoke.json
python - <<'PY'
import json
rep = json.load(open("BENCH_decode.smoke.json"))
assert rep["rows"], "empty benchmark report"
assert all(r["us_per_decode_step"] > 0 for r in rep["rows"])
print("bench smoke OK:", rep["summary"])
PY

echo "== prefill benchmark smoke (page-native vs gather, DESIGN.md §13) =="
python -m benchmarks.bench_prefill --smoke --out BENCH_prefill.smoke.json
test -s BENCH_prefill.smoke.json
python - <<'PY'
import json
rep = json.load(open("BENCH_prefill.smoke.json"))
assert rep["rows"], "empty benchmark report"
assert all(r["us_per_prompt_token"] > 0 for r in rep["rows"])
assert all(r["fallback_gather_calls"] == 0 for r in rep["rows"]
           if r["path"] == "paged"), "paged prefill fell back to gather"
print("prefill bench smoke OK:", rep["summary"])
PY

echo "== serving benchmark smoke (mixed vs phase-separated, DESIGN.md §14) =="
python -m benchmarks.bench_serving --smoke --speculate \
  --out BENCH_serving.smoke.json
test -s BENCH_serving.smoke.json
python - <<'PY'
import json
rep = json.load(open("BENCH_serving.smoke.json"))
for side in ("mixed", "phase_separated"):
    s = rep[side]
    assert s["requests"] > 0 and s["gen_tokens"] > 0, s
    assert s["ttft_p99_ms"] > 0 and s["tpot_p99_ms"] > 0, s
    assert s["fallback_gather_calls"] == 0, s
assert rep["mixed"]["mixed_steps"] > 0, "no mixed iterations exercised"
assert rep["phase_separated"]["mixed_steps"] == 0
assert rep["comparison"]["throughput_ratio"] > 0
print("serving bench smoke OK:", rep["comparison"],
      "verdict:", rep["verdict"])
# speculative block (DESIGN.md §16): the repetitive trace must really
# speculate — drafts proposed AND accepted — with zero gather fallbacks
spec = rep["speculative"]
assert spec["speculate"]["spec_proposed_tokens"] > 0, spec
assert spec["speculate"]["spec_accepted_tokens"] > 0, spec
assert spec["comparison"]["acceptance_rate"] > 0, spec
assert spec["speculate"]["fallback_gather_calls"] == 0, spec
assert spec["baseline"]["spec_steps"] == 0
print("speculative bench smoke OK:", spec["comparison"],
      "verdict:", spec["verdict"])
PY

echo "== HTTP frontend smoke (SSE streaming + fork parity, DESIGN.md §15) =="
python -m repro.launch.serve --http --port 0 --max-pages 256 \
  --admission fairshare --speculate --spec-k 3 --proposer ngram_cache \
  > /tmp/forkkv_http.log 2>&1 &
HTTP_PID=$!
trap 'kill $HTTP_PID 2>/dev/null || true' EXIT
for _ in $(seq 120); do
  grep -q "on http://" /tmp/forkkv_http.log && break
  sleep 1
done
HTTP_PORT=$(sed -n 's#.*on http://[^:]*:\([0-9]*\).*#\1#p' /tmp/forkkv_http.log)
test -n "$HTTP_PORT" || { cat /tmp/forkkv_http.log; exit 1; }
HTTP_PORT="$HTTP_PORT" python - <<'PY'
import os
import numpy as np
from repro.launch.serve import build_server
from repro.serving.frontend import ForkClient
from repro.serving.sampling import SamplingParams

client = ForkClient(port=int(os.environ["HTTP_PORT"]))
assert client.healthz()
rng = np.random.default_rng(0)
ctx = [int(t) for t in rng.integers(0, 1000, 96)]
instr = ctx[:8]   # re-quotes the context, so the proposer has material

# streamed SSE completions through a forked session, SPECULATION ON
# (--speculate on the server); the identical second fork replays the
# first's trajectory out of the warmed ngram cache
sid = client.create_session(ctx, adapter_id=0)
runs = []
for _ in range(2):
    events = list(client.stream_fork(sid, instr, adapter_id=1,
                                     max_new_tokens=8))
    streamed = [e["token"] for e in events if not e.get("finished")]
    assert events[-1]["finished"] and len(streamed) == 8, events[-1]
    assert streamed == events[-1]["tokens"]
    runs.append(streamed)
client.close_session(sid)
assert runs[0] == runs[1], runs

# ...must match the speculation-OFF in-process API token-for-token
# (greedy ON==OFF parity over HTTP), with the paged path never falling
# back to gather
server, _ = build_server("forkkv", max_pages=256, admission="fairshare")
sess = server.session(ctx, adapter_id=0)
expected = sess.fork(1, instr,
                     SamplingParams(max_new_tokens=8)).result().tokens
assert runs[0] == expected, (runs[0], expected)
m = client.metrics()
assert m["fallback_gather_calls"] == 0, m["fallback_gather_calls"]
assert m["queue_depth"] == 0 and m["admission"] == "fairshare"
assert m["speculate"] and m["spec_accepted_tokens"] > 0, \
    (m["speculate"], m["spec_proposed_tokens"], m["spec_accepted_tokens"])
print("http smoke OK: spec-on parity", len(runs[0]), "tokens,",
      "acceptance:", round(m["spec_acceptance_rate"], 3),
      "tenants:", list(m["tenants"]))
PY
echo "== graceful drain (SIGTERM mid-stream, DESIGN.md §17) =="
HTTP_PORT="$HTTP_PORT" HTTP_PID="$HTTP_PID" python - <<'PY'
import os
import signal
import time

import numpy as np

from repro.serving.frontend import ForkClient, HttpError

client = ForkClient(port=int(os.environ["HTTP_PORT"]))
rng = np.random.default_rng(1)
prompt = [int(t) for t in rng.integers(0, 1000, 48)]

# one stream in flight, then SIGTERM: the stream must run to completion
# while new work is refused with 503 + finish_reason="draining".  The
# generation is long so the drain window is comfortably open when the
# refusal probe lands (a short stream drains in milliseconds and the
# server exits before the probe connects).
stream = client.stream_completion(prompt, max_new_tokens=128)
first = next(stream)
os.kill(int(os.environ["HTTP_PID"]), signal.SIGTERM)
time.sleep(0.1)
try:
    client.completion(prompt[:32], max_new_tokens=2)
    raise SystemExit("new request admitted during drain")
except HttpError as exc:
    assert exc.status == 503, exc.status
    assert exc.doc.get("finish_reason") == "draining", exc.doc
    assert float(exc.headers.get("retry-after", 0)) >= 1.0
events = [first] + list(stream)
assert events[-1]["finished"] and len(events[-1]["tokens"]) == 128, events[-1]
print("drain OK: in-flight stream finished, new requests 503")
PY
DRAIN_RC=0
wait $HTTP_PID || DRAIN_RC=$?
test "$DRAIN_RC" -eq 0 || {
  echo "drained server exited rc=$DRAIN_RC"; cat /tmp/forkkv_http.log; exit 1; }
trap - EXIT

echo "== KV persist/restore across restart (DESIGN.md §18) =="
PERSIST_DIR=$(mktemp -d)
start_persist_server() {
  python -m repro.launch.serve --http --port 0 --max-pages 256 \
    --persist-dir "$PERSIST_DIR" --kv-codec zstd \
    > "$1" 2>&1 &
  PERSIST_PID=$!
  trap 'kill $PERSIST_PID 2>/dev/null || true' EXIT
  for _ in $(seq 120); do
    grep -q "on http://" "$1" && break
    sleep 1
  done
  PERSIST_PORT=$(sed -n 's#.*on http://[^:]*:\([0-9]*\).*#\1#p' "$1")
  test -n "$PERSIST_PORT" || { cat "$1"; exit 1; }
}
start_persist_server /tmp/forkkv_persist1.log
HTTP_PORT="$PERSIST_PORT" PHASE=record python - <<'PY'
import json
import os

import numpy as np

from repro.serving.frontend import ForkClient

client = ForkClient(port=int(os.environ["HTTP_PORT"]))
rng = np.random.default_rng(7)
ctx = [int(t) for t in rng.integers(0, 1000, 96)]
sid = client.create_session(ctx, adapter_id=0)
doc = client.fork(sid, ctx[:8], adapter_id=1, max_new_tokens=8)
client.close_session(sid)
assert len(doc["tokens"]) == 8, doc
json.dump({"ctx": ctx, "tokens": doc["tokens"]},
          open("/tmp/forkkv_persist_ref.json", "w"))
print("recorded", len(doc["tokens"]), "tokens before shutdown")
PY
kill -TERM $PERSIST_PID
wait $PERSIST_PID || { cat /tmp/forkkv_persist1.log; exit 1; }
grep -q "persist: wrote" /tmp/forkkv_persist1.log || {
  echo "server did not persist on shutdown"; cat /tmp/forkkv_persist1.log
  exit 1; }
test -s "$PERSIST_DIR/manifest.json" || {
  echo "missing persist manifest"; ls -la "$PERSIST_DIR"; exit 1; }
start_persist_server /tmp/forkkv_persist2.log
grep -q "restore: rehydrated" /tmp/forkkv_persist2.log || {
  echo "restarted server did not restore"; cat /tmp/forkkv_persist2.log
  exit 1; }
HTTP_PORT="$PERSIST_PORT" python - <<'PY'
import json
import os

from repro.serving.frontend import ForkClient

ref = json.load(open("/tmp/forkkv_persist_ref.json"))
client = ForkClient(port=int(os.environ["HTTP_PORT"]))
# the SAME shared context on the restarted server: rehydrated pages must
# serve it as tier hits (no full re-prefill), and the forked greedy
# continuation must be token-identical to the pre-restart run
sid = client.create_session(ref["ctx"], adapter_id=0)
doc = client.fork(sid, ref["ctx"][:8], adapter_id=1, max_new_tokens=8)
client.close_session(sid)
assert doc["tokens"] == ref["tokens"], (doc["tokens"], ref["tokens"])
m = client.metrics()
assert m["restored_pages"] > 0, "nothing was rehydrated"
assert m["tier_hits"] > 0, "restored context was not promoted"
assert m["hit_tokens"] > 0, "session prefill missed the restored prefix"
print(f"persist/restore OK: {m['restored_pages']} pages rehydrated, "
      f"tier_hits={m['tier_hits']}, tokens identical across restart")
PY
kill -TERM $PERSIST_PID
wait $PERSIST_PID || { cat /tmp/forkkv_persist2.log; exit 1; }
trap - EXIT
echo "smoke OK"
