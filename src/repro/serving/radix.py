"""Radix trees over token sequences + the ForkKV DualRadixTree (paper §5.2).

A RadixTree maps token sequences to lists of KV pages (page_size tokens per
page).  Nodes are page-aligned segments; matched pages are shared zero-copy
via the pool's refcounts.  Eviction is LRU over *leaf* nodes, never evicting
nodes locked by in-flight requests.

With a tiered pool (``pool.is_tiered``, DESIGN.md §10) eviction first tries
to DEMOTE the victim to host memory: the node stays in the tree tagged
``tier == "host"`` with its ``pages`` list holding host handles, and its
device pages are freed.  ``match_prefix`` transparently PROMOTES host-tier
nodes back into device pages as it walks (a *tier hit*), locking the path
while it works so concurrent eviction pressure cannot free pages under the
match.  Only when both the device pool and the host budget are exhausted
does eviction destroy bytes (the seed behaviour).

DualRadixTree composes two trees with DECOUPLED lifecycles:
  * base tree    — key = token ids           → bCache pages (shared across
    agents, the "parent process pages")
  * residual tree— key = (adapter id ‖ ids)  → rCache pages (per-agent CoW
    footprint, the "child process pages")

``fork()`` implements the OS-style fork: longest-prefix match inherits the
shared bCache, then exclusive rCache pages are allocated (copy-on-write).
A *partial hit* (base evicted, residual alive — or vice versa) degrades
gracefully: only the missing component is recomputed (paper's decoupled
eviction policy).
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.pool import PagePool

_counter = itertools.count()


def _adjust_chain(node: Optional["Node"], attr: str, delta: int) -> None:
    """Adjust a reference counter (``lock_ref``/``pin_ref``) along the
    CURRENT parent chain from ``node`` to the root.  The shared walk for
    lock release and pin take/release: counters cover the whole path and
    survive splits (the head copies them), so correctness depends on
    walking parents as they are NOW, not as they were recorded."""
    while node is not None:
        value = getattr(node, attr) + delta
        assert value >= 0, (attr, value)
        setattr(node, attr, value)
        node = node.parent


class Node:
    __slots__ = ("key", "pages", "children", "parent", "last_access",
                 "lock_ref", "pin_ref", "tier", "warm")

    def __init__(self, key: Tuple[int, ...], pages: List[int],
                 parent: Optional["Node"]):
        self.key = key                  # token segment (page-aligned length)
        self.pages = pages              # device pages, or host handles when
                                        # tier == "host" (DESIGN.md §10)
        self.children: Dict[int, Node] = {}
        self.parent = parent
        self.last_access = next(_counter)
        self.lock_ref = 0               # transient: held per in-flight request
        self.pin_ref = 0                # long-lived: held per AgentSession
                                        # (DESIGN.md §11) — blocks eviction
                                        # AND demotion for the session's life
        self.tier = "device"            # device | host | disk
        self.warm = False               # was ever session-pinned: after the
                                        # pin drops, the context stays ranked
                                        # ABOVE cold cache in eviction order
                                        # (DESIGN.md §15) until it is
                                        # demoted/evicted once


class RadixTree:
    """Page-aligned radix tree over token sequences."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.root = Node((), [], None)
        self.hits_tokens = 0
        self.miss_tokens = 0
        self.evicted_pages = 0
        self.demoted_pages = 0

    # ----------------------------------------------------------- matching
    def match_prefix(self, tokens: Sequence[int], lock: bool = False,
                     promote: bool = True) -> Tuple[List[int], int,
                                                    List[Node]]:
        """Longest page-aligned prefix match.

        Returns (pages, matched_tokens, path_nodes).  If ``lock``, every
        node on the path gets lock_ref+1 (caller must unlock_path later).

        The path is locked incrementally DURING the walk (and unlocked at
        the end unless ``lock``): with a tiered pool, promoting a host-tier
        node may apply eviction pressure, and the walk's own pages must not
        be demoted under it.  Host-tier nodes on the path are promoted back
        to device pages (a tier hit); a failed promotion truncates the
        match — a graceful partial hit, never a corrupt one.

        ``promote=False`` (used by :meth:`insert`, which only needs the
        match POSITION) traverses host-tier nodes without touching their
        bytes instead of paying H2D copies for pages the caller will
        never read; the returned ``pages`` then cover only the device
        portion and may be shorter than ``matched`` implies.
        """
        tokens = tuple(tokens)
        page = self.pool.page_size
        tiered = getattr(self.pool, "is_tiered", False)
        if tiered:
            self.pool.begin_match()
        node = self.root
        pages: List[int] = []
        matched = 0
        path = [self.root]
        self.root.lock_ref += 1
        try:
            while matched < len(tokens):
                child = node.children.get(tokens[matched])
                if child is None:
                    break
                rest = tokens[matched:]
                common = 0
                for a, b in zip(child.key, rest):
                    if a != b:
                        break
                    common += 1
                common = (common // page) * page   # page-aligned sharing only
                if common == 0:
                    break
                if common < len(child.key):
                    child = self._split(child, common)  # split; take the head
                if child.tier != "device" and promote:
                    room = self.pool.promote_room() if tiered else None
                    if room == 0:
                        break            # per-match promote budget spent
                    if room is not None and len(child.pages) > room:
                        # promote only the head the budget allows; the tail
                        # stays on host for a later match to pick up
                        child = self._split(child, room * page)
                child.lock_ref += 1
                try:
                    ok = child.tier == "device" or not promote or (
                        tiered and self.pool.promote_node(child))
                except BaseException:
                    child.lock_ref -= 1
                    raise
                if not ok:
                    child.lock_ref -= 1
                    break                # host budget / device pool exhausted
                if child.tier == "device":
                    pages.extend(child.pages)
                matched += len(child.key)
                node = child
                node.last_access = next(_counter)
                path.append(node)
        except BaseException:
            # a failed promotion copy must not leave the walk's locks
            # behind — a leaked lock pins pages against eviction forever
            self.unlock_path(path)
            raise
        if not lock:
            self.unlock_path(path)
        return pages, matched, path

    def _split(self, child: Node, keep: int) -> Node:
        """Split ``child`` at page-aligned token offset ``keep``; returns the
        new head node covering key[:keep]."""
        page = self.pool.page_size
        assert keep % page == 0 and 0 < keep < len(child.key)
        kp = keep // page
        head = Node(child.key[:keep], child.pages[:kp], child.parent)
        head.last_access = child.last_access
        head.lock_ref = child.lock_ref       # locks cover the whole path
        head.pin_ref = child.pin_ref         # ...and so do session pins
        head.warm = child.warm               # ...and the warmth marker
        head.tier = child.tier
        if head.tier != "device" and getattr(self.pool, "is_tiered", False):
            self.pool.retarget(head.pages, head)   # handles moved to head
        child.parent.children[head.key[0]] = head
        child.key = child.key[keep:]
        child.pages = child.pages[kp:]
        child.parent = head
        head.children[child.key[0]] = child
        return head

    def unlock_path(self, path: List[Node]) -> None:
        """Release the locks taken by a previous match (lock=True).

        Walks the CURRENT parent chain from the deepest locked node rather
        than the recorded list: a later match may have split a locked node,
        copying the lock onto the new head — a node the recorded list
        cannot know about.  Every node on the chain carries exactly one
        lock per locker, so one decrement each settles the account (and
        with tiers, leaves nothing permanently pinned against eviction).
        """
        if path:
            _adjust_chain(path[-1], "lock_ref", -1)

    # ------------------------------------------------------------- pinning
    def pin(self, tokens: Sequence[int]) -> Tuple[List[Node], int]:
        """Pin the cached prefix of ``tokens`` against eviction/demotion.

        Session-lifetime locks (DESIGN.md §11), DISTINCT from the transient
        per-request ``lock_ref`` a match takes: a pin survives arbitrarily
        many requests and is only dropped by :meth:`unpin` (session close).
        Returns ``(path, matched_tokens)``; the caller keeps the path as
        its unpin handle.  Host-tier nodes on the path are promoted first
        (a pinned prefix is always device-resident).
        """
        _, matched, path = self.match_prefix(tokens)
        # pins cover the whole path, same convention as locks
        _adjust_chain(path[-1], "pin_ref", +1)
        # session-aware eviction rank (DESIGN.md §15): mark the pinned
        # context warm, so after unpin it still outranks cold cache in
        # LRU order — an agent tree's context is the likeliest re-hit
        for node in path:
            node.warm = True
        return path, matched

    def unpin(self, path: List[Node]) -> None:
        """Release a session pin.  Walks the CURRENT parent chain from the
        deepest pinned node (splits copy ``pin_ref`` onto new heads exactly
        as they copy ``lock_ref`` — see :meth:`unlock_path`)."""
        if path:
            _adjust_chain(path[-1], "pin_ref", -1)

    # ----------------------------------------------------------- insertion
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Insert a fully page-aligned sequence owning ``pages``.

        The tree takes one reference on every NEW page it stores (caller
        keeps its own reference).  Returns number of pages newly adopted.
        """
        tokens = tuple(tokens)
        page_size = self.pool.page_size
        assert len(pages) >= len(tokens) // page_size, \
            "pages must cover every full page of tokens"
        _, matched, path = self.match_prefix(tokens, promote=False)
        node = path[-1]
        # only full pages are insertable; trailing partial page stays private
        full_tokens = (len(tokens) // page_size) * page_size
        if matched >= full_tokens:
            return 0
        new_tokens = tokens[matched:full_tokens]
        new_pages = list(pages[matched // page_size:full_tokens // page_size])
        if not new_pages:
            return 0
        if new_tokens[0] in node.children:
            # sibling shares a sub-page prefix: pages are page-granular so
            # nothing can be shared — skip the insert (rare; documented
            # limitation of page-aligned radix caching, as in SGLang)
            return 0
        child = Node(tuple(new_tokens), new_pages, node)
        node.children[new_tokens[0]] = child
        self.pool.incref(new_pages)
        return len(new_pages)

    def graft_host(self, tokens: Sequence[int], blobs) -> int:
        """Attach a host-tier node holding ``blobs`` for the page-aligned
        suffix of ``tokens`` not already present (restore path, DESIGN.md
        §18).  ``blobs`` are LOGICAL (decoded) page blobs covering exactly
        the suffix; they are encoded with the pool's codec and stored in
        the host tier, so the first match promotes them like any demoted
        node — the restored context costs zero device pages until used.

        Restores are best-effort: a sub-page divergence from existing
        content, a missing tier, or a full host budget skips the graft
        (returns 0) rather than failing the restart.
        """
        if not getattr(self.pool, "is_tiered", False):
            return 0
        tokens = tuple(tokens)
        page_size = self.pool.page_size
        node = self.root
        matched = 0
        # whole-segment walk only: persist records arrive parent-first, so
        # the prefix (if restored) exists as complete nodes
        while matched < len(tokens):
            child = node.children.get(tokens[matched])
            if child is None or tokens[matched:matched + len(child.key)] \
                    != child.key:
                break
            matched += len(child.key)
            node = child
        new_tokens = tokens[matched:]
        if not new_tokens or len(new_tokens) != len(blobs) * page_size:
            return 0
        if new_tokens[0] in node.children:
            return 0
        handles = self.pool.host_put_blobs(blobs)
        if handles is None:
            return 0
        child = Node(tuple(new_tokens), handles, node)
        child.tier = "host"
        node.children[new_tokens[0]] = child
        self.pool.adopt_host_handles(handles, child)
        return len(handles)

    # ------------------------------------------------------------ eviction
    def _leaves(self) -> List[Node]:
        """Device-frontier nodes: device-resident with no device-resident
        descendant.  For a non-tiered pool this is exactly the leaf set;
        with tiers it lets eviction walk UP the tree as leaves demote."""
        out = []
        root = self.root

        def walk(n: Node) -> bool:           # subtree holds a device node?
            has_device_below = False
            for c in n.children.values():
                if walk(c):
                    has_device_below = True
            is_device = n is not root and n.tier == "device"
            if is_device and not has_device_below:
                out.append(n)
            return is_device or has_device_below

        walk(root)
        return out

    def evict(self, n_pages: int) -> int:
        """Free ≥ n_pages device pages from LRU unlocked victims.

        Tiered pool: victims are DEMOTED to the host tier (node survives,
        bytes preserved) and only truly evicted when the host budget is
        exhausted too.  Non-tiered: destroy, as in the seed engine.
        """
        freed = 0
        skipped = set()
        while freed < n_pages:
            leaves = [l for l in self._leaves()
                      if l.lock_ref == 0 and l.pin_ref == 0
                      and id(l) not in skipped]
            if not leaves:
                break
            # cold cache first: unpinned-but-warm session contexts rank
            # above never-pinned nodes, falling back to plain LRU within
            # each class (DESIGN.md §15)
            victim = min(leaves, key=lambda n: (n.warm, n.last_access))
            got = _evict_one(self, victim)
            if got == 0:
                skipped.add(id(victim))
                continue
            freed += got
        return freed

    def total_nodes(self) -> int:
        n = 0

        def walk(node):
            nonlocal n
            n += 1
            for c in node.children.values():
                walk(c)

        walk(self.root)
        return n - 1


def _evict_one(owner, victim: Node) -> int:
    """Demote (tiered pool) or destroy one victim node.

    ``owner`` is the RadixTree or ResidualForest doing the eviction (it
    carries ``pool`` and the evicted/demoted counters).  Returns the number
    of device pages that ACTUALLY became free (a destroyed victim whose
    pages are still co-owned by a running request frees nothing yet —
    reporting its page count would let allocation pressure falsely claim
    room was made).  ``evicted_pages`` still counts cache entries lost.
    A demoted victim stays in the tree; a destroyed one is unlinked,
    taking any host-tier children with it (a device-frontier victim has
    no device-resident descendants, so nothing else can be orphaned).
    """
    pool = owner.pool
    n = len(victim.pages)
    victim.warm = False          # a pushed-out context spent its warmth:
                                 # next time it competes as plain LRU
    if getattr(pool, "is_tiered", False):
        if pool.demote_node(victim):
            owner.demoted_pages += n
            return n                     # refcount==1 guard: all freed
        if victim.children and any(pool.refcount(p) > 1
                                   for p in victim.pages):
            # transiently shared (e.g. a broadcast co-owner still running)
            # with preserved host state below: destroying it would lose
            # the subtree as collateral — skip, let the caller try the
            # next LRU candidate
            return 0
        freed = len(pool.decref(victim.pages))
        for child in list(victim.children.values()):
            pool._drop_subtree(child)
        del victim.parent.children[victim.key[0]]
        owner.evicted_pages += n
        return freed
    freed = len(pool.decref(victim.pages))
    del victim.parent.children[victim.key[0]]
    owner.evicted_pages += n
    return freed


class ForkResult:
    __slots__ = ("base_pages", "base_len", "res_pages", "res_len",
                 "reuse_len", "base_path", "res_path", "hit_kind")

    def __init__(self, base_pages, base_len, res_pages, res_len, reuse_len,
                 base_path, res_path, hit_kind):
        self.base_pages = base_pages
        self.base_len = base_len
        self.res_pages = res_pages
        self.res_len = res_len
        self.reuse_len = reuse_len      # tokens whose BOTH caches are live
        self.base_path = base_path
        self.res_path = res_path
        self.hit_kind = hit_kind        # full | partial_base | partial_res |
                                        # partial_both | miss


class ResidualForest:
    """The residual radix tree: Key_res = (adapter id ‖ token ids).

    Implemented as one namespace (sub-tree) per adapter id over a SHARED
    page pool — equivalent to prefixing the key with the agent id (paper
    §5.2) while keeping every namespace page-aligned.  LRU eviction is
    global across namespaces (one lifecycle for the whole rCache pool).
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.trees: Dict[int, RadixTree] = {}
        self.evicted_pages = 0
        self.demoted_pages = 0

    def tree(self, adapter_id: int) -> RadixTree:
        if adapter_id not in self.trees:
            self.trees[adapter_id] = RadixTree(self.pool)
        return self.trees[adapter_id]

    def match_prefix(self, adapter_id: int, tokens, lock=False):
        return self.tree(adapter_id).match_prefix(tokens, lock=lock)

    def insert(self, adapter_id: int, tokens, pages) -> int:
        return self.tree(adapter_id).insert(tokens, pages)

    def pin(self, adapter_id: int, tokens) -> Tuple[List[Node], int]:
        return self.tree(adapter_id).pin(tokens)

    def unpin(self, adapter_id: int, path: List[Node]) -> None:
        self.tree(adapter_id).unpin(path)

    def evict(self, n_pages: int) -> int:
        """Global LRU across namespaces; demotes before destroying (tiered
        pools), exactly as :meth:`RadixTree.evict`."""
        freed = 0
        skipped = set()
        while freed < n_pages:
            candidates = []
            for t in self.trees.values():
                candidates.extend(l for l in t._leaves()
                                  if l.lock_ref == 0 and l.pin_ref == 0
                                  and id(l) not in skipped)
            if not candidates:
                break
            victim = min(candidates, key=lambda n: (n.warm, n.last_access))
            got = _evict_one(self, victim)
            if got == 0:
                skipped.add(id(victim))
                continue
            freed += got
        return freed


class DualRadixTree:
    """ForkKV's coordinated dual-tree storage with fork/CoW semantics."""

    def __init__(self, base_pool: PagePool, res_pool: PagePool):
        self.base = RadixTree(base_pool)
        self.residual = ResidualForest(res_pool)
        self.fork_count = 0
        self.hit_kinds: Dict[str, int] = {}

    def fork(self, tokens: Sequence[int], adapter_id: int,
             lock: bool = True) -> ForkResult:
        """OS-style fork: inherit shared bCache, locate private rCache."""
        self.fork_count += 1
        b_pages, b_len, b_path = self.base.match_prefix(tokens, lock=lock)
        r_pages, r_len, r_path = self.residual.match_prefix(
            adapter_id, tokens, lock=lock)
        reuse = min(b_len, r_len)
        if b_len == 0 and r_len == 0:
            kind = "miss"
        elif reuse == b_len == r_len and reuse > 0:
            kind = "full"
        elif b_len < r_len:
            kind = "partial_base"       # base evicted: recompute xW only
        elif r_len < b_len:
            kind = "partial_res"        # residual missing: CoW-fill xA_i
        else:
            kind = "partial_both" if reuse else "miss"
        self.hit_kinds[kind] = self.hit_kinds.get(kind, 0) + 1
        # the paper's cache-hit metric (Fig 14b) counts bCache reuse: the
        # massive shared component; rCache reuse additionally skips the
        # residual prefill entirely (full hit)
        self.base.hits_tokens += b_len
        self.base.miss_tokens += len(tokens) - b_len
        self.residual.tree(adapter_id).hits_tokens += r_len
        self.residual.tree(adapter_id).miss_tokens += len(tokens) - r_len
        return ForkResult(b_pages, b_len, r_pages, r_len, reuse,
                          b_path if lock else None,
                          r_path if lock else None, kind)

    def commit(self, tokens: Sequence[int], adapter_id: int,
               base_pages: Sequence[int], res_pages: Sequence[int]) -> None:
        """After generation: publish this agent's caches into both trees."""
        self.base.insert(tokens, base_pages)
        self.residual.insert(adapter_id, tokens, res_pages)

    def pin(self, tokens: Sequence[int], adapter_id: int):
        """Session pin over BOTH trees: the shared bCache prefix plus the
        session adapter's rCache prefix (DESIGN.md §11)."""
        b_path, b_len = self.base.pin(tokens)
        r_path, r_len = self.residual.pin(adapter_id, tokens)
        return (b_path, r_path, min(b_len, r_len))

    def unpin(self, handle, adapter_id: int) -> None:
        b_path, r_path, _ = handle
        self.base.unpin(b_path)
        self.residual.unpin(adapter_id, r_path)

    def release(self, fr: ForkResult, adapter_id: int) -> None:
        if fr.base_path is not None:
            self.base.unlock_path(fr.base_path)
        if fr.res_path is not None:
            self.residual.tree(adapter_id).unlock_path(fr.res_path)
