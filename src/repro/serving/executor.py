"""Paged model executor: jit'd prefill/decode over pooled KV pages.

The pools are jnp arrays of shape (L, num_pages, page_size, ...); requests
address them through block tables.  In ForkKV mode two pools exist — the
shared bCache pool and the per-agent rCache pool — and attention runs over
the disaggregated layout.

Decode AND prefill are page-native (DESIGN.md §12/§13): the jitted steps
hand the pools and per-request block tables straight to the
``paged_residual_attention`` / ``paged_residual_attention_prefill``
dispatchers (``kernels/ops.py``) — the Pallas kernels on TPU, their XLA
gather mirrors elsewhere — so HBM traffic scales with each request's
actual ``kv_len`` instead of the engine-wide ``smax``.  Sliding-window
models run through the same kernels (the page walk clamps to the trailing
``ceil(window/page)+1`` pages).  The legacy gather-to-contiguous paths
survive behind ``ServeConfig.use_paged_kernel = False`` for bit-parity
testing; every executor call that takes them increments
``fallback_gather_calls`` so any remaining fallback is visible in
``Engine.metrics()``.  Compiled shapes are bucketed: batches pad to the
next power of two (capped at ``max_batch`` / the prefill plan) and paged
block-table widths to the next power of two of the batch's live page
count (floor ``ServeConfig.min_table_pages``), so the number of compiled
variants stays logarithmic under fluctuating load instead of retracing
per batch size.

Prefill is batched: ``prefill_batch`` packs several requests' chunks into
one padded ``(B, chunk)`` call (the engine schedules co-resident chunks
under the ``max_prefill_tokens`` budget).  Executor methods return DEVICE
arrays — no host syncs here; the engine blocks once per step.

CoW discipline: prefill never writes to inherited (shared) pages — the
engine passes the reserved DUMP page as the write target for positions
whose cache is inherited, so parent pages stay read-only.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig, ServeConfig
from repro.kernels import ops as kernel_ops
from repro.models import base
from repro.models import transformer as tfm
from repro.serving.sampling import sample_tokens

Params = Dict


def _pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 << max(0, n - 1).bit_length()


class Pools(NamedTuple):
    kb: jnp.ndarray          # (L, Pb, page, Hkv, hd)  base K (RoPE'd)
    vb: jnp.ndarray          # (L, Pb, page, Hkv, hd)  base V
    kr: Optional[jnp.ndarray]  # (L, Pr, page, R)      residual K (no RoPE)
    vr: Optional[jnp.ndarray]
    # int8 bCache pages (ModelConfig.kv_quant == "int8"): per-token-per-head
    # f32 dequant scales, written alongside every kb/vb write.  None on the
    # full-precision path; the rCache is rank-r and stays unquantized.
    kb_s: Optional[jnp.ndarray] = None   # (L, Pb, page, Hkv)
    vb_s: Optional[jnp.ndarray] = None


def make_pools(cfg: ModelConfig, num_pages: int, num_res_pages: int,
               page_size: int, disagg: bool, dtype=None) -> Pools:
    dt = dtype or cfg.activation_dtype
    L, hd = cfg.num_layers, cfg.resolved_head_dim
    quant = getattr(cfg, "kv_quant", "none") == "int8"
    kb = jnp.zeros((L, num_pages, page_size, cfg.num_kv_heads, hd),
                   jnp.int8 if quant else dt)
    vb = jnp.zeros_like(kb)
    if disagg:
        kr = jnp.zeros((L, num_res_pages, page_size, cfg.lora.rank), dt)
        vr = jnp.zeros_like(kr)
    else:
        kr = vr = None
    kb_s = vb_s = None
    if quant:
        kb_s = jnp.zeros((L, num_pages, page_size, cfg.num_kv_heads),
                         jnp.float32)
        vb_s = jnp.zeros_like(kb_s)
    return Pools(kb, vb, kr, vr, kb_s, vb_s)


def pool_bytes(pools: Pools) -> Dict[str, int]:
    out = {"base": int(pools.kb.nbytes + pools.vb.nbytes)}
    if pools.kb_s is not None:
        out["base"] += int(pools.kb_s.nbytes + pools.vb_s.nbytes)
    out["residual"] = int(pools.kr.nbytes + pools.vr.nbytes) \
        if pools.kr is not None else 0
    return out


class PagedExecutor:
    """Compiled paged prefill/decode for llama-family models."""

    def __init__(self, cfg: ModelConfig, params: Params,
                 lora: Optional[Params], serve_cfg: ServeConfig,
                 disagg: bool, max_pages_per_req: int):
        self.cfg = cfg
        self.params = params
        self.lora = lora
        self.sc = serve_cfg
        self.disagg = disagg and lora is not None
        self.page = serve_cfg.page_size
        self.max_pages_per_req = max_pages_per_req
        self.smax = max_pages_per_req * self.page
        # page-native serving: pools + block tables straight into the
        # kernel dispatchers for decode AND chunked prefill; SWA models
        # run the same kernels with window-clamped page walks (§13).
        self.use_paged = serve_cfg.use_paged_kernel
        self.min_table_pages = serve_cfg.min_table_pages
        # int8 bCache paging (DESIGN.md §18): quantize at write time,
        # dequantize per page tile inside the kernels / at the gather
        self.kv_quant = getattr(cfg, "kv_quant", "none") == "int8"
        # executor calls that took a legacy gather-to-contiguous path —
        # the acceptance probe for "zero gather copies" (0 whenever
        # use_paged_kernel=True; surfaced via Engine.metrics())
        self.fallback_gather_calls = 0
        res_factor = max(1, cfg.kv_dim // max(cfg.lora.rank, 1))             if self.disagg else 1
        self.num_res_pages = serve_cfg.max_pages * res_factor             if self.disagg else serve_cfg.max_pages
        self.pools = make_pools(cfg, serve_cfg.max_pages,
                                self.num_res_pages, self.page, self.disagg)
        # reserved scratch pages (the engine overwrites these with the pages
        # it actually allocated); residual pool has its OWN dump page
        self.dump_page = serve_cfg.max_pages - 1
        self.dump_page_r = self.num_res_pages - 1
        # ``sampled`` is static: all-greedy batches (the default) compile
        # the seed's pure-argmax body with the sampling math dead-code
        # eliminated; a second variant exists only once sampling is used
        self._decode = jax.jit(self._decode_fn, donate_argnums=(0,),
                               static_argnames=("sampled",))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(0,),
                                static_argnames=("chunk", "sampled",
                                                 "unified", "verify"))

    # ------------------------------------------------ tiered KV offload
    def export_pages(self, kind: str,
                     page_ids: Sequence[int]) -> List[Dict]:
        """Device→host copy of whole KV pages (DESIGN.md §10).

        ``kind`` selects the pool ("base" → kb/vb, "res" → kr/vr).  Returns
        one blob per page — ``{"k": (L, page, ...), "v": ...}`` numpy
        arrays holding the exact bytes, so a later :meth:`import_pages`
        restores the cache bit-identically.
        """
        ids = jnp.asarray(list(page_ids), jnp.int32)
        if kind == "base":
            k, v = self.pools.kb, self.pools.vb
        else:
            k, v = self.pools.kr, self.pools.vr
        karr = np.asarray(k[:, ids])          # (L, n, page, ...)
        varr = np.asarray(v[:, ids])
        # per-page COPIES, not views: each blob must be independently
        # freeable or the HostTier's byte accounting undercounts (a
        # surviving 1-page view would pin the whole n-page export)
        blobs = [{"k": karr[:, i].copy(), "v": varr[:, i].copy()}
                 for i in range(len(page_ids))]
        if kind == "base" and self.kv_quant:
            # int8 pages travel with their dequant scales so a round trip
            # through host/disk restores the cache bit-identically
            ksarr = np.asarray(self.pools.kb_s[:, ids])
            vsarr = np.asarray(self.pools.vb_s[:, ids])
            for i, b in enumerate(blobs):
                b["ks"] = ksarr[:, i].copy()
                b["vs"] = vsarr[:, i].copy()
        return blobs

    def import_pages(self, kind: str, page_ids: Sequence[int],
                     blobs: Sequence[Dict]) -> None:
        """Host→device copy: write blobs back into freshly allocated pages
        (the promotion half of the tier lifecycle).

        The scatter runs jitted with the pools donated, so XLA updates the
        pool buffers in place — O(pages promoted), not a copy of the whole
        pool.  Page counts are bucketed to powers of two (padding repeats
        page 0 with its own blob: duplicate index, identical value) so the
        number of compiled variants stays logarithmic.
        """
        n = len(page_ids)
        npad = _pow2(n)
        ids = list(page_ids) + [page_ids[0]] * (npad - n)
        blobs = list(blobs) + [blobs[0]] * (npad - n)
        k = jnp.asarray(np.stack([b["k"] for b in blobs], axis=1))
        v = jnp.asarray(np.stack([b["v"] for b in blobs], axis=1))
        quant = kind == "base" and self.kv_quant
        key = (kind, npad)
        if not hasattr(self, "_import_jit"):
            self._import_jit = {}
        if key not in self._import_jit:
            if quant:
                def fn(pools, ids_, k_, v_, ks_, vs_):
                    return pools._replace(
                        kb=pools.kb.at[:, ids_].set(k_),
                        vb=pools.vb.at[:, ids_].set(v_),
                        kb_s=pools.kb_s.at[:, ids_].set(ks_),
                        vb_s=pools.vb_s.at[:, ids_].set(vs_))
            elif kind == "base":
                def fn(pools, ids_, k_, v_):
                    return pools._replace(
                        kb=pools.kb.at[:, ids_].set(k_),
                        vb=pools.vb.at[:, ids_].set(v_))
            else:
                def fn(pools, ids_, k_, v_):
                    return pools._replace(
                        kr=pools.kr.at[:, ids_].set(k_),
                        vr=pools.vr.at[:, ids_].set(v_))
            self._import_jit[key] = jax.jit(fn, donate_argnums=(0,))
        if quant:
            ks = jnp.asarray(np.stack([b["ks"] for b in blobs], axis=1))
            vs = jnp.asarray(np.stack([b["vs"] for b in blobs], axis=1))
            self.pools = self._import_jit[key](
                self.pools, jnp.asarray(ids, jnp.int32), k, v, ks, vs)
        else:
            self.pools = self._import_jit[key](
                self.pools, jnp.asarray(ids, jnp.int32), k, v)

    # ------------------------------------------------------------ helpers
    def _layer_params(self, li):
        return jax.tree_util.tree_map(lambda t: t[li],
                                      self.params["layers"])

    def _lora_layer(self, li):
        if self.lora is None:
            return None
        return jax.tree_util.tree_map(lambda t: t[li], self.lora)

    def _project_kv(self, p_l, lora_l, h, sin, cos, adapter_ids):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        bsz, s, _ = h.shape
        k_base = (h @ p_l["wk"]).reshape(bsz, s, cfg.num_kv_heads, hd)
        v_base = (h @ p_l["wv"]).reshape(bsz, s, cfg.num_kv_heads, hd)
        if cfg.use_rope:
            from repro.core import rope as rope_lib
            k_base = rope_lib.apply_rope(k_base, sin, cos)
        if self.disagg:
            k_res = tfm._bgmv_down(h, lora_l["a_k"], lora_l["scaling"],
                                   adapter_ids)
            v_res = tfm._bgmv_down(h, lora_l["a_v"], lora_l["scaling"],
                                   adapter_ids)
            bk = lora_l["b_k"][adapter_ids]
            bv = lora_l["b_v"][adapter_ids]
            return k_base, v_base, k_res, v_res, bk, bv
        if lora_l is not None:   # unified: fold LoRA exactly into K/V
            k_off = tfm._bgmv(h, lora_l["a_k"], lora_l["b_k"],
                              lora_l["scaling"], adapter_ids)
            v_off = tfm._bgmv(h, lora_l["a_v"], lora_l["b_v"],
                              lora_l["scaling"], adapter_ids)
            k_off = k_off.reshape(bsz, s, cfg.num_kv_heads, hd)
            v_off = v_off.reshape(bsz, s, cfg.num_kv_heads, hd)
            if cfg.use_rope:
                from repro.core import rope as rope_lib
                k_off = rope_lib.apply_rope(k_off, sin, cos)
            k_base = k_base + k_off
            v_base = v_base + v_off
        return k_base, v_base, None, None, None, None

    def _pad_table(self, pages: Sequence[int], width: int,
                   dump: int) -> List[int]:
        """Crop/pad one block table to ``width`` entries."""
        bt = list(pages)[:width]
        return bt + [dump] * (width - len(bt))

    def _bucket_width(self, need: int) -> int:
        """Block-table width bucket for a batch needing ``need`` live
        pages: next power of two, floor ``min_table_pages``, capped at
        ``max_pages_per_req`` — shared by decode and prefill shapes."""
        return min(self.max_pages_per_req,
                   max(min(self.min_table_pages, self.max_pages_per_req),
                       _pow2(need)))

    def _maybe_quant(self, kb_, vb_):
        """Write-time bCache quantization (kv_quant == "int8"): the same
        per-(position, head) symmetric scheme as the dense-cache path
        (``tfm.quantize_kv``), so tier round trips stay bit-exact against
        what the kernels dequantize.  Returns (kb, vb, ks, vs) with
        ks/vs None on the full-precision path."""
        if not self.kv_quant:
            return kb_, vb_, None, None
        kq, ks = tfm.quantize_kv(kb_)
        vq, vs = tfm.quantize_kv(vb_)
        return kq, vq, ks, vs

    def _dq_gather(self, pool_l, scale_l, bt, bsz, w):
        """Legacy gather path under int8: gather pages AND scales, then
        dequantize the contiguous view (the kernels instead dequantize
        per page tile in VMEM)."""
        x = pool_l[bt].astype(jnp.float32) * scale_l[bt][..., None]
        return x.astype(self.cfg.activation_dtype).reshape(
            bsz, w, self.cfg.num_kv_heads, -1)

    # ------------------------------------------------------------- decode
    def _decode_fn(self, pools: Pools, tokens, kv_len, adapter_ids, bt_b,
                   bt_r, wpage_b, wpage_r, woff, temps, top_ks, top_ps,
                   seeds, spos, poison, *, sampled):
        """One decode step for a padded batch.

        tokens/kv_len/adapter_ids: (B,); bt_*: (B, W) block tables (W is
        the bucketed live width on the paged path, ``max_pages_per_req``
        on the gather path); wpage_*: (B,) page indices to write the new
        token's KV into (dump page for inactive rows); woff: (B,) in-page
        offsets; temps/top_ks/top_ps/seeds/spos: (B,) per-row sampling
        params (temp <= 0 -> greedy argmax, the seed's exact path);
        poison: (B,) fault-injection mask — rows > 0 get their logits
        forced to NaN in-jit (DESIGN.md §17), exercising the same
        quarantine path a real numeric blow-up takes; sampled: static —
        False compiles the argmax-only body.

        Returns ``(pools, next_tok, logits, row_ok)`` where ``row_ok`` is
        the per-row ``isfinite(logits).all()`` guard — it rides the
        step's existing single host sync, so quarantine detection costs
        zero extra syncs.
        """
        cfg = self.cfg
        bsz = tokens.shape[0]
        x = self.params["embed"][tokens][:, None]
        kmask_pos = None
        new_pools = pools
        for li in range(cfg.num_layers):
            p_l = self._layer_params(li)
            lora_l = self._lora_layer(li)
            h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            q, sin, cos = tfm._qkv(p_l, h, cfg, lora_l, adapter_ids,
                                   kv_len[:, None])
            kb_, vb_, kr_, vr_, bk, bv = self._project_kv(
                p_l, lora_l, h, sin, cos, adapter_ids)
            kb_, vb_, ks_, vs_ = self._maybe_quant(kb_, vb_)
            # write new token
            kbp = new_pools.kb.at[li, wpage_b, woff].set(kb_[:, 0])
            vbp = new_pools.vb.at[li, wpage_b, woff].set(vb_[:, 0])
            if self.kv_quant:
                ksp = new_pools.kb_s.at[li, wpage_b, woff].set(ks_[:, 0])
                vsp = new_pools.vb_s.at[li, wpage_b, woff].set(vs_[:, 0])
            else:
                ksp, vsp = new_pools.kb_s, new_pools.vb_s
            if self.disagg:
                krp = new_pools.kr.at[li, wpage_r, woff].set(kr_[:, 0])
                vrp = new_pools.vr.at[li, wpage_r, woff].set(vr_[:, 0])
            else:
                krp, vrp = new_pools.kr, new_pools.vr
            new_pools = Pools(kbp, vbp, krp, vrp, ksp, vsp)
            if self.use_paged:
                # page-native attention: pools + block tables, no gather
                attn = kernel_ops.paged_residual_attention(
                    q[:, 0], kbp[li], vbp[li],
                    krp[li] if self.disagg else None,
                    vrp[li] if self.disagg else None,
                    bk if self.disagg else None,
                    bv if self.disagg else None,
                    bt_b, bt_r if self.disagg else None, kv_len + 1,
                    scale=cfg.resolved_head_dim ** -0.5,
                    window=cfg.sliding_window,
                    rope_theta=cfg.rope_theta, use_rope=cfg.use_rope,
                    kb_scale=ksp[li] if self.kv_quant else None,
                    vb_scale=vsp[li] if self.kv_quant else None)
            else:
                # legacy: gather this request's pages -> contiguous view
                w = bt_b.shape[1] * self.page
                if self.kv_quant:
                    kc = self._dq_gather(kbp[li], ksp[li], bt_b, bsz, w)
                    vc = self._dq_gather(vbp[li], vsp[li], bt_b, bsz, w)
                else:
                    kc = kbp[li][bt_b].reshape(bsz, w, cfg.num_kv_heads, -1)
                    vc = vbp[li][bt_b].reshape(bsz, w, cfg.num_kv_heads, -1)
                if self.disagg:
                    krc = krp[li][bt_r].reshape(bsz, w, -1)
                    vrc = vrp[li][bt_r].reshape(bsz, w, -1)
                    bk_rows = bk.reshape(bsz, cfg.lora.rank, -1)
                    bv_rows = bv.reshape(bsz, cfg.lora.rank, -1)
                else:
                    krc = vrc = bk_rows = bv_rows = None
                if kmask_pos is None:
                    kmask_pos = jnp.broadcast_to(jnp.arange(w)[None],
                                                 (bsz, w))
                attn = tfm._attend(q, kc, vc, krc, vrc, bk_rows, bv_rows,
                                   kmask_pos, kv_len + 1, kv_len[:, None],
                                   cfg.sliding_window,
                                   cfg.resolved_head_dim ** -0.5, cfg,
                                   self.disagg)
            x = x + attn.reshape(bsz, 1, -1) @ p_l["wo"]
            h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + tfm.ffn(p_l, h, cfg)
        logits = tfm.unembed(self.params, x, cfg)[:, 0]
        logits = jnp.where(poison[:, None] > 0, jnp.nan, logits)
        row_ok = jnp.all(jnp.isfinite(logits), axis=-1)
        if sampled:
            next_tok = sample_tokens(logits, temps, top_ks, top_ps, seeds,
                                     spos)
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return new_pools, next_tok, logits, row_ok

    def decode(self, tokens, kv_len, adapter_ids, base_tables, res_tables,
               wpage_b, wpage_r, woff, temps=None, top_ks=None,
               top_ps=None, seeds=None, spos=None, poison=None):
        """One decode step over ``len(tokens)`` live rows.

        ``base_tables``/``res_tables`` are RAW per-request page lists; this
        method owns the shape policy: the batch pads to the next power of
        two (<= ``max_batch``) and, on the paged path, block tables
        crop/pad to the bucketed live width — so compile variants stay
        O(log max_batch · log max_pages_per_req) while per-step HBM
        traffic tracks actual ``kv_len``.  Returns DEVICE arrays
        ``(next_tok, logits, row_ok)``; rows past the live count are
        padding.
        """
        bsz = len(tokens)
        assert bsz <= self.sc.max_batch, (bsz, self.sc.max_batch)
        bpad = min(_pow2(bsz), self.sc.max_batch)
        if self.use_paged:
            width = self._bucket_width(max(kvl // self.page + 1
                                           for kvl in kv_len))
        else:
            width = self.max_pages_per_req
            self.fallback_gather_calls += 1
        bt_b = [self._pad_table(p, width, self.dump_page)
                for p in base_tables]
        bt_r = [self._pad_table(p, width, self.dump_page_r)
                for p in res_tables]
        temps = list(temps) if temps is not None else [0.0] * bsz
        top_ks = list(top_ks) if top_ks is not None else [0] * bsz
        top_ps = list(top_ps) if top_ps is not None else [1.0] * bsz
        seeds = list(seeds) if seeds is not None else [0] * bsz
        spos = list(spos) if spos is not None else [0] * bsz
        poison = list(poison) if poison is not None else [0] * bsz
        pad = bpad - bsz
        tokens = list(tokens) + [0] * pad
        kv_len = list(kv_len) + [0] * pad
        adapter_ids = list(adapter_ids) + [0] * pad
        bt_b += [[self.dump_page] * width] * pad
        bt_r += [[self.dump_page_r] * width] * pad
        wpage_b = list(wpage_b) + [self.dump_page] * pad
        wpage_r = list(wpage_r) + [self.dump_page_r] * pad
        woff = list(woff) + [0] * pad
        temps += [0.0] * pad
        top_ks += [0] * pad
        top_ps += [1.0] * pad
        seeds += [0] * pad
        spos += [0] * pad
        poison += [0] * pad
        self.pools, next_tok, logits, row_ok = self._decode(
            self.pools, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(kv_len, jnp.int32),
            jnp.asarray(adapter_ids, jnp.int32),
            jnp.asarray(bt_b, jnp.int32), jnp.asarray(bt_r, jnp.int32),
            jnp.asarray(wpage_b, jnp.int32), jnp.asarray(wpage_r, jnp.int32),
            jnp.asarray(woff, jnp.int32),
            jnp.asarray(temps, jnp.float32), jnp.asarray(top_ks, jnp.int32),
            jnp.asarray(top_ps, jnp.float32), jnp.asarray(seeds, jnp.int32),
            jnp.asarray(spos, jnp.int32), jnp.asarray(poison, jnp.int32),
            sampled=any(t > 0 for t in temps))
        return next_tok, logits, row_ok

    def decode_cache_size(self) -> int:
        """Number of compiled decode variants (bucket coverage probe)."""
        try:
            return self._decode._cache_size()
        except Exception:       # pragma: no cover - older jax
            return -1

    # ------------------------------------------------------------ prefill
    def _prefill_fn(self, pools: Pools, tokens, start, n_valid, adapter_ids,
                    bt_b, bt_r, wpages_b, wpages_r, temps, top_ks, top_ps,
                    seeds, spos, poison, *, chunk, sampled, unified=False,
                    verify=False):
        """Chunked prefill for a PADDED BATCH of requests.

        tokens: (B, chunk) padded; start: (B,) absolute position of each
        row's tokens[0]; n_valid: (B,) #real tokens per row (0 for padding
        rows); wpages_*: (B, chunk) page to write each token into (dump
        page where the cache is inherited — CoW: shared pages are never
        written); temps/top_ks/top_ps/seeds/spos: (B,) sampling params for
        each row's first generated token (sampled: static — False compiles
        the argmax-only body).

        ``unified`` (static) routes the paged attention through the mixed
        prefill/decode grid (DESIGN.md §14): same math, but each row's
        ``n_valid`` also rides into the kernel as its q-length so rows of
        wildly different lengths — decode rows padded to the chunk width
        next to full prefill chunks — share one launch with their padding
        rows masked to exact zeros.  The non-unified prefill grid instead
        leaves rows past ``n_valid`` as ignored garbage; both take their
        logits at row ``n_valid - 1``, so outputs agree.

        ``verify`` (static, DESIGN.md §16) additionally unembeds EVERY
        row position and reduces the longest greedy-accepted draft prefix
        in-jit: verify rows carry ``[t0, d_1..d_k]`` as their tokens, and
        draft ``d_{j+1}`` is accepted iff it equals the argmax after
        consuming ``[t0, d_1..d_j]`` AND every earlier draft was
        (cumprod over the match mask — no per-token host sync).  Returns
        the extended tuple ``(pools, next_tok, logits, greedy_all,
        n_acc)``; ``greedy_all[i, :n_acc[i]+1]`` is exactly the token
        run the engine commits (accepted drafts + the bonus correction
        token, whose input prefix is fully accepted so it is the true
        greedy continuation).

        ``poison``: (B,) fault-injection mask (rows > 0 → NaN logits);
        every return shape ends with ``row_ok``, the per-row isfinite
        guard on the final logits (DESIGN.md §17).
        """
        cfg = self.cfg
        bsz = tokens.shape[0]
        positions = start[:, None] + jnp.arange(chunk)[None]    # (B, chunk)
        x = self.params["embed"][tokens]                        # (B, chunk, d)
        woff = positions % self.page
        valid = jnp.arange(chunk)[None] < n_valid[:, None]      # (B, chunk)
        new_pools = pools
        for li in range(cfg.num_layers):
            p_l = self._layer_params(li)
            lora_l = self._lora_layer(li)
            h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            q, sin, cos = tfm._qkv(p_l, h, cfg, lora_l, adapter_ids,
                                   positions)
            kb_, vb_, kr_, vr_, bk, bv = self._project_kv(
                p_l, lora_l, h, sin, cos, adapter_ids)
            kb_, vb_, ks_, vs_ = self._maybe_quant(kb_, vb_)
            wp_b = jnp.where(valid, wpages_b, self.dump_page)
            wp_r = jnp.where(valid, wpages_r, self.dump_page_r)
            kbp = new_pools.kb.at[li, wp_b, woff].set(kb_)
            vbp = new_pools.vb.at[li, wp_b, woff].set(vb_)
            if self.kv_quant:
                ksp = new_pools.kb_s.at[li, wp_b, woff].set(ks_)
                vsp = new_pools.vb_s.at[li, wp_b, woff].set(vs_)
            else:
                ksp, vsp = new_pools.kb_s, new_pools.vb_s
            if self.disagg:
                krp = new_pools.kr.at[li, wp_r, woff].set(kr_)
                vrp = new_pools.vr.at[li, wp_r, woff].set(vr_)
            else:
                krp, vrp = new_pools.kr, new_pools.vr
            new_pools = Pools(kbp, vbp, krp, vrp, ksp, vsp)
            if self.use_paged and unified:
                # unified mixed grid (§14): per-row q-length scalar
                # prefetch — decode rows (n_valid=1) and prefill chunks
                # attend in ONE launch, padding rows exact-zeroed
                attn = kernel_ops.paged_residual_attention_mixed(
                    q, kbp[li], vbp[li],
                    krp[li] if self.disagg else None,
                    vrp[li] if self.disagg else None,
                    bk if self.disagg else None,
                    bv if self.disagg else None,
                    bt_b, bt_r if self.disagg else None, start, n_valid,
                    start + n_valid, scale=cfg.resolved_head_dim ** -0.5,
                    window=cfg.sliding_window, rope_theta=cfg.rope_theta,
                    use_rope=cfg.use_rope,
                    kb_scale=ksp[li] if self.kv_quant else None,
                    vb_scale=vsp[li] if self.kv_quant else None)
            elif self.use_paged:
                # page-native prefill (§13): the chunk's K/V is already in
                # the pools — stream KV page by page via the block tables,
                # causal mask inside the chunk, no gather-to-contiguous
                attn = kernel_ops.paged_residual_attention_prefill(
                    q, kbp[li], vbp[li],
                    krp[li] if self.disagg else None,
                    vrp[li] if self.disagg else None,
                    bk if self.disagg else None,
                    bv if self.disagg else None,
                    bt_b, bt_r if self.disagg else None, start,
                    start + n_valid, scale=cfg.resolved_head_dim ** -0.5,
                    window=cfg.sliding_window, rope_theta=cfg.rope_theta,
                    use_rope=cfg.use_rope,
                    kb_scale=ksp[li] if self.kv_quant else None,
                    vb_scale=vsp[li] if self.kv_quant else None)
            else:
                # legacy: gather every request's pages -> contiguous view
                w = bt_b.shape[1] * self.page
                if self.kv_quant:
                    kc = self._dq_gather(kbp[li], ksp[li], bt_b, bsz, w)
                    vc = self._dq_gather(vbp[li], vsp[li], bt_b, bsz, w)
                else:
                    kc = kbp[li][bt_b].reshape(bsz, w, cfg.num_kv_heads, -1)
                    vc = vbp[li][bt_b].reshape(bsz, w, cfg.num_kv_heads, -1)
                if self.disagg:
                    krc = krp[li][bt_r].reshape(bsz, w, -1)
                    vrc = vrp[li][bt_r].reshape(bsz, w, -1)
                    bk_rows = bk.reshape(bsz, cfg.lora.rank, -1)
                    bv_rows = bv.reshape(bsz, cfg.lora.rank, -1)
                else:
                    krc = vrc = bk_rows = bv_rows = None
                kmask_pos = jnp.broadcast_to(jnp.arange(w)[None], (bsz, w))
                attn = tfm._attend(q, kc, vc, krc, vrc, bk_rows, bv_rows,
                                   kmask_pos, start + n_valid, positions,
                                   cfg.sliding_window,
                                   cfg.resolved_head_dim ** -0.5, cfg,
                                   self.disagg)
            x = x + attn.reshape(bsz, chunk, -1) @ p_l["wo"]
            h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + tfm.ffn(p_l, h, cfg)
        # per-row logits of the LAST VALID token
        idx = jnp.maximum(n_valid - 1, 0).astype(jnp.int32)
        if verify:
            # unembed EVERY position once; the last-valid logits are a
            # gather from the same tensor (bit-identical to the x_last
            # path: unembed is a per-position matmul)
            logits_all = tfm.unembed(self.params, x, cfg)     # (B, chunk, V)
            greedy_all = jnp.argmax(logits_all, axis=-1).astype(jnp.int32)
            logits = jnp.take_along_axis(
                logits_all, idx[:, None, None], axis=1)[:, 0]
            # longest accepted draft prefix: token column j+1 must match
            # the greedy prediction at column j, for in-range drafts only
            ok = (tokens[:, 1:] == greedy_all[:, :-1]) & \
                (jnp.arange(1, chunk)[None] < n_valid[:, None])
            n_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
        else:
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
            logits = tfm.unembed(self.params, x_last, cfg)[:, 0]   # (B, V)
        logits = jnp.where(poison[:, None] > 0, jnp.nan, logits)
        row_ok = jnp.all(jnp.isfinite(logits), axis=-1)
        if sampled:
            next_tok = sample_tokens(logits, temps, top_ks, top_ps, seeds,
                                     spos)
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if verify:
            return new_pools, next_tok, logits, greedy_all, n_acc, row_ok
        return new_pools, next_tok, logits, row_ok

    def prefill_plan(self, n_rows: int):
        """Shape policy for a batched prefill of ``n_rows`` requests:
        returns ``(bpad, chunk)`` — the power-of-two padded batch and the
        per-row token budget (``max_prefill_tokens`` split across the
        PADDED batch, so compile variants stay logarithmic and B=1
        degenerates to the seed's single-request chunk).  The engine
        slices prompts with this BEFORE calling :meth:`prefill_batch`,
        which pads with the same plan."""
        bpad = _pow2(max(1, n_rows))
        return bpad, max(1, self.sc.max_prefill_tokens // bpad)

    def prefill_batch(self, chunks, starts, adapter_ids, base_tables,
                      res_tables, wpages_b, wpages_r, chunk_size,
                      temps=None, top_ks=None, top_ps=None, seeds=None,
                      spos=None, poison=None):
        """Batched chunked prefill: ``len(chunks)`` rows padded per
        :meth:`prefill_plan`, each row padded to ``chunk_size`` tokens.
        Block tables arrive as RAW page lists.  Returns DEVICE arrays
        ``(next_tok, logits, row_ok)`` — the engine syncs once per step,
        not per chunk.
        """
        bsz = len(chunks)
        bpad = self.prefill_plan(bsz)[0]
        temps = list(temps) if temps is not None else [0.0] * bsz
        top_ks = list(top_ks) if top_ks is not None else [0] * bsz
        top_ps = list(top_ps) if top_ps is not None else [1.0] * bsz
        seeds = list(seeds) if seeds is not None else [0] * bsz
        spos = list(spos) if spos is not None else [0] * bsz
        poison = list(poison) if poison is not None else [0] * bsz
        if self.use_paged:
            # prefill width bucketing (§13): tables cover the batch's
            # largest post-chunk kv extent, bucketed like decode widths
            w = self._bucket_width(max(
                -(-(starts[i] + len(chunks[i])) // self.page)
                for i in range(bsz)))
        else:
            w = self.max_pages_per_req
            self.fallback_gather_calls += 1
        toks, nvalid, wb, wr, btb, btr = [], [], [], [], [], []
        for i in range(bpad):
            if i < bsz:
                row = list(chunks[i])
                pad = chunk_size - len(row)
                toks.append(row + [0] * pad)
                nvalid.append(len(row))
                wb.append(list(wpages_b[i]) + [self.dump_page] * pad)
                wr.append(list(wpages_r[i]) + [self.dump_page_r] * pad)
                btb.append(self._pad_table(base_tables[i], w,
                                           self.dump_page))
                btr.append(self._pad_table(res_tables[i], w,
                                           self.dump_page_r))
            else:               # padding row: all writes go to the dump
                toks.append([0] * chunk_size)
                nvalid.append(0)
                wb.append([self.dump_page] * chunk_size)
                wr.append([self.dump_page_r] * chunk_size)
                btb.append([self.dump_page] * w)
                btr.append([self.dump_page_r] * w)
        pad = bpad - bsz
        starts = list(starts) + [0] * pad
        adapter_ids = list(adapter_ids) + [0] * pad
        temps += [0.0] * pad
        top_ks += [0] * pad
        top_ps += [1.0] * pad
        seeds += [0] * pad
        spos += [0] * pad
        poison += [0] * pad
        self.pools, next_tok, logits, row_ok = self._prefill(
            self.pools, jnp.asarray(toks, jnp.int32),
            jnp.asarray(starts, jnp.int32), jnp.asarray(nvalid, jnp.int32),
            jnp.asarray(adapter_ids, jnp.int32),
            jnp.asarray(btb, jnp.int32), jnp.asarray(btr, jnp.int32),
            jnp.asarray(wb, jnp.int32), jnp.asarray(wr, jnp.int32),
            jnp.asarray(temps, jnp.float32), jnp.asarray(top_ks, jnp.int32),
            jnp.asarray(top_ps, jnp.float32), jnp.asarray(seeds, jnp.int32),
            jnp.asarray(spos, jnp.int32), jnp.asarray(poison, jnp.int32),
            chunk=chunk_size, sampled=any(t > 0 for t in temps))
        return next_tok, logits, row_ok

    # ------------------------------------------------------- mixed batch
    def mixed_step(self, chunks, starts, adapter_ids, base_tables,
                   res_tables, wpages_b, wpages_r, temps=None, top_ks=None,
                   top_ps=None, seeds=None, spos=None, poison=None,
                   verify=False, qfloor=0):
        """One iteration-level mixed batch (DESIGN.md §14): decode rows
        (``chunks[i] == [last_token]``, ``starts[i] == kv_len``) and
        chunked-prefill rows side by side, executed as a SINGLE call.

        Shape policy: a plan whose rows are all single-token and fit the
        decode batch delegates to :meth:`decode` — steady-state decode
        keeps its own compiled variants (and the logarithmic
        variant-count bound probed by ``decode_cache_size``).  Truly
        mixed plans pad rows to the power-of-two chunk width of the
        LONGEST row and run the unified kernel grid, each row's real
        length riding in as its q-length.  Returns DEVICE arrays
        ``(next_tok, logits, row_ok)``; rows past ``len(chunks)`` are
        padding.

        ``verify=True`` (DESIGN.md §16): the plan carries speculative
        verify rows (``chunks[i] == [t0, d_1..d_k]``); returns the
        extended tuple ``(next_tok, logits, greedy_all, n_acc, row_ok)``
        with the per-position greedy tokens and accepted-prefix lengths.
        ``qfloor`` overrides the q-tile floor — verify-dominated plans
        with no prefill rows pad to pow2(k+1) instead of the 32-wide
        prefill tile, so a k=4 verify step is not 8x padding waste.
        """
        bsz = len(chunks)
        qmax = max(len(c) for c in chunks)
        if not verify and qmax == 1 and bsz <= self.sc.max_batch:
            # decode-shaped plan: write position == starts, attend over
            # starts+1 tokens — exactly the decode contract
            return self.decode(
                [c[0] for c in chunks], list(starts), adapter_ids,
                base_tables, res_tables,
                [w[0] for w in wpages_b], [w[0] for w in wpages_r],
                [s % self.page for s in starts], temps=temps,
                top_ks=top_ks, top_ps=top_ps, seeds=seeds, spos=spos,
                poison=poison)
        # shape-bucket with FLOORS, not just pow2: which rows (and which
        # chunk lengths) coincide in a plan is timing-sensitive, so
        # bucketing purely by pow2(bsz)/pow2(qmax) sprays one compiled
        # variant per batch/chunk combination the schedule happens to
        # produce — and each stray compile is a multi-second stall in the
        # serving loop.  Flooring the batch at the steady-state size and
        # the q tile at the prefill chunk cap collapses both axes to one
        # or two stable buckets; pad rows/columns carry q_len 0 (or sit
        # past a row's q_len) and are skipped by the kernels' live/mask
        # conditions.
        qfloor = qfloor if qfloor > 0 else min(self.sc.max_prefill_tokens,
                                               32)
        qpad = _pow2(max(qmax, qfloor))
        bpad = _pow2(max(bsz, min(self.sc.max_batch, 4)))
        temps = list(temps) if temps is not None else [0.0] * bsz
        top_ks = list(top_ks) if top_ks is not None else [0] * bsz
        top_ps = list(top_ps) if top_ps is not None else [1.0] * bsz
        seeds = list(seeds) if seeds is not None else [0] * bsz
        spos = list(spos) if spos is not None else [0] * bsz
        poison = list(poison) if poison is not None else [0] * bsz
        if self.use_paged:
            w = self._bucket_width(max(
                -(-(starts[i] + len(chunks[i])) // self.page)
                for i in range(bsz)))
        else:
            w = self.max_pages_per_req
            self.fallback_gather_calls += 1
        toks, nvalid, wb, wr, btb, btr = [], [], [], [], [], []
        for i in range(bpad):
            if i < bsz:
                row = list(chunks[i])
                pad = qpad - len(row)
                toks.append(row + [0] * pad)
                nvalid.append(len(row))
                wb.append(list(wpages_b[i]) + [self.dump_page] * pad)
                wr.append(list(wpages_r[i]) + [self.dump_page_r] * pad)
                btb.append(self._pad_table(base_tables[i], w,
                                           self.dump_page))
                btr.append(self._pad_table(res_tables[i], w,
                                           self.dump_page_r))
            else:               # padding row: q_len 0, writes to the dump
                toks.append([0] * qpad)
                nvalid.append(0)
                wb.append([self.dump_page] * qpad)
                wr.append([self.dump_page_r] * qpad)
                btb.append([self.dump_page] * w)
                btr.append([self.dump_page_r] * w)
        pad = bpad - bsz
        starts = list(starts) + [0] * pad
        adapter_ids = list(adapter_ids) + [0] * pad
        temps += [0.0] * pad
        top_ks += [0] * pad
        top_ps += [1.0] * pad
        seeds += [0] * pad
        spos += [0] * pad
        poison += [0] * pad
        out = self._prefill(
            self.pools, jnp.asarray(toks, jnp.int32),
            jnp.asarray(starts, jnp.int32), jnp.asarray(nvalid, jnp.int32),
            jnp.asarray(adapter_ids, jnp.int32),
            jnp.asarray(btb, jnp.int32), jnp.asarray(btr, jnp.int32),
            jnp.asarray(wb, jnp.int32), jnp.asarray(wr, jnp.int32),
            jnp.asarray(temps, jnp.float32), jnp.asarray(top_ks, jnp.int32),
            jnp.asarray(top_ps, jnp.float32), jnp.asarray(seeds, jnp.int32),
            jnp.asarray(spos, jnp.int32), jnp.asarray(poison, jnp.int32),
            chunk=qpad, sampled=any(t > 0 for t in temps), unified=True,
            verify=verify)
        self.pools = out[0]
        return tuple(out[1:])

    # ------------------------------------------------- broadcast fork
    def _prefill_broadcast_fn(self, pools: Pools, tokens, start, n_valid,
                              adapter_ids, bt_b, wpages_b, wpages_r, *,
                              chunk, n_agents):
        """Beyond-paper broadcast fork (DESIGN.md §9): ONE base-trajectory
        pass over the shared context computes rCaches for ``n_agents``
        adapters at once (residuals are rank-r projections of the same x).

        tokens: (chunk,); adapter_ids: (n_agents,); wpages_r:
        (n_agents, chunk).  Base attention only (the approximation);
        bCache written once via wpages_b.
        """
        cfg = self.cfg
        positions = start + jnp.arange(chunk)
        x = self.params["embed"][tokens][None]
        woff = positions % self.page
        valid = jnp.arange(chunk) < n_valid
        new_pools = pools
        for li in range(cfg.num_layers):
            p_l = self._layer_params(li)
            lora_l = self._lora_layer(li)
            h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            # base trajectory: no q-LoRA
            q, sin, cos = tfm._qkv(p_l, h, cfg, None, None, positions[None])
            hd = cfg.resolved_head_dim
            kb_ = (h @ p_l["wk"]).reshape(1, chunk, cfg.num_kv_heads, hd)
            vb_ = (h @ p_l["wv"]).reshape(1, chunk, cfg.num_kv_heads, hd)
            if cfg.use_rope:
                from repro.core import rope as rope_lib
                kb_ = rope_lib.apply_rope(kb_, sin, cos)
            # all agents' residuals from the SAME x: (n_agents, chunk, r)
            a_k = lora_l["a_k"][adapter_ids]          # (K, d, r)
            a_v = lora_l["a_v"][adapter_ids]
            sc = lora_l["scaling"][adapter_ids].astype(x.dtype)
            kr_ = jnp.einsum("sd,kdr->ksr", h[0], a_k.astype(x.dtype)) \
                * sc[:, None, None]
            vr_ = jnp.einsum("sd,kdr->ksr", h[0], a_v.astype(x.dtype)) \
                * sc[:, None, None]
            kb_, vb_, ks_, vs_ = self._maybe_quant(kb_, vb_)
            wp_b = jnp.where(valid, wpages_b, self.dump_page)
            wp_r = jnp.where(valid[None], wpages_r, self.dump_page_r)
            kbp = new_pools.kb.at[li, wp_b, woff].set(kb_[0])
            vbp = new_pools.vb.at[li, wp_b, woff].set(vb_[0])
            if self.kv_quant:
                ksp = new_pools.kb_s.at[li, wp_b, woff].set(ks_[0])
                vsp = new_pools.vb_s.at[li, wp_b, woff].set(vs_[0])
            else:
                ksp, vsp = new_pools.kb_s, new_pools.vb_s
            krp = new_pools.kr.at[li, wp_r, woff[None]].set(kr_)
            vrp = new_pools.vr.at[li, wp_r, woff[None]].set(vr_)
            new_pools = Pools(kbp, vbp, krp, vrp, ksp, vsp)
            # attention over base cache only
            if self.use_paged:
                attn = kernel_ops.paged_residual_attention_prefill(
                    q, kbp[li], vbp[li], None, None, None, None,
                    bt_b[None], None, start[None],
                    (start + n_valid)[None],
                    scale=cfg.resolved_head_dim ** -0.5,
                    window=cfg.sliding_window, rope_theta=cfg.rope_theta,
                    use_rope=cfg.use_rope,
                    kb_scale=ksp[li] if self.kv_quant else None,
                    vb_scale=vsp[li] if self.kv_quant else None)
            else:
                w = bt_b.shape[0] * self.page
                if self.kv_quant:
                    kc = self._dq_gather(kbp[li], ksp[li], bt_b[None], 1, w)
                    vc = self._dq_gather(vbp[li], vsp[li], bt_b[None], 1, w)
                else:
                    kc = kbp[li][bt_b].reshape(1, w, cfg.num_kv_heads, -1)
                    vc = vbp[li][bt_b].reshape(1, w, cfg.num_kv_heads, -1)
                kmask_pos = jnp.arange(w)[None]
                attn = tfm._attend(q, kc, vc, None, None, None, None,
                                   kmask_pos, (start + n_valid)[None],
                                   positions[None], cfg.sliding_window,
                                   cfg.resolved_head_dim ** -0.5, cfg,
                                   False)
            x = x + attn.reshape(1, chunk, -1) @ p_l["wo"]
            h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + tfm.ffn(p_l, h, cfg)
        return new_pools

    def prefill_broadcast(self, tokens, start, adapter_ids, bt_b,
                          wpages_b, wpages_r_list, chunk_size):
        n = len(tokens)
        pad = chunk_size - n
        if self.use_paged:
            bt_b = self._pad_table(bt_b, self._bucket_width(
                -(-(start + n) // self.page)), self.dump_page)
        else:
            self.fallback_gather_calls += 1
        toks = jnp.asarray(list(tokens) + [0] * pad, jnp.int32)
        wb = jnp.asarray(list(wpages_b) + [self.dump_page] * pad, jnp.int32)
        wr = jnp.asarray([list(w) + [self.dump_page_r] * pad
                          for w in wpages_r_list], jnp.int32)
        if not hasattr(self, "_broadcast_jit"):
            self._broadcast_jit = {}
        key = (chunk_size, len(adapter_ids))
        if key not in self._broadcast_jit:
            self._broadcast_jit[key] = jax.jit(
                self._prefill_broadcast_fn, donate_argnums=(0,),
                static_argnames=("chunk", "n_agents"))
        self.pools = self._broadcast_jit[key](
            self.pools, toks, jnp.asarray(start, jnp.int32),
            jnp.asarray(n, jnp.int32),
            jnp.asarray(list(adapter_ids), jnp.int32),
            jnp.asarray(bt_b, jnp.int32), wb, wr,
            chunk=chunk_size, n_agents=len(adapter_ids))
