"""Speculative decoding on CoW forks (DESIGN.md §16).

ForkKV's fork/CoW machinery makes speculative decoding unusually cheap:

  * **Propose.** A draft-free :class:`Proposer` guesses the next k tokens
    from token statistics alone — no draft model, no extra forward pass.
    Two built-ins: :class:`PromptLookupProposer` (longest-suffix n-gram
    match against the request's OWN prompt+output — agent traces quote
    their context constantly) and :class:`NGramCacheProposer` (a bounded
    global n-gram → continuation cache warmed by COMPLETED requests, so a
    repeated fork replays its sibling's output at ~100% acceptance).
  * **Verify.** The scheduler turns the request's decode row into a
    ``verify`` row carrying ``[last_token, d_1..d_k]`` — q_len = k+1
    through the existing unified mixed grid (the per-row q-length
    scalar-prefetch from DESIGN.md §14 already handles it).  The executor
    computes the greedy argmax at EVERY row position in-jit and reduces
    the longest accepted prefix per row — one host sync per step, never
    per token.
  * **Rollback.** Drafted tokens' KV lands at positions >= kv_len, which
    the page-aligned radix invariants guarantee live in request-OWNED
    (CoW-private) pages: ``match_prefix`` only matches whole pages and
    ``insert`` only adopts full pages, so shared prefixes end at a page
    boundary <= kv_len.  Rejected-draft KV is therefore private garbage —
    overwritten by the next step's writes at those same positions, or
    freed by the ordinary refcount decrement at finish.  ``_finish``
    commits only ``(prompt + output[:-1])[:kv_len]``, so garbage can never
    enter the radix tree.  Rollback is a refcount decrement, not a rewind.

Greedy only: under argmax sampling, accepted tokens are bit-identical to
the non-speculative stream (the verify pass computes the same logits the
sequential decode would), which is what the parity matrix locks down.
Sampled requests fall back to plain decode rows.

Pure host-side token statistics: no jax, no pools — unit-testable without
a model (``tests/test_speculate.py``).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Sequence

from repro.core.config import ServeConfig

__all__ = ["Proposer", "PromptLookupProposer", "NGramCacheProposer",
           "AdaptiveK", "longest_accepted_prefix", "make_proposer"]


def longest_accepted_prefix(draft: Sequence[int],
                            greedy: Sequence[int]) -> int:
    """Reference accept rule: the number of leading draft tokens that
    match the target model's greedy predictions.  ``greedy[j]`` is the
    argmax AFTER consuming ``[t0, d_1..d_j]``, so draft ``d_{j+1}`` is
    accepted iff it equals ``greedy[j]`` and every earlier draft was.
    The jit-stable equivalent (cumprod-sum over the match mask) runs
    inside the executor; this mirror exists for tests."""
    n = 0
    for d, g in zip(draft, greedy):
        if d != g:
            break
        n += 1
    return n


class Proposer:
    """Draft-free proposer interface.

    ``propose(tokens, k)`` returns up to ``k`` guessed continuations of
    ``tokens`` (the request's prompt + output so far); an empty list
    means "no guess" and the request runs a plain decode row this step.
    ``observe(tokens)`` feeds a COMPLETED request's definitive sequence
    back in so future requests can replay it (no-op by default).
    """

    name = "base"

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        raise NotImplementedError

    def observe(self, tokens: Sequence[int]) -> None:
        pass

    def stats(self) -> dict:
        return {}


class PromptLookupProposer(Proposer):
    """Prompt-lookup decoding: match the current suffix n-gram against
    earlier occurrences in the request's OWN tokens and propose the
    continuation of the MOST RECENT match (longest n wins).

    Agent workloads re-quote their context constantly (tool schemas,
    instructions, prior turns), so self-matches are common and free —
    no state beyond the request's token list, nothing to evict.
    """

    name = "prompt_lookup"

    def __init__(self, max_ngram: int = 4, min_ngram: int = 2,
                 scan_window: int = 4096):
        assert 1 <= min_ngram <= max_ngram
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        # bound the per-proposal scan for very long sequences; recent
        # tokens are the likeliest match sites anyway
        self.scan_window = scan_window

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        toks = list(tokens)
        L = len(toks)
        if k <= 0 or L < self.min_ngram + 1:
            return []
        lo = max(0, L - self.scan_window)
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            suffix = toks[L - n:]
            # most recent earlier occurrence: scan right-to-left, the
            # match must END strictly before the sequence's end so a
            # continuation token exists
            for i in range(L - n - 1, lo - 1, -1):
                if toks[i:i + n] == suffix:
                    cont = toks[i + n:i + n + k]
                    if cont:
                        return cont
        return []


class NGramCacheProposer(Proposer):
    """Bounded global n-gram → continuation cache, warmed by completed
    requests (a flat radix over fixed-length keys — the bounded stand-in
    for a suffix automaton).

    :meth:`observe` indexes every n-gram of a finished request's
    definitive sequence to its following tokens; :meth:`propose` looks up
    the current suffix (longest n first) and returns the cached
    continuation.  LRU-bounded at ``max_entries`` keys, each holding at
    most ``cont_len`` continuation tokens, so memory is
    O(max_entries · cont_len) regardless of traffic.  On a cache miss it
    falls back to prompt-lookup over the request's own tokens, so cold
    requests still speculate.

    The payoff case is the agent tree: sibling forks sharing a context
    produce near-identical outputs, so the second fork's continuation is
    already cached when it decodes — acceptance approaches 100% and a
    verify step commits k+1 tokens at the cost of one.
    """

    name = "ngram_cache"

    def __init__(self, max_ngram: int = 4, min_ngram: int = 2,
                 max_entries: int = 8192, cont_len: int = 16):
        assert 1 <= min_ngram <= max_ngram
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_entries = max_entries
        self.cont_len = cont_len
        self._cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._fallback = PromptLookupProposer(max_ngram, min_ngram)
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._cache)

    def observe(self, tokens: Sequence[int]) -> None:
        toks = list(tokens)
        L = len(toks)
        for n in range(self.min_ngram, self.max_ngram + 1):
            for i in range(0, L - n):
                key = tuple(toks[i:i + n])
                cont = tuple(toks[i + n:i + n + self.cont_len])
                # last writer wins + refreshes recency
                self._cache.pop(key, None)
                self._cache[key] = cont
        while len(self._cache) > self.max_entries:
            self._cache.popitem(last=False)       # LRU eviction

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        toks = list(tokens)
        L = len(toks)
        if k <= 0 or L < self.min_ngram:
            return []
        for n in range(min(self.max_ngram, L), self.min_ngram - 1, -1):
            key = tuple(toks[L - n:])
            cont = self._cache.get(key)
            if cont:
                self._cache.move_to_end(key)      # refresh recency
                self.hits += 1
                return list(cont[:k])
        self.misses += 1
        return self._fallback.propose(toks, k)

    def stats(self) -> dict:
        return {"entries": len(self._cache), "hits": self.hits,
                "misses": self.misses}


class AdaptiveK:
    """Per-request draft-length controller: back off when acceptance
    drops, recover when it runs high.

    Keeps an EMA of the per-step acceptance rate; below ``low`` the draft
    length halves (floor ``k_min``), above ``high`` it grows by one
    (ceiling ``k_max``).  A proposer feeding garbage therefore converges
    to k_min within a few steps — the verify row stays nearly as cheap as
    a plain decode row — while a replayed trace climbs back to k_max.
    """

    def __init__(self, k_max: int, k_min: int = 1, alpha: float = 0.5,
                 low: float = 0.35, high: float = 0.8):
        self.k_max = max(1, k_max)
        self.k_min = max(1, min(k_min, self.k_max))
        self.alpha = alpha
        self.low = low
        self.high = high
        self.k = self.k_max           # optimistic start
        self.ema = 1.0

    def update(self, proposed: int, accepted: int) -> int:
        """Feed one verify step's outcome; returns the new draft cap."""
        if proposed > 0:
            rate = accepted / proposed
            self.ema = self.alpha * rate + (1.0 - self.alpha) * self.ema
            if self.ema < self.low:
                self.k = max(self.k_min, self.k // 2)
            elif self.ema > self.high:
                self.k = min(self.k_max, self.k + 1)
        return self.k


def make_proposer(sc: ServeConfig) -> Proposer:
    """Build the proposer named by ``ServeConfig.spec_proposer``."""
    if sc.spec_proposer == "prompt_lookup":
        return PromptLookupProposer(min_ngram=sc.spec_min_ngram)
    if sc.spec_proposer == "ngram_cache":
        return NGramCacheProposer(min_ngram=sc.spec_min_ngram,
                                  max_entries=sc.spec_cache_entries)
    raise ValueError(f"unknown spec_proposer {sc.spec_proposer!r}")
