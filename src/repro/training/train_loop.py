"""Training step builders: full-parameter pretraining and LoRA fine-tuning.

``make_train_step(cfg)`` returns a jit-compatible
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` with
optional gradient accumulation.  ``make_lora_train_step`` freezes the base
model and trains only the adapter stacks (how ForkKV's specialized agents
are produced).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import base
from repro.models.registry import get_model
from repro.training import optimizer as opt_lib


def _loss_fn(api, params, batch, lora=None, adapter_ids=None,
             disagg: bool = False):
    kwargs = {}
    if "extra_embeds" in batch:
        kwargs["extra_embeds"] = batch["extra_embeds"]
    if lora is not None:
        kwargs.update(lora=lora, adapter_ids=adapter_ids, disagg=disagg)
    logits = api.forward(params, batch["tokens"], **kwargs)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # VLM: logits cover [patches ‖ text]; loss only on the text tail
        logits = logits[:, -labels.shape[1]:]
    return base.cross_entropy(logits, labels)


def make_train_step(cfg: ModelConfig, lr: float = 3e-4,
                    accum_steps: int = 1) -> Tuple[Callable, Callable]:
    """Full-parameter training.  Returns (init_opt_state, step)."""
    api = get_model(cfg)
    init, update = opt_lib.get_optimizer(cfg.optimizer, lr)

    def loss(params, batch):
        return _loss_fn(api, params, batch)

    def step(params, opt_state, batch):
        if accum_steps > 1:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss)(params, mb)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            l = lsum / accum_steps
        else:
            l, grads = jax.value_and_grad(loss)(params, batch)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        params, opt_state = update(grads, opt_state, params)
        return params, opt_state, {"loss": l, "grad_norm": gnorm}

    return init, step


def make_lora_train_step(cfg: ModelConfig, lr: float = 1e-3,
                         adapter_id: int = 0) -> Tuple[Callable, Callable]:
    """LoRA fine-tuning: base params frozen, adapter stacks trained."""
    api = get_model(cfg)
    init, update = opt_lib.get_optimizer("adamw", lr)

    def loss(lora, params, batch):
        ids = jnp.full((batch["tokens"].shape[0],), adapter_id, jnp.int32)
        return _loss_fn(api, params, batch, lora=lora, adapter_ids=ids)

    def step(lora, opt_state, params, batch):
        l, grads = jax.value_and_grad(loss)(lora, params, batch)
        lora, opt_state = update(grads, opt_state, lora)
        return lora, opt_state, {"loss": l}

    return init, step


def eval_loss(cfg: ModelConfig, params, batch, lora=None,
              adapter_ids=None) -> jnp.ndarray:
    api = get_model(cfg)
    return _loss_fn(api, params, batch, lora=lora, adapter_ids=adapter_ids)
