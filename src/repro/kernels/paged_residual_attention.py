"""Paged ResidualAttention decode kernels (TPU target).

The serving engine stores the disaggregated cache in page pools addressed by
block tables.  The dense kernels (residual_attention.py) assume the wrapper
gathered pages into contiguous views; THESE kernels consume the pools
directly — block tables ride in as scalar-prefetch operands and the
BlockSpec index maps dereference them, so each grid step DMA's exactly one
(page × kv_head) tile of bCache + one page of rCache from HBM.  This is the
Pallas analogue of SGLang's paged RadixAttention fused with ForkKV's
on-chip reconstruction (paper §5.3), and the production decode path on real
TPU (DESIGN.md §3, §12).

Per-request page-count masking: the page axis of the grid is sized for the
widest request in the batch, but a request with ``kv_len`` tokens only has
``ceil(kv_len / page)`` live pages.  Grid steps past that point (a) clamp
their index maps to the request's last live page — the block index repeats,
so the Pallas pipeline skips the DMA re-fetch — and (b) skip the softmax
update entirely under ``pl.when``, so short requests pay FLOPs for their
own length, not the batch maximum.

Two variants:

* :func:`paged_residual_attention_decode` — disaggregated (bCache + rCache
  with per-request B_k/B_v up-projections, ForkKV mode).  RoPE for the
  reconstructed K residual is computed *in kernel* from the logical
  position (page_index·page_size + offset) — no sin/cos tables in HBM.
* :func:`paged_attention_decode_base` — base-only (unified caches: the
  prefix / full_reuse baselines, or ForkKV serving base-model requests
  with no adapter).  Same grid and skip logic, no residual stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INIT = -1e30


def _last_live_page(kvl, page: int):
    """Index of the last page holding valid tokens (kv_len >= 1 assumed;
    clamps to page 0 for empty/padded rows)."""
    return jnp.maximum(kvl - 1, 0) // page


def _kernel(bt_b_ref, bt_r_ref, kvlen_ref, q_ref, kb_ref, vb_ref, kr_ref,
            vr_ref, bk_ref, bv_ref, out_ref, m_scr, l_scr, acc_scr,
            accr_scr, *, scale: float, page: int, rope_theta: float,
            use_rope: bool):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g, d = q_ref.shape[2], q_ref.shape[3]
    kvlen = kvlen_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accr_scr[...] = jnp.zeros_like(accr_scr)

    # pages past ceil(kv_len/page) contribute nothing: skip their FLOPs
    # (their DMA is already skipped by the clamped index maps)
    @pl.when(j * page < kvlen)
    def _compute():
        # ---- on-the-fly K reconstruction with in-kernel deferred RoPE ----
        k_b = kb_ref[0, :, 0, :].astype(jnp.float32)           # (page, D)
        k_r = kr_ref[0].astype(jnp.float32)                    # (page, R)
        b_k = bk_ref[0, 0].astype(jnp.float32)                 # (R, D)
        k_lora = jnp.dot(k_r, b_k, preferred_element_type=jnp.float32)
        if use_rope:
            pos = (j * page + jax.lax.broadcasted_iota(
                jnp.int32, (page, 1), 0)).astype(jnp.float32)  # (page, 1)
            half = d // 2
            freqs = 1.0 / (rope_theta ** (
                jax.lax.broadcasted_iota(jnp.float32, (1, half), 1) / half))
            ang = pos * freqs                                  # (page, half)
            sin, cos = jnp.sin(ang), jnp.cos(ang)
            x1, x2 = k_lora[:, :half], k_lora[:, half:]
            k_lora = jnp.concatenate([x1 * cos - x2 * sin,
                                      x2 * cos + x1 * sin], axis=-1)
        k = k_b + k_lora

        # ---- scores + online softmax with dual accumulators --------------
        q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = kpos < kvlen
        s = jnp.where(mask, s, NEG_INIT)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new) * mask
        l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)

        v_b = vb_ref[0, :, 0, :].astype(jnp.float32)
        v_r = vr_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v_b, preferred_element_type=jnp.float32)
        accr_scr[...] = accr_scr[...] * alpha + jnp.dot(
            p, v_r, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == nj - 1)
    def _fini():
        b_v = bv_ref[0, 0].astype(jnp.float32)
        acc = acc_scr[...] + jnp.dot(accr_scr[...], b_v,
                                     preferred_element_type=jnp.float32)
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out_ref[0, 0] = (acc / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "rope_theta",
                                             "use_rope", "interpret"))
def paged_residual_attention_decode(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                    b_k, b_v, bt_b, bt_r, kv_len, *,
                                    scale: float,
                                    rope_theta: float = 10_000.0,
                                    use_rope: bool = True,
                                    interpret: bool = True):
    """Decode over paged disaggregated caches.

    q:        (B, Hq, D)
    kb/vb:    (P,  page, Hkv, D) base pools (K RoPE'd at write time)
    kr/vr:    (Pr, page, R)      residual pools (no RoPE, scaled)
    b_k/b_v:  (B, R, Hkv*D)      per-request up-projections
    bt_b/bt_r:(B, n_pages) int32 block tables (logical page -> pool page)
    kv_len:   (B,) valid tokens.  Returns (B, Hq, D).
    """
    bsz, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    r = kr_pool.shape[-1]
    n_pages = bt_b.shape[1]

    qt = q.reshape(bsz, hkv, g, d)
    bkt = b_k.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)
    bvt = b_v.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, page=page,
                               rope_theta=rope_theta, use_rope=use_rope)

    # clamp dead grid steps to the request's last live page: the block
    # index repeats, so the pipeline skips the DMA instead of prefetching
    # padding pages the kernel would only mask away
    def _b_map(b, h, j, btb, btr, kvl):
        jc = jnp.minimum(j, _last_live_page(kvl[b], page))
        return (btb[b, jc], 0, h, 0)

    def _r_map(b, h, j, btb, btr, kvl):
        jc = jnp.minimum(j, _last_live_page(kvl[b], page))
        return (btr[b, jc], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bsz, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d), _b_map),
            pl.BlockSpec((1, page, 1, d), _b_map),
            pl.BlockSpec((1, page, r), _r_map),
            pl.BlockSpec((1, page, r), _r_map),
            pl.BlockSpec((1, 1, r, d),
                         lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, r, d),
                         lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), bt_r.astype(jnp.int32),
      kv_len.astype(jnp.int32), qt, kb_pool, vb_pool, kr_pool, vr_pool,
      bkt, bvt)
    return out.reshape(bsz, hq, d)


# --------------------------------------------------------------------------
# Base-only variant (unified caches / no-LoRA requests)
# --------------------------------------------------------------------------
def _kernel_base(bt_b_ref, kvlen_ref, q_ref, kb_ref, vb_ref, out_ref,
                 m_scr, l_scr, acc_scr, *, scale: float, page: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    kvlen = kvlen_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * page < kvlen)
    def _compute():
        k = kb_ref[0, :, 0, :].astype(jnp.float32)             # (page, D)
        q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = kpos < kvlen
        s = jnp.where(mask, s, NEG_INIT)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new) * mask
        l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)

        v = vb_ref[0, :, 0, :].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == nj - 1)
    def _fini():
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out_ref[0, 0] = (acc_scr[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention_decode_base(q, kb_pool, vb_pool, bt_b, kv_len, *,
                                scale: float, interpret: bool = True):
    """Base-only paged decode: attention over the bCache pool alone.

    Serves the unified-cache baselines (prefix / full_reuse) and ForkKV
    requests without an adapter.  Same shapes as the disaggregated variant
    minus the residual stream:

    q: (B, Hq, D); kb/vb: (P, page, Hkv, D); bt_b: (B, n_pages);
    kv_len: (B,).  Returns (B, Hq, D).
    """
    bsz, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    n_pages = bt_b.shape[1]
    qt = q.reshape(bsz, hkv, g, d)

    kernel = functools.partial(_kernel_base, scale=scale, page=page)

    def _b_map(b, h, j, btb, kvl):
        jc = jnp.minimum(j, _last_live_page(kvl[b], page))
        return (btb[b, jc], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b, h, j, btb, kvl: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d), _b_map),
            pl.BlockSpec((1, page, 1, d), _b_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h, j, btb, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), kv_len.astype(jnp.int32), qt, kb_pool,
      vb_pool)
    return out.reshape(bsz, hq, d)
