"""Analytic roofline cost model (exact FLOPs, first-order bytes/collectives).

Why this exists: XLA's ``compiled.cost_analysis()`` counts while-loop bodies
ONCE (verified in tests/test_roofline.py), so any scanned model (layer scans,
flash-attention block scans, gradient-accumulation scans) is undercounted by
the product of its trip counts.  The dry-run therefore records BOTH the raw
HLO numbers and this analytic model; the roofline table (EXPERIMENTS.md) is
built from the analytic terms, which we validate against cost_analysis on
small fully-unrolled probes.

All FLOPs are exact matmul FLOPs of the implementation as written (e.g. the
blocked flash path computes *all* kv blocks including fully-masked ones — we
count what the code does, not an idealized causal half).  Bytes and
collective volumes are first-order: dominant terms only, constants
documented inline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.config import ModelConfig, ShapeConfig
from repro.launch import sharding as shd

F32, BF16 = 4, 2


def _mesh_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _dtype_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


# --------------------------------------------------------------------------
# FLOPs (global, one step)
# --------------------------------------------------------------------------
def _attn_layer_flops(cfg, B, s_new, k_eff, with_lora) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    f = 2 * B * s_new * d * (cfg.q_dim + 2 * cfg.kv_dim)      # qkv proj
    f += 2 * B * s_new * cfg.q_dim * d                         # o proj
    f += 4 * B * cfg.num_heads * s_new * k_eff * hd            # QK^T + PV
    if with_lora:
        r = cfg.lora.rank
        f += 2 * B * s_new * (3 * d * r + r * (cfg.q_dim + 2 * cfg.kv_dim))
    return f


def _mlp_flops(cfg, B, s_new) -> float:
    n_mats = 3 if cfg.mlp_activation == "silu" else 2
    return 2 * n_mats * B * s_new * cfg.d_model * cfg.d_ff


def _moe_layer_flops(cfg, B, s_new) -> float:
    d = cfg.d_model
    ffe = cfg.moe_d_ff or cfg.d_ff
    t = B * s_new
    slots = t * cfg.num_experts_per_tok * 1.25      # capacity factor
    f = 2 * 3 * slots * d * ffe                     # expert matmuls (silu)
    f += 2 * t * d * cfg.num_experts                # router
    if cfg.moe_shared_expert:
        f += 2 * 3 * t * d * ffe
    return f


def _ssm_layer_flops(cfg, B, s_new, decode: bool) -> float:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    heads = cfg.ssm_heads or max(1, inner // 64)
    p = inner // heads
    n = cfg.ssm_state
    in_dim = 2 * inner + 2 * n + heads
    f = 2 * B * s_new * d * in_dim                  # in_proj
    f += 2 * B * s_new * inner * d                  # out_proj
    f += 2 * B * s_new * (inner + 2 * n) * cfg.ssm_conv   # conv
    if decode:
        f += 4 * B * heads * p * n                  # state update + readout
    else:
        q = 64                                      # SSD chunk
        f += 2 * B * s_new * q * n                  # intra scores
        f += 2 * B * s_new * q * heads * p          # intra apply
        f += 4 * B * s_new * heads * p * n          # chunk states + inter
    return f


def _rglru_layer_flops(cfg, B, s_new) -> float:
    d = cfg.d_model
    w = cfg.lru_width or d
    f = 2 * B * s_new * d * w * 2                   # gelu + recurrent branch
    f += 2 * B * s_new * w * w * 2                  # r/i gates
    f += 2 * B * s_new * w * d                      # out proj
    f += 10 * B * s_new * w                         # scan elementwise
    return f


def _unembed_flops(cfg, B, s_new) -> float:
    return 2 * B * s_new * cfg.d_model * cfg.vocab_size


def forward_flops(cfg: ModelConfig, B: int, s_new: int,
                  cache_len: int = 0, with_lora: bool = False,
                  decode: bool = False,
                  banded_window: bool = False) -> float:
    """One forward pass, global FLOPs.

    banded_window: §Perf optimization — windowed attention attends only a
    (window + q_block) band instead of every kv block (what the optimized
    code path computes).
    """
    L = cfg.num_layers
    total = _unembed_flops(cfg, B, s_new)

    def k_eff(window):
        if decode:
            smax = cache_len
            return min(smax, window) if window else smax
        full = s_new if not cache_len else cache_len   # flash loops all blocks
        if window and banded_window:
            return min(full, window + 512)             # banded path
        return full

    if cfg.family == "ssm":
        total += L * _ssm_layer_flops(cfg, B, s_new, decode)
        return total
    if cfg.family == "hybrid":
        from repro.models.hybrid import layer_kinds
        for kind in layer_kinds(cfg):
            if kind == "rglru":
                total += _rglru_layer_flops(cfg, B, s_new)
            else:
                total += _attn_layer_flops(cfg, B, s_new,
                                           k_eff(cfg.local_window), with_lora)
            total += _mlp_flops(cfg, B, s_new)
        return total
    if cfg.family == "audio":
        # decoder self + cross; encoder counted by caller for prefill/train
        for _ in range(L):
            total += _attn_layer_flops(cfg, B, s_new, k_eff(0), with_lora)
            total += _attn_layer_flops(cfg, B, s_new, cfg.encoder_seq, False)
            total += _mlp_flops(cfg, B, s_new)
        return total
    # llama-family (dense / moe / vlm)
    ke = k_eff(cfg.sliding_window)
    total += L * _attn_layer_flops(cfg, B, s_new, ke, with_lora)
    if cfg.num_experts:
        L_moe = L // cfg.moe_interleave
        total += L_moe * _moe_layer_flops(cfg, B, s_new)
        total += (L - L_moe) * _mlp_flops(cfg, B, s_new)
    else:
        total += L * _mlp_flops(cfg, B, s_new)
    return total


def encoder_flops(cfg: ModelConfig, B: int) -> float:
    if cfg.family != "audio":
        return 0.0
    se = cfg.encoder_seq
    f = 0.0
    for _ in range(cfg.num_encoder_layers):
        f += _attn_layer_flops(cfg, B, se, se, False)
        f += 2 * 2 * B * se * cfg.d_model * cfg.d_ff     # gelu mlp
    return f


# --------------------------------------------------------------------------
# Per-device bytes and collectives (first order)
# --------------------------------------------------------------------------
def _param_bytes(cfg) -> float:
    return cfg.num_params * _dtype_bytes(cfg)


def _param_shards(cfg, sizes, purpose, strategy="baseline") -> int:
    n_model = sizes.get("model", 1)
    n_data = sizes.get("data", 1)
    n_pod = sizes.get("pod", 1)
    if purpose == "decode":
        if cfg.num_params > shd.BIG_MODEL:
            return n_model * n_data * n_pod          # 2D/3D TP
        return n_model
    if strategy == "optimized":
        if purpose == "train" and cfg.num_params < shd.SMALL_MODEL:
            return 1                                 # fully replicated
        if purpose == "prefill" and cfg.num_params <= shd.BIG_MODEL:
            return n_model                           # FSDP over model axis
    if cfg.num_params > 2e11:
        return n_model * n_data * n_pod              # FSDP over pod+data
    return n_model * n_data                          # FSDP over data


def _cache_bytes_dev(cfg, B, S, sizes, disagg) -> float:
    """Per-device KV/state cache bytes."""
    n_data = sizes.get("data", 1)
    n_pod = sizes.get("pod", 1)
    n_model = sizes.get("model", 1)
    bshard = n_data * n_pod if B % (n_data * n_pod) == 0 else (
        n_data if B % n_data == 0 else 1)
    dt = _dtype_bytes(cfg)
    L = cfg.num_layers
    if cfg.family == "ssm":
        inner = cfg.ssm_expand * cfg.d_model
        heads = cfg.ssm_heads or max(1, inner // 64)
        per = (cfg.ssm_conv - 1) * (inner + 2 * cfg.ssm_state) * 4 + \
            heads * (inner // heads) * cfg.ssm_state * 4
        return L * B * per / bshard
    smax = min(S, cfg.sliding_window) if cfg.sliding_window else S
    if cfg.kv_quant == "int8":
        kv = 2 * smax * cfg.kv_dim * 1 + 2 * smax * cfg.num_kv_heads * 4
    else:
        kv = 2 * smax * cfg.kv_dim * dt
    if disagg:
        kv += 2 * smax * cfg.lora.rank * dt
    total = 0.0
    if cfg.family == "hybrid":
        from repro.models.hybrid import layer_kinds
        w = cfg.lru_width or cfg.d_model
        sl = min(S, cfg.local_window) if cfg.local_window else S
        kv_l = 2 * sl * cfg.kv_dim * dt + (2 * sl * cfg.lora.rank * dt
                                           if disagg else 0)
        for kind in layer_kinds(cfg):
            total += B * (kv_l if kind == "local" else
                          (3 * w * dt + w * 4))
        return total / bshard
    total = L * B * kv
    if cfg.family == "audio":
        total += L * B * 2 * cfg.encoder_seq * cfg.kv_dim * dt
    # kv head/head_dim sharding over the model axis when divisible
    hshard = n_model if (cfg.num_kv_heads % n_model == 0 or
                         cfg.resolved_head_dim % n_model == 0) else 1
    return total / (bshard * hshard)


def analytic_costs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   purpose: Optional[str] = None,
                   strategy: str = "baseline") -> Dict[str, float]:
    sizes = _mesh_sizes(mesh)
    chips = mesh.devices.size
    n_model = sizes.get("model", 1)
    n_data = sizes.get("data", 1)
    n_pod = sizes.get("pod", 1)
    B, S = shape.global_batch, shape.seq_len
    purpose = purpose or shape.mode
    if purpose == "train":
        purpose = "train"
    dt = _dtype_bytes(cfg)
    pbytes = _param_bytes(cfg)
    pshards = _param_shards(cfg, sizes, purpose, strategy)
    small_dp = (strategy == "optimized" and purpose == "train" and
                cfg.num_params < shd.SMALL_MODEL)
    prefill_fsdp = (strategy == "optimized" and purpose == "prefill" and
                    cfg.num_params <= shd.BIG_MODEL)
    api_lora = cfg.family != "ssm"

    bshard = n_data * n_pod if B % (n_data * n_pod) == 0 else (
        n_data if B % n_data == 0 else 1)
    tokens_local = B * S / bshard

    banded = strategy == "optimized"
    if shape.mode == "train":
        fwd = forward_flops(cfg, B, S, with_lora=False,
                            banded_window=banded) + \
            encoder_flops(cfg, B)
        mult = 4.0 if cfg.remat else 3.0            # fwd + bwd (+ recompute)
        flops = fwd * mult
        # bytes: params traffic (fwd+bwd+recompute) x accum + optimizer
        from repro.launch.steps import accum_for
        accum = accum_for(cfg, strategy)
        opt_b = 24 if cfg.optimizer == "adamw" else 9   # B/param (fp32 m,v)
        bytes_dev = (pbytes / pshards) * mult * accum + \
            cfg.num_params * opt_b / pshards
        # activations: ~12 B/token/feature through each layer (r+w, f32 ln)
        bytes_dev += 12 * tokens_local * cfg.d_model * cfg.num_layers * dt / \
            max(n_model // 4, 1)
        # collectives: FSDP AG (fwd+recompute+bwd) + RS grads + TP ARs
        coll = 0.0
        if small_dp:
            coll = 2 * pbytes                        # grad all-reduce only
        else:
            if pshards > n_model:                    # FSDP active
                coll += (pbytes / n_model) * \
                    (1 - 1 / (pshards / n_model)) * (mult - 1) * accum
            if n_model > 1:
                coll += 2 * 2 * cfg.num_layers * tokens_local * \
                    cfg.d_model * dt * accum / accum
            if n_pod > 1 and pshards <= n_data * n_model:
                coll += 2 * pbytes / pshards         # pod grad all-reduce
    elif shape.mode == "prefill":
        fwd = forward_flops(cfg, B, S, with_lora=api_lora,
                            banded_window=banded) + \
            encoder_flops(cfg, B)
        flops = fwd
        cache_dev = _cache_bytes_dev(cfg, B, S, sizes,
                                     disagg=cfg.family != "ssm")
        # flash re-reads K/V per q-block (q_block=512)
        nq = max(1, S // 512)
        kv_reread = cfg.num_layers * nq * (2 * S * cfg.kv_dim * dt) \
            * (B / bshard) / max(n_model, 1)
        bytes_dev = pbytes / pshards + \
            8 * tokens_local * cfg.d_model * cfg.num_layers * dt / \
            max(n_model // 4, 1) + cache_dev + kv_reread
        coll = 0.0
        if prefill_fsdp:
            # one weight all-gather per layer over the model axis; no
            # per-token TP all-reduces
            coll = pbytes * (1 - 1 / max(n_model, 1))
        else:
            if pshards > n_model:
                coll += (pbytes / n_model) * (1 - n_model / pshards)
            if n_model > 1:
                coll += 2 * 2 * cfg.num_layers * tokens_local * \
                    cfg.d_model * dt
    else:  # decode
        window = cfg.sliding_window or (cfg.local_window
                                        if cfg.family == "hybrid" else 0)
        cache_len = min(S, window) if window else S
        fwd = forward_flops(cfg, B, 1, cache_len=cache_len,
                            with_lora=api_lora, decode=True)
        flops = fwd
        cache_dev = _cache_bytes_dev(cfg, B, S, sizes,
                                     disagg=cfg.family != "ssm")
        bytes_dev = pbytes / pshards + cache_dev     # read params + full cache
        coll = 0.0
        if n_model > 1:
            b_eff = B / bshard if pshards <= n_model else B
            coll += 2 * 2 * cfg.num_layers * b_eff * cfg.d_model * dt

    flops_dev = flops / chips
    return {
        "flops_global": flops,
        "flops_dev": flops_dev,
        "bytes_dev": bytes_dev,
        "coll_bytes_dev": coll,
        "param_bytes_dev": pbytes / pshards,
        "param_shards": pshards,
    }
