"""The paper's own evaluation models (ForkKV §7.1): Llama3-8B, Qwen2.5-7B,
Qwen2.5-14B — used by the benchmark suite, not part of the assigned pool."""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

LLAMA3_8B = ModelConfig(
    name="llama3-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
    lora=LoRAConfig(rank=16), scan_layers=True, citation="arXiv:2407.21783")

QWEN25_7B = ModelConfig(
    name="qwen2.5-7b", family="dense", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    lora=LoRAConfig(rank=16), scan_layers=True, citation="Qwen2.5")

QWEN25_14B = ModelConfig(
    name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=13824, vocab_size=152064,
    lora=LoRAConfig(rank=16), scan_layers=True, citation="Qwen2.5")


def tiny_serving_model(rank: int = 16, *, sliding_window: int = 0,
                       num_heads: int = 8, num_kv_heads: int = 4,
                       num_layers: int = 4, d_model: int = 256,
                       vocab_size: int = 1024) -> ModelConfig:
    """Small llama-family model for the CPU serving engine / benchmarks.

    The attention-flavour knobs (MHA/GQA/MQA via head counts, SWA via
    ``sliding_window``) exist for the cross-mode parity matrix
    (tests/test_parity_matrix.py); the defaults are the historical
    serve-tiny shape."""
    return ModelConfig(
        name="serve-tiny", family="dense", num_layers=num_layers,
        d_model=d_model, num_heads=num_heads, num_kv_heads=num_kv_heads,
        d_ff=2 * d_model, vocab_size=vocab_size, dtype="float32",
        sliding_window=sliding_window, lora=LoRAConfig(rank=rank),
        scan_layers=True, remat=False)
