"""Mamba2 (SSD — state-space duality) blocks. [arXiv:2405.21060]

Attention-free: no KV cache exists, so ForkKV's disaggregation is N/A for
this family (DESIGN.md §5); it is served with its native bounded state cache
(conv window + SSM state).  Implements the chunked SSD algorithm for
train/prefill and the O(1) recurrent update for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import base

Params = Dict[str, Any]

CHUNK = 64


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or max(1, d_inner // 64)
    head_p = d_inner // heads
    n = cfg.ssm_state
    return d_inner, heads, head_p, n


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = cfg.activation_dtype
    d, L = cfg.d_model, cfg.num_layers
    d_inner, heads, head_p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n                      # x, B, C all convolved
    ks = base.split_keys(key, 8)
    in_dim = 2 * d_inner + 2 * n + heads            # z, x, B, C, dt
    layers = {
        "ln": jnp.zeros((L, d), dt),
        "w_in": base.dense_init(ks[0], (L, d, in_dim), dt),
        "conv_w": base.dense_init(ks[1], (L, cfg.ssm_conv, conv_dim), dt, 0.2),
        "conv_b": jnp.zeros((L, conv_dim), dt),
        "a_log": jnp.zeros((L, heads), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((L, heads), jnp.float32),
        "dt_bias": jnp.zeros((L, heads), jnp.float32),
        "gate_ln": jnp.zeros((L, d_inner), dt),
        "w_out": base.dense_init(ks[2], (L, d_inner, d), dt),
    }
    return {
        "embed": base.dense_init(ks[3], (cfg.vocab_size, d), dt),
        "final_norm": jnp.zeros((d,), dt),
        "layers": layers,
        "unembed": base.dense_init(ks[4], (d, cfg.vocab_size), dt),
    }


def logical_axes(cfg: ModelConfig) -> Params:
    return {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "unembed": ("embed", "vocab"),
        "layers": {
            "ln": ("layers", "embed"),
            "w_in": ("layers", "embed", "inner"),
            "conv_w": ("layers", None, "inner"),
            "conv_b": ("layers", "inner"),
            "a_log": ("layers", None),
            "d_skip": ("layers", None),
            "dt_bias": ("layers", None),
            "gate_ln": ("layers", "inner"),
            "w_out": ("layers", "inner", "embed"),
        },
    }


def _split_proj(proj, cfg):
    d_inner, heads, head_p, n = _dims(cfg)
    z, x, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    return z, x, b, c, dt


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B,S,C), w: (K,C).  state: (B,K-1,C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)   # conv state is stored f32
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):]
    return jax.nn.silu(out + b), new_state


def _ssd_chunked(x, dt, a, bm, cm, d_skip, h0):
    """Chunked SSD scan.

    x:  (B,S,H,P)  values
    dt: (B,S,H)    discretization (softplus'd, >0)
    a:  (H,)       negative decay rates
    bm/cm: (B,S,N) input/output projections (single group)
    h0: (B,H,P,N) initial state
    Returns (y (B,S,H,P), h_final).
    """
    bsz, s, h, p = x.shape
    n = bm.shape[-1]
    q = min(CHUNK, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q
    xc = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = bm.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = cm.reshape(bsz, nc, q, n).astype(jnp.float32)

    la = dtc * a                                     # (B,nc,Q,H) log-decays
    cs = jnp.cumsum(la, axis=2)                      # within-chunk cumsum
    # intra-chunk (quadratic, attention-like)
    li = cs[:, :, :, None, :]                        # i
    lj = cs[:, :, None, :, :]                        # j
    decay = jnp.exp(li - lj)                         # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)[..., None] * decay
    scores = jnp.where(causal[None, None, :, :, None], scores, 0.0)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dtc, xc)

    # chunk states: contribution of each chunk to the running state
    tail = jnp.exp(cs[:, :, -1:, :] - cs)            # decay to chunk end
    state_c = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchpn", tail, dtc, bc, xc)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(jnp.sum(la, axis=2))       # (B,nc,H)

    def step(hprev, inp):
        dec, st = inp                                # (B,H), (B,H,P,N)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev                           # emit state entering chunk

    h_last, h_in = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (chunk_decay.transpose(1, 0, 2), state_c.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, h_in, jnp.exp(cs))
    y = y_intra + y_inter + d_skip[None, None, None, :, None] * xc.reshape(
        bsz, nc, q, h, p)
    y = y.reshape(bsz, sp, h, p)[:, :s]
    return y.astype(x.dtype), h_last


def _layer(p_l, x, cfg, cache_l, mode):
    """One mamba2 block.  cache_l: {"conv": (B,K-1,C), "ssm": (B,H,P,N)}."""
    d_inner, heads, head_p, n = _dims(cfg)
    h = base.rms_norm(x, p_l["ln"], cfg.norm_eps)
    proj = h @ p_l["w_in"]
    z, xin, bm, cm, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xin, bm, cm], axis=-1)
    conv_state = cache_l["conv"] if cache_l is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p_l["conv_w"], p_l["conv_b"],
                                      conv_state)
    xin, bm, cm = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p_l["dt_bias"][None, None, :])
    a = -jnp.exp(p_l["a_log"])                      # (H,)
    xv = xin.reshape(xin.shape[:2] + (heads, head_p))

    h0 = cache_l["ssm"].astype(jnp.float32) if cache_l is not None else \
        jnp.zeros((x.shape[0], heads, head_p, n), jnp.float32)

    if mode == "decode":                            # S == 1: O(1) update
        dt1 = dt[:, 0]                              # (B,H)
        dec = jnp.exp(dt1 * a[None, :])             # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, bm[:, 0].astype(jnp.float32),
                         xv[:, 0].astype(jnp.float32))
        h_new = h0 * dec[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cm[:, 0].astype(jnp.float32), h_new)
        y = y + p_l["d_skip"][None, :, None] * xv[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)              # (B,1,H,P)
    else:
        y, h_new = _ssd_chunked(xv, dt, a, bm, cm, p_l["d_skip"], h0)

    y = y.reshape(y.shape[:2] + (d_inner,))
    y = base.rms_norm(y * jax.nn.silu(z), p_l["gate_ln"], cfg.norm_eps)
    out = x + y @ p_l["w_out"]
    new_cache = None
    if cache_l is not None:
        new_cache = {"conv": new_conv.astype(cache_l["conv"].dtype),
                     "ssm": h_new.astype(cache_l["ssm"].dtype)}
    return out, new_cache


def _apply(params, x, cfg, cache, mode):
    lp = params["layers"]

    def body(carry, xs):
        p_l, c_l = xs
        out, nc = _layer(p_l, carry, cfg,
                         c_l if cache is not None else None, mode)
        return out, (nc if nc is not None else jnp.zeros((), x.dtype))

    dummy = cache if cache is not None else jnp.zeros((cfg.num_layers,), x.dtype)
    fn = jax.checkpoint(body) if (cfg.remat and mode == "full") else body
    x, new_cache = jax.lax.scan(fn, x, (lp, dummy))
    return x, (new_cache if cache is not None else None)


def forward(params, tokens, cfg: ModelConfig, **_) -> jnp.ndarray:
    x = params["embed"][tokens]
    x, _ = _apply(params, x, cfg, None, "full")
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["unembed"]


def init_cache(cfg: ModelConfig, batch: int, max_len: int, disagg=False,
               dtype=None) -> Params:
    dt = jnp.float32                                 # states kept in f32
    d_inner, heads, head_p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    L = cfg.num_layers
    return {"conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), dt),
            "ssm": jnp.zeros((L, batch, heads, head_p, n), dt)}


def cache_logical_axes(cfg: ModelConfig, disagg=False) -> Params:
    return {"conv": ("layers", "batch", None, "inner"),
            "ssm": ("layers", "batch", None, "inner_head", "state")}


def prefill(params, tokens, cache, cfg: ModelConfig, *, start=0,
            lora=None, adapter_ids=None, disagg=False, extra_embeds=None):
    x = params["embed"][tokens]
    x, cache = _apply(params, x, cfg, cache, "prefill")
    x = base.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return x @ params["unembed"], cache


def decode_step(params, tokens, cache, kv_len, cfg: ModelConfig, *,
                lora=None, adapter_ids=None, disagg=False):
    x = params["embed"][tokens][:, None]
    x, cache = _apply(params, x, cfg, cache, "decode")
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["unembed"])[:, 0], cache
