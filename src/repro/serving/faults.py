"""Deterministic fault injection for the serving stack (DESIGN.md §17).

Tests, smoke.sh and CI need to *replay* failure schedules — "the third
pool allocation fails", "request r7's logits go NaN", "the first tier
demotion hits an IO error" — so the harness is a tiny seeded rule engine
rather than a random monkeypatcher:

  * every instrumented code path names its **site** and asks
    ``injector.fire(site, key=...)`` whether this particular call faults;
  * a **plan** maps sites to trigger lists; with a fixed seed the same
    plan fires at exactly the same calls on every run, which is what the
    preempt–restore parity gate relies on.

Plan grammar (``ServeConfig.fault_plan`` or ``FORKKV_FAULT_PLAN``)::

    site:trig,trig;site2:trig

with triggers

    cN     the Nth call at this site (1-based, per-site counter)
    rKEY   any call whose ``key`` argument equals KEY (e.g. a request id)
    pX     each call fires with probability X (seeded — deterministic)
    *      every call

Example: ``pool_alloc:c3,c4;nan_logits:r7`` fails the 3rd and 4th pool
allocations and poisons request 7's logits.

Known sites (grep for ``faults.fire`` / ``faults.io``):

  pool_alloc     device page allocation (engine._alloc) — fail → OOM path
  tier_demote    device→host page export (tiers.demote_node IO)
  tier_promote   host→device page import (tiers.promote_node IO)
  disk_io        disk-tier file read/write (tiers.DiskTier io_hook) —
                 spill failure drops the node, promote failure truncates
                 the match; either way the server recomputes (§18)
  nan_logits     poison one batch row's logits in-jit (engine step)
  pump_stall     sleep ``stall_s`` inside the step loop (watchdog food)
  executor       raise before the batched executor call (isolation test)
"""
from __future__ import annotations

import os
import random
import time
from typing import Dict, List, Optional

SITES = ("pool_alloc", "tier_demote", "tier_promote", "disk_io",
         "nan_logits", "pump_stall", "executor")


class FaultError(RuntimeError):
    """Raised by ``io()`` sites; carries the site name for assertions."""

    def __init__(self, site: str):
        super().__init__(f"injected fault at site '{site}'")
        self.site = site


class FaultInjector:
    """Seeded, deterministic fault plan evaluator.

    The default (empty plan) instance never fires and costs one dict
    lookup per instrumented call, so production paths keep it inline
    rather than branching on "faults enabled".
    """

    def __init__(self, plan: str = "", seed: int = 0, stall_s: float = 0.25):
        self.plan = plan or ""
        self.seed = int(seed)
        self.stall_s = float(stall_s)
        self._rng = random.Random(self.seed)
        self._rules: Dict[str, List[str]] = {}
        self._calls: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}
        for part in filter(None, (p.strip() for p in self.plan.split(";"))):
            site, _, trigs = part.partition(":")
            site = site.strip()
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site '{site}' (known: {', '.join(SITES)})")
            self._rules.setdefault(site, []).extend(
                t.strip() for t in trigs.split(",") if t.strip())

    @property
    def active(self) -> bool:
        return bool(self._rules)

    def fire(self, site: str, key=None) -> bool:
        """Should this call at ``site`` fault?  Increments the per-site
        call counter either way so cN triggers stay aligned."""
        rules = self._rules.get(site)
        if not rules:
            return False
        n = self._calls.get(site, 0) + 1
        self._calls[site] = n
        hit = False
        for trig in rules:
            if trig == "*":
                hit = True
            elif trig.startswith("c"):
                if n == int(trig[1:]):
                    hit = True
            elif trig.startswith("p"):
                if self._rng.random() < float(trig[1:]):
                    hit = True
            elif trig.startswith("r"):
                if key is not None and str(key) == trig[1:]:
                    hit = True
            else:
                raise ValueError(f"bad fault trigger '{trig}'")
            if hit:
                break
        if hit:
            self.fired[site] = self.fired.get(site, 0) + 1
        return hit

    def io(self, site: str, key=None) -> None:
        """Raise :class:`FaultError` when the plan fires — for sites that
        model IO failures (tier export/import, executor)."""
        if self.fire(site, key=key):
            raise FaultError(site)

    def maybe_stall(self, site: str = "pump_stall") -> None:
        """Sleep ``stall_s`` when the plan fires — feeds the watchdog."""
        if self.fire(site):
            time.sleep(self.stall_s)

    def stats(self) -> Dict[str, int]:
        return {f"fault_{s}": self.fired.get(s, 0) for s in self._rules}


def from_config(sc) -> FaultInjector:
    """Build the injector from ServeConfig, falling back to the
    FORKKV_FAULT_PLAN / FORKKV_FAULT_SEED environment (smoke/CI wiring)."""
    plan = getattr(sc, "fault_plan", "") or os.environ.get(
        "FORKKV_FAULT_PLAN", "")
    seed = getattr(sc, "fault_seed", 0) or int(os.environ.get(
        "FORKKV_FAULT_SEED", "0"))
    return FaultInjector(plan=plan, seed=seed)
