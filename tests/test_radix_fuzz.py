"""Property-based fuzz of the radix/CoW/tier lifecycle (hypothesis).

Random interleavings of fork / append(commit) / evict / demote / promote
against a dict-of-tokens oracle: every KV page carries its own tokens as
content (via fake export/import callbacks), so any refcount, CoW, or
tier-transition bug surfaces as a content mismatch on a later match — the
fuzz analogue of "the cache returned bytes that belong to someone else".

Checked after every operation:
  * no leaked transient locks (every ``lock_ref`` returns to 0);
  * device nodes own live pages (refcount >= 1) and no page is owned by
    two nodes of the same pool; host nodes hold live host-tier handles;
  * pool accounting (free + used == total) never drifts;
  * session-pinned prefixes survive arbitrary eviction/demotion pressure
    and keep matching in full;
  * matched pages always hold exactly the tokens they claim to cache
    (bit-identical through demote -> host-LRU -> promote round trips).

Optional-dep-guarded: skipped when ``hypothesis`` is unavailable
(requirements-dev.txt installs it in CI).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # minimal env: skip the fuzz suite
    HAVE_HYPOTHESIS = False

from repro.serving.pool import PagePool
from repro.serving.radix import DualRadixTree
from repro.serving.tiers import HostTier, TieredPagePool

PAGE = 4
N_PAGES = 24
ADAPTERS = (0, 1)


class FuzzHarness:
    """DualRadixTree over two tiered pools + a dict-of-tokens oracle."""

    def __init__(self, host_budget_bytes: int, promote_limit: int):
        self.host = HostTier(host_budget_bytes)
        self.mem = {"base": {}, "res": {}}      # page id -> token ndarray
        self.base_pool = TieredPagePool(
            PagePool(N_PAGES, PAGE, "base"), self.host,
            promote_limit=promote_limit)
        self.res_pool = TieredPagePool(
            PagePool(N_PAGES, PAGE, "residual"), self.host,
            promote_limit=promote_limit)
        self.dual = DualRadixTree(self.base_pool, self.res_pool)
        self.base_pool.bind(
            export_fn=lambda p: self._export("base", p),
            import_fn=lambda p, b: self._import("base", p, b),
            pressure_fn=lambda n: self.dual.base.evict(n))
        self.res_pool.bind(
            export_fn=lambda p: self._export("res", p),
            import_fn=lambda p, b: self._import("res", p, b),
            pressure_fn=lambda n: self.dual.residual.evict(n))
        self.committed = []                     # (tokens tuple, adapter_id)
        self.pinned = []                        # (tokens, aid, handle, len)

    # fake device<->host byte movement: one page blob = its tokens
    def _export(self, kind, pages):
        return [{"d": self.mem[kind][p].copy()} for p in pages]

    def _import(self, kind, pages, blobs):
        for p, b in zip(pages, blobs):
            self.mem[kind][p] = b["d"].copy()

    def _alloc(self, pool, evict, n):
        if n == 0:
            return []
        pages = pool.alloc(n)
        if pages is None:
            evict(n - pool.free_pages)
            pages = pool.alloc(n)
        return pages

    # --------------------------------------------------------------- ops
    def commit(self, tokens, aid):
        """Engine-style publish: alloc pages for the whole sequence, write
        their contents, insert into both trees, drop the local refs (the
        trees adopt the new suffix; duplicate prefix pages free)."""
        n = len(tokens) // PAGE
        base_pages = self._alloc(self.base_pool, self.dual.base.evict, n)
        if base_pages is None:
            return
        res_pages = self._alloc(self.res_pool, self.dual.residual.evict, n)
        if res_pages is None:
            self.base_pool.decref(base_pages)
            return
        for i in range(n):
            chunk = np.asarray(tokens[i * PAGE:(i + 1) * PAGE], np.int64)
            self.mem["base"][base_pages[i]] = chunk.copy()
            self.mem["res"][res_pages[i]] = chunk.copy()
        self.dual.commit(tokens, aid, base_pages, res_pages)
        self.base_pool.decref(base_pages)
        self.res_pool.decref(res_pages)
        if (tuple(tokens), aid) not in self.committed:
            self.committed.append((tuple(tokens), aid))

    def fork(self, tokens, aid):
        """fork + oracle check + release: whatever prefix the trees claim
        to have cached must hold exactly those tokens."""
        fr = self.dual.fork(tokens, aid, lock=True)
        try:
            for kind, matched, pages in (("base", fr.base_len,
                                          fr.base_pages),
                                         ("res", fr.res_len,
                                          fr.res_pages)):
                assert matched % PAGE == 0
                assert len(pages) == matched // PAGE, \
                    (kind, matched, pages)
                for i, p in enumerate(pages):
                    want = np.asarray(tokens[i * PAGE:(i + 1) * PAGE],
                                      np.int64)
                    np.testing.assert_array_equal(
                        self.mem[kind][p], want,
                        err_msg=f"{kind} page {p} holds foreign tokens")
            assert fr.reuse_len == min(fr.base_len, fr.res_len)
        finally:
            self.dual.release(fr, aid)

    def pin(self, tokens, aid):
        handle = self.dual.pin(tokens, aid)
        self.pinned.append((tokens, aid, handle, handle[2]))

    def unpin(self, idx):
        tokens, aid, handle, _ = self.pinned.pop(idx % len(self.pinned))
        self.dual.unpin(handle, aid)

    # -------------------------------------------------------- invariants
    def _iter_nodes(self, root):
        stack = list(root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def check(self):
        for pool, trees in ((self.base_pool, [self.dual.base]),
                            (self.res_pool,
                             list(self.dual.residual.trees.values()))):
            seen = set()
            for tree in trees:
                for node in self._iter_nodes(tree.root):
                    assert node.lock_ref == 0, "leaked transient lock"
                    assert node.pin_ref >= 0
                    if node.tier == "device":
                        for p in node.pages:
                            assert pool.refcount(p) >= 1, \
                                "tree references a freed page"
                            assert p not in seen, \
                                "page owned by two nodes"
                            seen.add(p)
                    else:
                        for h in node.pages:
                            assert h in self.host, \
                                "host node references a dropped handle"
            inner = pool.pool
            assert inner.free_pages + inner.used_pages == inner.num_pages
        assert self.host.used_bytes >= 0
        # pinned prefixes are immune to eviction AND demotion: they must
        # still match in full, without any tier promotion
        for tokens, aid, _, mlen in self.pinned:
            fr = self.dual.fork(tokens, aid, lock=False)
            assert fr.reuse_len >= mlen, "pinned prefix lost cache"

    def teardown(self):
        while self.pinned:
            self.unpin(0)
        self.dual.base.evict(N_PAGES)
        self.dual.residual.evict(N_PAGES)
        self.check()
        # with no pins and full eviction pressure, every device page must
        # be reclaimable — anything less is a refcount leak
        assert self.base_pool.pool.free_pages == N_PAGES
        assert self.res_pool.pool.free_pages == N_PAGES


if HAVE_HYPOTHESIS:
    def seqs(draw):
        """A page-aligned token sequence (1–4 pages, tiny alphabet so
        radix paths branch and share)."""
        return draw(st.lists(st.integers(0, 4), min_size=PAGE,
                             max_size=4 * PAGE).map(
            lambda t: t[:len(t) // PAGE * PAGE]))

    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_radix_cow_tier_fuzz(data):
        host_budget = data.draw(st.sampled_from(
            [0, 2 * PAGE * 8, 10 ** 6]), label="host_budget")
        promote_limit = data.draw(st.sampled_from([0, 1]),
                                  label="promote_limit")
        h = FuzzHarness(host_budget, promote_limit)
        n_ops = data.draw(st.integers(5, 30), label="n_ops")
        for _ in range(n_ops):
            op = data.draw(st.sampled_from(
                ["commit", "append", "fork", "evict_base", "evict_res",
                 "pin", "unpin"]), label="op")
            aid = data.draw(st.sampled_from(ADAPTERS), label="aid")
            if op == "commit":
                h.commit(seqs(data.draw), aid)
            elif op == "append" and h.committed:
                base, base_aid = h.committed[
                    data.draw(st.integers(0, len(h.committed) - 1))]
                h.commit(list(base) + seqs(data.draw), base_aid)
            elif op == "fork":
                if h.committed and data.draw(st.booleans()):
                    toks, aid = h.committed[
                        data.draw(st.integers(0, len(h.committed) - 1))]
                    cut = data.draw(st.integers(1, len(toks)))
                    h.fork(list(toks[:cut]), aid)
                else:
                    h.fork(seqs(data.draw) or [0] * PAGE, aid)
            elif op == "evict_base":
                h.dual.base.evict(data.draw(st.integers(1, N_PAGES)))
            elif op == "evict_res":
                h.dual.residual.evict(data.draw(st.integers(1, N_PAGES)))
            elif op == "pin" and h.committed and len(h.pinned) < 3:
                toks, aid = h.committed[
                    data.draw(st.integers(0, len(h.committed) - 1))]
                h.pin(list(toks), aid)
            elif op == "unpin" and h.pinned:
                h.unpin(data.draw(st.integers(0, 7)))
            h.check()
        h.teardown()
