"""Paged ResidualAttention kernels (TPU target): decode AND prefill.

The serving engine stores the disaggregated cache in page pools addressed by
block tables.  The dense kernels (residual_attention.py) assume the wrapper
gathered pages into contiguous views; THESE kernels consume the pools
directly — block tables ride in as scalar-prefetch operands and the
BlockSpec index maps dereference them, so each grid step DMA's exactly one
(page × kv_head) tile of bCache + one page of rCache from HBM.  This is the
Pallas analogue of SGLang's paged RadixAttention fused with ForkKV's
on-chip reconstruction (paper §5.3), and the production serving path on
real TPU (DESIGN.md §3, §12, §13).

Per-request page-count masking: the page axis of the grid is sized for the
widest request in the batch, but a request with ``kv_len`` tokens only has
``ceil(kv_len / page)`` live pages.  Grid steps past that point (a) clamp
their index maps to the request's last live page — the block index repeats,
so the Pallas pipeline skips the DMA re-fetch — and (b) skip the softmax
update entirely under ``pl.when``, so short requests pay FLOPs for their
own length, not the batch maximum.

Sliding windows (``window > 0``) clamp the page walk at BOTH ends: leading
pages entirely outside the attention window of the earliest query row are
clamped to the first in-window page (same repeated-block-index DMA skip)
and their FLOPs are skipped too, so a long-context SWA request pays for
``ceil(window/page) + 1`` trailing pages, not its whole history
(DESIGN.md §13).

Six variants:

* :func:`paged_residual_attention_decode` — disaggregated (bCache + rCache
  with per-request B_k/B_v up-projections, ForkKV mode).  RoPE for the
  reconstructed K residual is computed *in kernel* from the logical
  position (page_index·page_size + offset) — no sin/cos tables in HBM.
* :func:`paged_attention_decode_base` — base-only (unified caches: the
  prefix / full_reuse baselines, or ForkKV serving base-model requests
  with no adapter).  Same grid and skip logic, no residual stream.
* :func:`paged_residual_attention_prefill` — chunked prefill over the same
  pools: Q is a (chunk) tile per request, KV streams page by page with a
  causal mask inside the chunk and the running softmax carried across page
  steps in VMEM scratch.
* :func:`paged_attention_prefill_base` — base-only chunked prefill.
* :func:`paged_residual_attention_mixed` — the unified grid (DESIGN.md
  §14): one launch serves rows of DIFFERENT q-lengths — decode rows
  (q_len=1) and chunked-prefill rows (q_len=chunk) side by side in the
  same batch.  Each row's q-length rides in as a scalar-prefetch operand;
  rows are padded to the tile's chunk width and the per-row mask
  ``rowidx < q_len`` kills padding rows, whose outputs are written as
  exact zeros (deterministic across backends, unlike prefill's
  ignored-garbage rows).  This is what lets iteration-level continuous
  batching attend a mixed plan in ONE kernel launch instead of a prefill
  launch plus a decode launch.
* :func:`paged_attention_mixed_base` — base-only unified grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INIT = -1e30


def _last_live_page(kvl, page: int):
    """Index of the last page holding valid tokens (kv_len >= 1 assumed;
    clamps to page 0 for empty/padded rows)."""
    return jnp.maximum(kvl - 1, 0) // page


def _first_window_page(qpos_min, page: int, window: int):
    """Index of the first page intersecting the attention window of the
    earliest query row (``kpos >= qpos_min - window + 1``).  Only
    meaningful for ``window > 0``."""
    return jnp.maximum(qpos_min - (window - 1), 0) // page


def _reconstruct_k(kb_ref, kr_ref, bk_ref, j, *, page: int, d: int,
                   rope_theta: float, use_rope: bool, ks_ref=None):
    """In-kernel K reconstruction with deferred RoPE — shared by the
    disaggregated decode and prefill kernel bodies so a numerics fix can
    never diverge the two paths: K = K_b + RoPE(K_r B_k), with RoPE
    computed from the logical position (j·page + offset), no sin/cos
    tables in HBM.  When ``ks_ref`` is given the bCache tile is int8 and
    is dequantized in VMEM with its (page, 1) per-token scale before the
    residual is folded in (DESIGN.md §18) — the residual stream stays
    full precision.  Returns a (page, D) f32 tile."""
    k_b = kb_ref[0, :, 0, :].astype(jnp.float32)               # (page, D)
    if ks_ref is not None:
        k_b = k_b * ks_ref[0]                                  # (page, 1)
    k_r = kr_ref[0].astype(jnp.float32)                        # (page, R)
    b_k = bk_ref[0, 0].astype(jnp.float32)                     # (R, D)
    k_lora = jnp.dot(k_r, b_k, preferred_element_type=jnp.float32)
    if use_rope:
        pos = (j * page + jax.lax.broadcasted_iota(
            jnp.int32, (page, 1), 0)).astype(jnp.float32)      # (page, 1)
        half = d // 2
        freqs = 1.0 / (rope_theta ** (
            jax.lax.broadcasted_iota(jnp.float32, (1, half), 1) / half))
        ang = pos * freqs                                      # (page, half)
        sin, cos = jnp.sin(ang), jnp.cos(ang)
        x1, x2 = k_lora[:, :half], k_lora[:, half:]
        k_lora = jnp.concatenate([x1 * cos - x2 * sin,
                                  x2 * cos + x1 * sin], axis=-1)
    return k_b + k_lora


def _softmax_update(s, mask, m_scr, l_scr, acc_scr, v_b,
                    accr_scr=None, v_r=None):
    """One online-softmax step over a (rows, page) score tile — the
    single implementation behind all four kernel bodies.  Rescales the
    running accumulators by alpha and folds in this page's masked probs;
    the residual accumulator update is skipped for base-only kernels."""
    s = jnp.where(mask, s, NEG_INIT)
    m_prev = m_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new) * mask
    l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v_b, preferred_element_type=jnp.float32)
    if accr_scr is not None:
        accr_scr[...] = accr_scr[...] * alpha + jnp.dot(
            p, v_r, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)


def _kernel(bt_b_ref, bt_r_ref, kvlen_ref, q_ref, kb_ref, vb_ref, *rest,
            scale: float, page: int, window: int,
            rope_theta: float, use_rope: bool, quant: bool = False):
    # ``quant`` is a trace-time static: the int8 variant threads two extra
    # scale operands right after the bCache tiles, so the ref list is
    # unpacked per-variant instead of duplicating the whole body.
    if quant:
        (ks_ref, vs_ref, kr_ref, vr_ref, bk_ref, bv_ref, out_ref,
         m_scr, l_scr, acc_scr, accr_scr) = rest
    else:
        (kr_ref, vr_ref, bk_ref, bv_ref, out_ref,
         m_scr, l_scr, acc_scr, accr_scr) = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g, d = q_ref.shape[2], q_ref.shape[3]
    kvlen = kvlen_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accr_scr[...] = jnp.zeros_like(accr_scr)

    # pages past ceil(kv_len/page) contribute nothing: skip their FLOPs
    # (their DMA is already skipped by the clamped index maps).  With a
    # sliding window the query sits at kvlen-1, so pages entirely before
    # kvlen - window are dead too (their DMA repeats the first in-window
    # page and is likewise skipped).
    live = j * page < kvlen
    if window > 0:
        live = live & ((j + 1) * page > kvlen - window)

    @pl.when(live)
    def _compute():
        k = _reconstruct_k(kb_ref, kr_ref, bk_ref, j, page=page, d=d,
                           rope_theta=rope_theta, use_rope=use_rope,
                           ks_ref=ks_ref)
        q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = kpos < kvlen
        if window > 0:
            mask = mask & (kpos > kvlen - 1 - window)
        v_b = vb_ref[0, :, 0, :].astype(jnp.float32)
        if vs_ref is not None:
            v_b = v_b * vs_ref[0]
        _softmax_update(s, mask, m_scr, l_scr, acc_scr, v_b,
                        accr_scr, vr_ref[0].astype(jnp.float32))

    @pl.when(j == nj - 1)
    def _fini():
        b_v = bv_ref[0, 0].astype(jnp.float32)
        acc = acc_scr[...] + jnp.dot(accr_scr[...], b_v,
                                     preferred_element_type=jnp.float32)
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out_ref[0, 0] = (acc / l).astype(out_ref.dtype)


def _decode_page_clamp(page: int, window: int):
    """Index-map page clamp for decode: dead grid steps repeat a live
    page's block index so the Pallas pipeline skips their DMA.  Trailing
    steps clamp to the last live page; with a sliding window, leading
    steps clamp to the first in-window page."""
    def clamp(j, kvl):
        jc = jnp.minimum(j, _last_live_page(kvl, page))
        if window > 0:
            jc = jnp.maximum(jc, _first_window_page(kvl - 1, page, window))
        return jc
    return clamp


@functools.partial(jax.jit, static_argnames=("scale", "window", "rope_theta",
                                             "use_rope", "interpret"))
def paged_residual_attention_decode(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                    b_k, b_v, bt_b, bt_r, kv_len, *,
                                    scale: float, window: int = 0,
                                    rope_theta: float = 10_000.0,
                                    use_rope: bool = True,
                                    kb_scale=None, vb_scale=None,
                                    interpret: bool = True):
    """Decode over paged disaggregated caches.

    q:        (B, Hq, D)
    kb/vb:    (P,  page, Hkv, D) base pools (K RoPE'd at write time)
    kr/vr:    (Pr, page, R)      residual pools (no RoPE, scaled)
    b_k/b_v:  (B, R, Hkv*D)      per-request up-projections
    bt_b/bt_r:(B, n_pages) int32 block tables (logical page -> pool page)
    kv_len:   (B,) valid tokens; ``window > 0`` restricts attention to the
    trailing ``window`` positions (SWA).  ``kb_scale``/``vb_scale``
    ((P, page, Hkv) f32, or None) mark the base pools as int8-quantized:
    each page tile is dequantized in VMEM next to the running softmax
    (DESIGN.md §18).  Returns (B, Hq, D).
    """
    bsz, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    r = kr_pool.shape[-1]
    n_pages = bt_b.shape[1]
    quant = kb_scale is not None

    qt = q.reshape(bsz, hkv, g, d)
    bkt = b_k.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)
    bvt = b_v.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, page=page,
                               window=window, rope_theta=rope_theta,
                               use_rope=use_rope, quant=quant)

    clamp = _decode_page_clamp(page, window)

    def _b_map(b, h, j, btb, btr, kvl):
        return (btb[b, clamp(j, kvl[b])], 0, h, 0)

    def _s_map(b, h, j, btb, btr, kvl):
        return (btb[b, clamp(j, kvl[b])], 0, h)

    def _r_map(b, h, j, btb, btr, kvl):
        return (btr[b, clamp(j, kvl[b])], 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
        pl.BlockSpec((1, page, 1, d), _b_map),
        pl.BlockSpec((1, page, 1, d), _b_map),
    ]
    operands = [qt, kb_pool, vb_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _s_map),
                     pl.BlockSpec((1, page, 1), _s_map)]
        operands += [kb_scale, vb_scale]
    in_specs += [
        pl.BlockSpec((1, page, r), _r_map),
        pl.BlockSpec((1, page, r), _r_map),
        pl.BlockSpec((1, 1, r, d),
                     lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, r, d),
                     lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
    ]
    operands += [kr_pool, vr_pool, bkt, bvt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bsz, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h, j, btb, btr, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), bt_r.astype(jnp.int32),
      kv_len.astype(jnp.int32), *operands)
    return out.reshape(bsz, hq, d)


# --------------------------------------------------------------------------
# Base-only variant (unified caches / no-LoRA requests)
# --------------------------------------------------------------------------
def _kernel_base(bt_b_ref, kvlen_ref, q_ref, kb_ref, vb_ref, *rest,
                 scale: float, page: int, window: int,
                 quant: bool = False):
    if quant:
        ks_ref, vs_ref, out_ref, m_scr, l_scr, acc_scr = rest
    else:
        out_ref, m_scr, l_scr, acc_scr = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    kvlen = kvlen_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = j * page < kvlen
    if window > 0:
        live = live & ((j + 1) * page > kvlen - window)

    @pl.when(live)
    def _compute():
        k = kb_ref[0, :, 0, :].astype(jnp.float32)             # (page, D)
        if ks_ref is not None:
            k = k * ks_ref[0]
        q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = kpos < kvlen
        if window > 0:
            mask = mask & (kpos > kvlen - 1 - window)
        v_b = vb_ref[0, :, 0, :].astype(jnp.float32)
        if vs_ref is not None:
            v_b = v_b * vs_ref[0]
        _softmax_update(s, mask, m_scr, l_scr, acc_scr, v_b)

    @pl.when(j == nj - 1)
    def _fini():
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out_ref[0, 0] = (acc_scr[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_attention_decode_base(q, kb_pool, vb_pool, bt_b, kv_len, *,
                                scale: float, window: int = 0,
                                kb_scale=None, vb_scale=None,
                                interpret: bool = True):
    """Base-only paged decode: attention over the bCache pool alone.

    Serves the unified-cache baselines (prefix / full_reuse) and ForkKV
    requests without an adapter.  Same shapes as the disaggregated variant
    minus the residual stream:

    q: (B, Hq, D); kb/vb: (P, page, Hkv, D); bt_b: (B, n_pages);
    kv_len: (B,); kb_scale/vb_scale: (P, page, Hkv) f32 int8 dequant
    scales, or None.  Returns (B, Hq, D).
    """
    bsz, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    n_pages = bt_b.shape[1]
    quant = kb_scale is not None
    qt = q.reshape(bsz, hkv, g, d)

    kernel = functools.partial(_kernel_base, scale=scale, page=page,
                               window=window, quant=quant)
    clamp = _decode_page_clamp(page, window)

    def _b_map(b, h, j, btb, kvl):
        return (btb[b, clamp(j, kvl[b])], 0, h, 0)

    def _s_map(b, h, j, btb, kvl):
        return (btb[b, clamp(j, kvl[b])], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda b, h, j, btb, kvl: (b, h, 0, 0)),
        pl.BlockSpec((1, page, 1, d), _b_map),
        pl.BlockSpec((1, page, 1, d), _b_map),
    ]
    operands = [qt, kb_pool, vb_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _s_map),
                     pl.BlockSpec((1, page, 1), _s_map)]
        operands += [kb_scale, vb_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h, j, btb, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), kv_len.astype(jnp.int32), *operands)
    return out.reshape(bsz, hq, d)


# --------------------------------------------------------------------------
# Chunked prefill variants (Q is a chunk tile, KV streams from the pools)
# --------------------------------------------------------------------------
def _prefill_page_clamp(page: int, window: int):
    """Index-map page clamp for prefill: trailing dead steps repeat the last
    live page; with a sliding window, leading steps repeat the first page
    that intersects the EARLIEST query row's window (``start``)."""
    def clamp(j, kvl, st):
        jc = jnp.minimum(j, _last_live_page(kvl, page))
        if window > 0:
            jc = jnp.maximum(jc, _first_window_page(st, page, window))
        return jc
    return clamp


def _kernel_prefill(bt_b_ref, bt_r_ref, kvlen_ref, start_ref, q_ref, kb_ref,
                    vb_ref, *rest, scale: float, page: int,
                    window: int, rope_theta: float, use_rope: bool,
                    quant: bool = False):
    if quant:
        (ks_ref, vs_ref, kr_ref, vr_ref, bk_ref, bv_ref, out_ref,
         m_scr, l_scr, acc_scr, accr_scr) = rest
    else:
        (kr_ref, vr_ref, bk_ref, bv_ref, out_ref,
         m_scr, l_scr, acc_scr, accr_scr) = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g, chunk, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    rows = g * chunk
    kvlen = kvlen_ref[b]        # valid tokens INCLUDING this chunk's writes
    start = start_ref[b]        # absolute position of the chunk's first row

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accr_scr[...] = jnp.zeros_like(accr_scr)

    # dead pages: past the last live page, or (SWA) entirely before the
    # earliest query row's window.  Their DMA is skipped by the clamped
    # index maps; skip their FLOPs here.
    live = j * page < kvlen
    if window > 0:
        live = live & ((j + 1) * page > start - (window - 1))

    @pl.when(live)
    def _compute():
        k = _reconstruct_k(kb_ref, kr_ref, bk_ref, j, page=page, d=d,
                           rope_theta=rope_theta, use_rope=use_rope,
                           ks_ref=ks_ref)
        # causal chunk scores; the online softmax carries across page steps
        q = q_ref[0, 0].astype(jnp.float32).reshape(rows, d)   # (G*chunk, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        rowpos = start + jax.lax.broadcasted_iota(
            jnp.int32, (g, chunk), 1).reshape(rows, 1)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = (kpos < kvlen) & (kpos <= rowpos)
        if window > 0:
            mask = mask & (kpos > rowpos - window)
        v_b = vb_ref[0, :, 0, :].astype(jnp.float32)
        if vs_ref is not None:
            v_b = v_b * vs_ref[0]
        _softmax_update(s, mask, m_scr, l_scr, acc_scr, v_b,
                        accr_scr, vr_ref[0].astype(jnp.float32))

    @pl.when(j == nj - 1)
    def _fini():
        b_v = bv_ref[0, 0].astype(jnp.float32)
        acc = acc_scr[...] + jnp.dot(accr_scr[...], b_v,
                                     preferred_element_type=jnp.float32)
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out_ref[0, 0] = (acc / l).reshape(g, chunk, d).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "rope_theta",
                                             "use_rope", "interpret"))
def paged_residual_attention_prefill(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                     b_k, b_v, bt_b, bt_r, start, kv_len, *,
                                     scale: float, window: int = 0,
                                     rope_theta: float = 10_000.0,
                                     use_rope: bool = True,
                                     kb_scale=None, vb_scale=None,
                                     interpret: bool = True):
    """Chunked prefill over paged disaggregated caches (DESIGN.md §13).

    The chunk's own K/V must already be written into the pools (the
    executor writes before attending), so the causal mask inside the chunk
    is pure masking — no separate self-attention pass.

    q:        (B, chunk, Hq, D) RoPE'd queries
    kb/vb:    (P,  page, Hkv, D) base pools;  kr/vr: (Pr, page, R)
    b_k/b_v:  (B, R, Hkv*D) per-request up-projections
    bt_b/bt_r:(B, n_pages) block tables
    start:    (B,) absolute position of each chunk's first query row
    kv_len:   (B,) valid tokens incl. this chunk's writes (= start+n_valid;
              rows past kv_len-1 are padding and produce garbage rows the
              caller must ignore).  Returns (B, chunk, Hq, D).
    """
    bsz, sq, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    r = kr_pool.shape[-1]
    n_pages = bt_b.shape[1]
    rows = g * sq
    quant = kb_scale is not None

    qt = q.reshape(bsz, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
    bkt = b_k.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)
    bvt = b_v.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel_prefill, scale=scale, page=page,
                               window=window, rope_theta=rope_theta,
                               use_rope=use_rope, quant=quant)
    clamp = _prefill_page_clamp(page, window)

    def _b_map(b, h, j, btb, btr, kvl, st):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h, 0)

    def _s_map(b, h, j, btb, btr, kvl, st):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h)

    def _r_map(b, h, j, btb, btr, kvl, st):
        return (btr[b, clamp(j, kvl[b], st[b])], 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, sq, d),
                     lambda b, h, j, btb, btr, kvl, st: (b, h, 0, 0, 0)),
        pl.BlockSpec((1, page, 1, d), _b_map),
        pl.BlockSpec((1, page, 1, d), _b_map),
    ]
    operands = [qt, kb_pool, vb_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _s_map),
                     pl.BlockSpec((1, page, 1), _s_map)]
        operands += [kb_scale, vb_scale]
    in_specs += [
        pl.BlockSpec((1, page, r), _r_map),
        pl.BlockSpec((1, page, r), _r_map),
        pl.BlockSpec((1, 1, r, d),
                     lambda b, h, j, btb, btr, kvl, st: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, r, d),
                     lambda b, h, j, btb, btr, kvl, st: (b, h, 0, 0)),
    ]
    operands += [kr_pool, vr_pool, bkt, bvt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, sq, d),
            lambda b, h, j, btb, btr, kvl, st: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, d), jnp.float32),
            pltpu.VMEM((rows, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, sq, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), bt_r.astype(jnp.int32),
      kv_len.astype(jnp.int32), start.astype(jnp.int32), *operands)
    return out.transpose(0, 3, 1, 2, 4).reshape(bsz, sq, hq, d)


def _kernel_prefill_base(bt_b_ref, kvlen_ref, start_ref, q_ref, kb_ref,
                         vb_ref, *rest, scale: float, page: int,
                         window: int, quant: bool = False):
    if quant:
        ks_ref, vs_ref, out_ref, m_scr, l_scr, acc_scr = rest
    else:
        out_ref, m_scr, l_scr, acc_scr = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g, chunk, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    rows = g * chunk
    kvlen = kvlen_ref[b]
    start = start_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = j * page < kvlen
    if window > 0:
        live = live & ((j + 1) * page > start - (window - 1))

    @pl.when(live)
    def _compute():
        k = kb_ref[0, :, 0, :].astype(jnp.float32)             # (page, D)
        if ks_ref is not None:
            k = k * ks_ref[0]
        q = q_ref[0, 0].astype(jnp.float32).reshape(rows, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        rowpos = start + jax.lax.broadcasted_iota(
            jnp.int32, (g, chunk), 1).reshape(rows, 1)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = (kpos < kvlen) & (kpos <= rowpos)
        if window > 0:
            mask = mask & (kpos > rowpos - window)
        v_b = vb_ref[0, :, 0, :].astype(jnp.float32)
        if vs_ref is not None:
            v_b = v_b * vs_ref[0]
        _softmax_update(s, mask, m_scr, l_scr, acc_scr, v_b)

    @pl.when(j == nj - 1)
    def _fini():
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out_ref[0, 0] = (acc_scr[...] / l).reshape(
            g, chunk, d).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_attention_prefill_base(q, kb_pool, vb_pool, bt_b, start, kv_len, *,
                                 scale: float, window: int = 0,
                                 kb_scale=None, vb_scale=None,
                                 interpret: bool = True):
    """Base-only chunked prefill: unified caches / no-LoRA requests, and
    the broadcast-fork base trajectory.  Shapes as the disaggregated
    variant minus the residual stream.  Returns (B, chunk, Hq, D)."""
    bsz, sq, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    n_pages = bt_b.shape[1]
    rows = g * sq
    quant = kb_scale is not None
    qt = q.reshape(bsz, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)

    kernel = functools.partial(_kernel_prefill_base, scale=scale, page=page,
                               window=window, quant=quant)
    clamp = _prefill_page_clamp(page, window)

    def _b_map(b, h, j, btb, kvl, st):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h, 0)

    def _s_map(b, h, j, btb, kvl, st):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, g, sq, d),
                     lambda b, h, j, btb, kvl, st: (b, h, 0, 0, 0)),
        pl.BlockSpec((1, page, 1, d), _b_map),
        pl.BlockSpec((1, page, 1, d), _b_map),
    ]
    operands = [qt, kb_pool, vb_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _s_map),
                     pl.BlockSpec((1, page, 1), _s_map)]
        operands += [kb_scale, vb_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bsz, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, sq, d),
            lambda b, h, j, btb, kvl, st: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, sq, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), kv_len.astype(jnp.int32),
      start.astype(jnp.int32), *operands)
    return out.transpose(0, 3, 1, 2, 4).reshape(bsz, sq, hq, d)


# --------------------------------------------------------------------------
# Unified mixed prefill/decode grid (DESIGN.md §14)
# --------------------------------------------------------------------------
def _kernel_mixed(bt_b_ref, bt_r_ref, kvlen_ref, start_ref, qlen_ref, q_ref,
                  kb_ref, vb_ref, *rest, scale: float,
                  page: int, window: int, rope_theta: float,
                  use_rope: bool, quant: bool = False):
    """Prefill kernel body generalized with a per-row q-length: rows past
    ``q_len`` are masked everywhere and written out as zeros, and rows
    with ``q_len == 0`` (batch padding) skip every page's FLOPs."""
    if quant:
        (ks_ref, vs_ref, kr_ref, vr_ref, bk_ref, bv_ref, out_ref,
         m_scr, l_scr, acc_scr, accr_scr) = rest
    else:
        (kr_ref, vr_ref, bk_ref, bv_ref, out_ref,
         m_scr, l_scr, acc_scr, accr_scr) = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g, chunk, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    rows = g * chunk
    kvlen = kvlen_ref[b]        # valid tokens INCLUDING this row's writes
    start = start_ref[b]        # absolute position of the row's first query
    qlen = qlen_ref[b]          # valid query rows (1 = decode, chunk = full)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accr_scr[...] = jnp.zeros_like(accr_scr)

    live = (qlen > 0) & (j * page < kvlen)
    if window > 0:
        live = live & ((j + 1) * page > start - (window - 1))

    @pl.when(live)
    def _compute():
        k = _reconstruct_k(kb_ref, kr_ref, bk_ref, j, page=page, d=d,
                           rope_theta=rope_theta, use_rope=use_rope,
                           ks_ref=ks_ref)
        q = q_ref[0, 0].astype(jnp.float32).reshape(rows, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        rowidx = jax.lax.broadcasted_iota(
            jnp.int32, (g, chunk), 1).reshape(rows, 1)
        rowpos = start + rowidx
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = (kpos < kvlen) & (kpos <= rowpos) & (rowidx < qlen)
        if window > 0:
            mask = mask & (kpos > rowpos - window)
        v_b = vb_ref[0, :, 0, :].astype(jnp.float32)
        if vs_ref is not None:
            v_b = v_b * vs_ref[0]
        _softmax_update(s, mask, m_scr, l_scr, acc_scr, v_b,
                        accr_scr, vr_ref[0].astype(jnp.float32))

    @pl.when(j == nj - 1)
    def _fini():
        b_v = bv_ref[0, 0].astype(jnp.float32)
        acc = acc_scr[...] + jnp.dot(accr_scr[...], b_v,
                                     preferred_element_type=jnp.float32)
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        rowidx = jax.lax.broadcasted_iota(
            jnp.int32, (g, chunk), 1).reshape(rows, 1)
        out = jnp.where(rowidx < qlen, acc / l, 0.0)
        out_ref[0, 0] = out.reshape(g, chunk, d).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "rope_theta",
                                             "use_rope", "interpret"))
def paged_residual_attention_mixed(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                   b_k, b_v, bt_b, bt_r, start, q_len,
                                   kv_len, *, scale: float, window: int = 0,
                                   rope_theta: float = 10_000.0,
                                   use_rope: bool = True,
                                   kb_scale=None, vb_scale=None,
                                   interpret: bool = True):
    """Unified mixed prefill/decode grid over paged disaggregated caches.

    Identical to :func:`paged_residual_attention_prefill` except each row
    additionally carries ``q_len`` (B,) — its count of VALID query rows —
    as a scalar-prefetch operand: a decode row is ``q_len=1`` (its single
    query padded up to the tile's chunk width), a prefill row uses its
    whole chunk.  Rows past ``q_len`` produce exact zeros; ``q_len=0``
    rows (batch padding) skip all FLOPs.  ``kv_len`` must equal
    ``start + q_len`` per row.  Returns (B, chunk, Hq, D).
    """
    bsz, sq, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    r = kr_pool.shape[-1]
    n_pages = bt_b.shape[1]
    rows = g * sq
    quant = kb_scale is not None

    qt = q.reshape(bsz, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
    bkt = b_k.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)
    bvt = b_v.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel_mixed, scale=scale, page=page,
                               window=window, rope_theta=rope_theta,
                               use_rope=use_rope, quant=quant)
    clamp = _prefill_page_clamp(page, window)

    def _b_map(b, h, j, btb, btr, kvl, st, ql):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h, 0)

    def _s_map(b, h, j, btb, btr, kvl, st, ql):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h)

    def _r_map(b, h, j, btb, btr, kvl, st, ql):
        return (btr[b, clamp(j, kvl[b], st[b])], 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, sq, d),
                     lambda b, h, j, btb, btr, kvl, st, ql:
                     (b, h, 0, 0, 0)),
        pl.BlockSpec((1, page, 1, d), _b_map),
        pl.BlockSpec((1, page, 1, d), _b_map),
    ]
    operands = [qt, kb_pool, vb_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _s_map),
                     pl.BlockSpec((1, page, 1), _s_map)]
        operands += [kb_scale, vb_scale]
    in_specs += [
        pl.BlockSpec((1, page, r), _r_map),
        pl.BlockSpec((1, page, r), _r_map),
        pl.BlockSpec((1, 1, r, d),
                     lambda b, h, j, btb, btr, kvl, st, ql: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, r, d),
                     lambda b, h, j, btb, btr, kvl, st, ql: (b, h, 0, 0)),
    ]
    operands += [kr_pool, vr_pool, bkt, bvt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(bsz, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, sq, d),
            lambda b, h, j, btb, btr, kvl, st, ql: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, d), jnp.float32),
            pltpu.VMEM((rows, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, sq, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), bt_r.astype(jnp.int32),
      kv_len.astype(jnp.int32), start.astype(jnp.int32),
      q_len.astype(jnp.int32), *operands)
    return out.transpose(0, 3, 1, 2, 4).reshape(bsz, sq, hq, d)


def _kernel_mixed_base(bt_b_ref, kvlen_ref, start_ref, qlen_ref, q_ref,
                       kb_ref, vb_ref, *rest, scale: float, page: int,
                       window: int, quant: bool = False):
    if quant:
        ks_ref, vs_ref, out_ref, m_scr, l_scr, acc_scr = rest
    else:
        out_ref, m_scr, l_scr, acc_scr = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g, chunk, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    rows = g * chunk
    kvlen = kvlen_ref[b]
    start = start_ref[b]
    qlen = qlen_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = (qlen > 0) & (j * page < kvlen)
    if window > 0:
        live = live & ((j + 1) * page > start - (window - 1))

    @pl.when(live)
    def _compute():
        k = kb_ref[0, :, 0, :].astype(jnp.float32)
        if ks_ref is not None:
            k = k * ks_ref[0]
        q = q_ref[0, 0].astype(jnp.float32).reshape(rows, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        rowidx = jax.lax.broadcasted_iota(
            jnp.int32, (g, chunk), 1).reshape(rows, 1)
        rowpos = start + rowidx
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = (kpos < kvlen) & (kpos <= rowpos) & (rowidx < qlen)
        if window > 0:
            mask = mask & (kpos > rowpos - window)
        v_b = vb_ref[0, :, 0, :].astype(jnp.float32)
        if vs_ref is not None:
            v_b = v_b * vs_ref[0]
        _softmax_update(s, mask, m_scr, l_scr, acc_scr, v_b)

    @pl.when(j == nj - 1)
    def _fini():
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        rowidx = jax.lax.broadcasted_iota(
            jnp.int32, (g, chunk), 1).reshape(rows, 1)
        out = jnp.where(rowidx < qlen, acc_scr[...] / l, 0.0)
        out_ref[0, 0] = out.reshape(g, chunk, d).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_attention_mixed_base(q, kb_pool, vb_pool, bt_b, start, q_len,
                               kv_len, *, scale: float, window: int = 0,
                               kb_scale=None, vb_scale=None,
                               interpret: bool = True):
    """Base-only unified mixed grid: unified caches / no-LoRA requests.
    Shapes as :func:`paged_residual_attention_mixed` minus the residual
    stream.  Returns (B, chunk, Hq, D)."""
    bsz, sq, hq, d = q.shape
    page, hkv = kb_pool.shape[1], kb_pool.shape[2]
    g = hq // hkv
    n_pages = bt_b.shape[1]
    rows = g * sq
    quant = kb_scale is not None
    qt = q.reshape(bsz, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)

    kernel = functools.partial(_kernel_mixed_base, scale=scale, page=page,
                               window=window, quant=quant)
    clamp = _prefill_page_clamp(page, window)

    def _b_map(b, h, j, btb, kvl, st, ql):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h, 0)

    def _s_map(b, h, j, btb, kvl, st, ql):
        return (btb[b, clamp(j, kvl[b], st[b])], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, g, sq, d),
                     lambda b, h, j, btb, kvl, st, ql: (b, h, 0, 0, 0)),
        pl.BlockSpec((1, page, 1, d), _b_map),
        pl.BlockSpec((1, page, 1, d), _b_map),
    ]
    operands = [qt, kb_pool, vb_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _s_map),
                     pl.BlockSpec((1, page, 1), _s_map)]
        operands += [kb_scale, vb_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bsz, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, sq, d),
            lambda b, h, j, btb, kvl, st, ql: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, sq, d), q.dtype),
        interpret=interpret,
    )(bt_b.astype(jnp.int32), kv_len.astype(jnp.int32),
      start.astype(jnp.int32), q_len.astype(jnp.int32), *operands)
    return out.transpose(0, 3, 1, 2, 4).reshape(bsz, sq, hq, d)
