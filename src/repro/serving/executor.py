"""Paged model executor: jit'd prefill/decode over pooled KV pages.

The pools are jnp arrays of shape (L, num_pages, page_size, ...); requests
address them through block tables.  In ForkKV mode two pools exist — the
shared bCache pool and the per-agent rCache pool — and attention runs over
the disaggregated layout (the XLA mirror of the ResidualAttention kernel;
on real TPU the gather + attend lowers to the Pallas kernel with paged
index maps, see DESIGN.md §3).

CoW discipline: prefill never writes to inherited (shared) pages — the
engine passes the reserved DUMP page as the write target for positions
whose cache is inherited, so parent pages stay read-only.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig, ServeConfig
from repro.models import base
from repro.models import transformer as tfm
from repro.serving.sampling import sample_tokens

Params = Dict


class Pools(NamedTuple):
    kb: jnp.ndarray          # (L, Pb, page, Hkv, hd)  base K (RoPE'd)
    vb: jnp.ndarray          # (L, Pb, page, Hkv, hd)  base V
    kr: Optional[jnp.ndarray]  # (L, Pr, page, R)      residual K (no RoPE)
    vr: Optional[jnp.ndarray]


def make_pools(cfg: ModelConfig, num_pages: int, num_res_pages: int,
               page_size: int, disagg: bool, dtype=None) -> Pools:
    dt = dtype or cfg.activation_dtype
    L, hd = cfg.num_layers, cfg.resolved_head_dim
    kb = jnp.zeros((L, num_pages, page_size, cfg.num_kv_heads, hd), dt)
    vb = jnp.zeros_like(kb)
    if disagg:
        kr = jnp.zeros((L, num_res_pages, page_size, cfg.lora.rank), dt)
        vr = jnp.zeros_like(kr)
    else:
        kr = vr = None
    return Pools(kb, vb, kr, vr)


def pool_bytes(pools: Pools) -> Dict[str, int]:
    out = {"base": int(pools.kb.nbytes + pools.vb.nbytes)}
    out["residual"] = int(pools.kr.nbytes + pools.vr.nbytes) \
        if pools.kr is not None else 0
    return out


class PagedExecutor:
    """Compiled paged prefill/decode for llama-family models."""

    def __init__(self, cfg: ModelConfig, params: Params,
                 lora: Optional[Params], serve_cfg: ServeConfig,
                 disagg: bool, max_pages_per_req: int):
        self.cfg = cfg
        self.params = params
        self.lora = lora
        self.sc = serve_cfg
        self.disagg = disagg and lora is not None
        self.page = serve_cfg.page_size
        self.max_pages_per_req = max_pages_per_req
        self.smax = max_pages_per_req * self.page
        res_factor = max(1, cfg.kv_dim // max(cfg.lora.rank, 1))             if self.disagg else 1
        self.num_res_pages = serve_cfg.max_pages * res_factor             if self.disagg else serve_cfg.max_pages
        self.pools = make_pools(cfg, serve_cfg.max_pages,
                                self.num_res_pages, self.page, self.disagg)
        self.dump_page = serve_cfg.max_pages - 1   # reserved scratch page
        # ``sampled`` is static: all-greedy batches (the default) compile
        # the seed's pure-argmax body with the sampling math dead-code
        # eliminated; a second variant exists only once sampling is used
        self._decode = jax.jit(self._decode_fn, donate_argnums=(0,),
                               static_argnames=("sampled",))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(0,),
                                static_argnames=("chunk", "sampled"))

    # ------------------------------------------------ tiered KV offload
    def export_pages(self, kind: str,
                     page_ids: Sequence[int]) -> List[Dict]:
        """Device→host copy of whole KV pages (DESIGN.md §10).

        ``kind`` selects the pool ("base" → kb/vb, "res" → kr/vr).  Returns
        one blob per page — ``{"k": (L, page, ...), "v": ...}`` numpy
        arrays holding the exact bytes, so a later :meth:`import_pages`
        restores the cache bit-identically.
        """
        ids = jnp.asarray(list(page_ids), jnp.int32)
        if kind == "base":
            k, v = self.pools.kb, self.pools.vb
        else:
            k, v = self.pools.kr, self.pools.vr
        karr = np.asarray(k[:, ids])          # (L, n, page, ...)
        varr = np.asarray(v[:, ids])
        # per-page COPIES, not views: each blob must be independently
        # freeable or the HostTier's byte accounting undercounts (a
        # surviving 1-page view would pin the whole n-page export)
        return [{"k": karr[:, i].copy(), "v": varr[:, i].copy()}
                for i in range(len(page_ids))]

    def import_pages(self, kind: str, page_ids: Sequence[int],
                     blobs: Sequence[Dict]) -> None:
        """Host→device copy: write blobs back into freshly allocated pages
        (the promotion half of the tier lifecycle).

        The scatter runs jitted with the pools donated, so XLA updates the
        pool buffers in place — O(pages promoted), not a copy of the whole
        pool.  Page counts are bucketed to powers of two (padding repeats
        page 0 with its own blob: duplicate index, identical value) so the
        number of compiled variants stays logarithmic.
        """
        n = len(page_ids)
        npad = 1 << max(0, n - 1).bit_length()
        ids = list(page_ids) + [page_ids[0]] * (npad - n)
        blobs = list(blobs) + [blobs[0]] * (npad - n)
        k = jnp.asarray(np.stack([b["k"] for b in blobs], axis=1))
        v = jnp.asarray(np.stack([b["v"] for b in blobs], axis=1))
        key = (kind, npad)
        if not hasattr(self, "_import_jit"):
            self._import_jit = {}
        if key not in self._import_jit:
            if kind == "base":
                def fn(pools, ids_, k_, v_):
                    return pools._replace(
                        kb=pools.kb.at[:, ids_].set(k_),
                        vb=pools.vb.at[:, ids_].set(v_))
            else:
                def fn(pools, ids_, k_, v_):
                    return pools._replace(
                        kr=pools.kr.at[:, ids_].set(k_),
                        vr=pools.vr.at[:, ids_].set(v_))
            self._import_jit[key] = jax.jit(fn, donate_argnums=(0,))
        self.pools = self._import_jit[key](
            self.pools, jnp.asarray(ids, jnp.int32), k, v)

    # ------------------------------------------------------------ helpers
    def _layer_params(self, li):
        return jax.tree_util.tree_map(lambda t: t[li],
                                      self.params["layers"])

    def _lora_layer(self, li):
        if self.lora is None:
            return None
        return jax.tree_util.tree_map(lambda t: t[li], self.lora)

    def _project_kv(self, p_l, lora_l, h, sin, cos, adapter_ids):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        bsz, s, _ = h.shape
        k_base = (h @ p_l["wk"]).reshape(bsz, s, cfg.num_kv_heads, hd)
        v_base = (h @ p_l["wv"]).reshape(bsz, s, cfg.num_kv_heads, hd)
        if cfg.use_rope:
            from repro.core import rope as rope_lib
            k_base = rope_lib.apply_rope(k_base, sin, cos)
        if self.disagg:
            k_res = tfm._bgmv_down(h, lora_l["a_k"], lora_l["scaling"],
                                   adapter_ids)
            v_res = tfm._bgmv_down(h, lora_l["a_v"], lora_l["scaling"],
                                   adapter_ids)
            bk = lora_l["b_k"][adapter_ids]
            bv = lora_l["b_v"][adapter_ids]
            return k_base, v_base, k_res, v_res, bk, bv
        if lora_l is not None:   # unified: fold LoRA exactly into K/V
            k_off = tfm._bgmv(h, lora_l["a_k"], lora_l["b_k"],
                              lora_l["scaling"], adapter_ids)
            v_off = tfm._bgmv(h, lora_l["a_v"], lora_l["b_v"],
                              lora_l["scaling"], adapter_ids)
            k_off = k_off.reshape(bsz, s, cfg.num_kv_heads, hd)
            v_off = v_off.reshape(bsz, s, cfg.num_kv_heads, hd)
            if cfg.use_rope:
                from repro.core import rope as rope_lib
                k_off = rope_lib.apply_rope(k_off, sin, cos)
            k_base = k_base + k_off
            v_base = v_base + v_off
        return k_base, v_base, None, None, None, None

    # ------------------------------------------------------------- decode
    def _decode_fn(self, pools: Pools, tokens, kv_len, adapter_ids, bt_b,
                   bt_r, wpage_b, wpage_r, woff, temps, top_ks, top_ps,
                   seeds, spos, *, sampled):
        """One decode step for a padded batch.

        tokens/kv_len/adapter_ids: (B,); bt_*: (B, maxpages) block tables;
        wpage_*: (B,) page indices to write the new token's KV into
        (dump page for inactive rows); woff: (B,) in-page offsets;
        temps/top_ks/top_ps/seeds/spos: (B,) per-row sampling params
        (temp <= 0 -> greedy argmax, the seed's exact path); sampled:
        static — False compiles the argmax-only body.
        """
        cfg = self.cfg
        bsz = tokens.shape[0]
        x = self.params["embed"][tokens][:, None]
        kmask_pos = None
        new_pools = pools
        bidx = jnp.arange(bsz)
        for li in range(cfg.num_layers):
            p_l = self._layer_params(li)
            lora_l = self._lora_layer(li)
            h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            q, sin, cos = tfm._qkv(p_l, h, cfg, lora_l, adapter_ids,
                                   kv_len[:, None])
            kb_, vb_, kr_, vr_, bk, bv = self._project_kv(
                p_l, lora_l, h, sin, cos, adapter_ids)
            # write new token
            kbp = new_pools.kb.at[li, wpage_b, woff].set(kb_[:, 0])
            vbp = new_pools.vb.at[li, wpage_b, woff].set(vb_[:, 0])
            if self.disagg:
                krp = new_pools.kr.at[li, wpage_r, woff].set(kr_[:, 0])
                vrp = new_pools.vr.at[li, wpage_r, woff].set(vr_[:, 0])
            else:
                krp, vrp = new_pools.kr, new_pools.vr
            new_pools = Pools(kbp, vbp, krp, vrp)
            # gather this request's pages -> contiguous view
            kc = kbp[li][bt_b].reshape(bsz, self.smax, cfg.num_kv_heads, -1)
            vc = vbp[li][bt_b].reshape(bsz, self.smax, cfg.num_kv_heads, -1)
            if self.disagg:
                krc = krp[li][bt_r].reshape(bsz, self.smax, -1)
                vrc = vrp[li][bt_r].reshape(bsz, self.smax, -1)
                bk_rows = bk.reshape(bsz, cfg.lora.rank, -1)
                bv_rows = bv.reshape(bsz, cfg.lora.rank, -1)
            else:
                krc = vrc = bk_rows = bv_rows = None
            if kmask_pos is None:
                kmask_pos = jnp.broadcast_to(jnp.arange(self.smax)[None],
                                             (bsz, self.smax))
            attn = tfm._attend(q, kc, vc, krc, vrc, bk_rows, bv_rows,
                               kmask_pos, kv_len + 1, kv_len[:, None],
                               cfg.sliding_window,
                               cfg.resolved_head_dim ** -0.5, cfg,
                               self.disagg)
            x = x + attn.reshape(bsz, 1, -1) @ p_l["wo"]
            h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + tfm.ffn(p_l, h, cfg)
        logits = tfm.unembed(self.params, x, cfg)[:, 0]
        if sampled:
            next_tok = sample_tokens(logits, temps, top_ks, top_ps, seeds,
                                     spos)
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return new_pools, next_tok, logits

    def decode(self, tokens, kv_len, adapter_ids, bt_b, bt_r, wpage_b,
               wpage_r, woff, temps=None, top_ks=None, top_ps=None,
               seeds=None, spos=None):
        bsz = len(tokens)
        temps = [0.0] * bsz if temps is None else temps
        top_ks = [0] * bsz if top_ks is None else top_ks
        top_ps = [1.0] * bsz if top_ps is None else top_ps
        seeds = [0] * bsz if seeds is None else seeds
        spos = [0] * bsz if spos is None else spos
        self.pools, next_tok, logits = self._decode(
            self.pools, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(kv_len, jnp.int32),
            jnp.asarray(adapter_ids, jnp.int32),
            jnp.asarray(bt_b, jnp.int32), jnp.asarray(bt_r, jnp.int32),
            jnp.asarray(wpage_b, jnp.int32), jnp.asarray(wpage_r, jnp.int32),
            jnp.asarray(woff, jnp.int32),
            jnp.asarray(temps, jnp.float32), jnp.asarray(top_ks, jnp.int32),
            jnp.asarray(top_ps, jnp.float32), jnp.asarray(seeds, jnp.int32),
            jnp.asarray(spos, jnp.int32),
            sampled=any(t > 0 for t in temps))
        return next_tok, logits

    # ------------------------------------------------------------ prefill
    def _prefill_fn(self, pools: Pools, tokens, start, n_valid, adapter_id,
                    bt_b, bt_r, wpages_b, wpages_r, temp, top_k, top_p,
                    seed, spos, *, chunk, sampled):
        """Chunked prefill for ONE request.

        tokens: (chunk,) padded; start: scalar absolute position of
        tokens[0]; n_valid: scalar #real tokens; wpages_*: (chunk,) page to
        write each token into (dump page where the cache is inherited —
        CoW: shared pages are never written); temp/top_k/top_p/seed/spos:
        scalar sampling params for the first generated token (sampled:
        static — False compiles the argmax-only body).
        """
        cfg = self.cfg
        positions = start + jnp.arange(chunk)
        x = self.params["embed"][tokens][None]        # (1, chunk, d)
        ids = adapter_id[None]
        woff = positions % self.page
        valid = jnp.arange(chunk) < n_valid
        new_pools = pools
        for li in range(cfg.num_layers):
            p_l = self._layer_params(li)
            lora_l = self._lora_layer(li)
            h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            q, sin, cos = tfm._qkv(p_l, h, cfg, lora_l, ids, positions[None])
            kb_, vb_, kr_, vr_, bk, bv = self._project_kv(
                p_l, lora_l, h, sin, cos, ids)
            wp_b = jnp.where(valid, wpages_b, self.dump_page)
            wp_r = jnp.where(valid, wpages_r, self.dump_page)
            kbp = new_pools.kb.at[li, wp_b, woff].set(kb_[0])
            vbp = new_pools.vb.at[li, wp_b, woff].set(vb_[0])
            if self.disagg:
                krp = new_pools.kr.at[li, wp_r, woff].set(kr_[0])
                vrp = new_pools.vr.at[li, wp_r, woff].set(vr_[0])
            else:
                krp, vrp = new_pools.kr, new_pools.vr
            new_pools = Pools(kbp, vbp, krp, vrp)
            kc = kbp[li][bt_b].reshape(1, self.smax, cfg.num_kv_heads, -1)
            vc = vbp[li][bt_b].reshape(1, self.smax, cfg.num_kv_heads, -1)
            if self.disagg:
                krc = krp[li][bt_r].reshape(1, self.smax, -1)
                vrc = vrp[li][bt_r].reshape(1, self.smax, -1)
                bk_rows = bk.reshape(1, cfg.lora.rank, -1)
                bv_rows = bv.reshape(1, cfg.lora.rank, -1)
            else:
                krc = vrc = bk_rows = bv_rows = None
            kmask_pos = jnp.arange(self.smax)[None]
            attn = tfm._attend(q, kc, vc, krc, vrc, bk_rows, bv_rows,
                               kmask_pos, (start + n_valid)[None],
                               positions[None], cfg.sliding_window,
                               cfg.resolved_head_dim ** -0.5, cfg,
                               self.disagg)
            x = x + attn.reshape(1, chunk, -1) @ p_l["wo"]
            h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + tfm.ffn(p_l, h, cfg)
        # logits of the LAST VALID token
        idx = jnp.maximum(n_valid - 1, 0)
        logits = tfm.unembed(self.params, x[:, idx][:, None], cfg)[0, 0]
        if sampled:
            next_tok = sample_tokens(logits[None], temp[None], top_k[None],
                                     top_p[None], seed[None], spos[None])[0]
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return new_pools, next_tok, logits

    # ------------------------------------------------- broadcast fork
    def _prefill_broadcast_fn(self, pools: Pools, tokens, start, n_valid,
                              adapter_ids, bt_b, wpages_b, wpages_r, *,
                              chunk, n_agents):
        """Beyond-paper broadcast fork (DESIGN.md §9): ONE base-trajectory
        pass over the shared context computes rCaches for ``n_agents``
        adapters at once (residuals are rank-r projections of the same x).

        tokens: (chunk,); adapter_ids: (n_agents,); wpages_r:
        (n_agents, chunk).  Base attention only (the approximation);
        bCache written once via wpages_b.
        """
        cfg = self.cfg
        positions = start + jnp.arange(chunk)
        x = self.params["embed"][tokens][None]
        woff = positions % self.page
        valid = jnp.arange(chunk) < n_valid
        new_pools = pools
        for li in range(cfg.num_layers):
            p_l = self._layer_params(li)
            lora_l = self._lora_layer(li)
            h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            # base trajectory: no q-LoRA
            q, sin, cos = tfm._qkv(p_l, h, cfg, None, None, positions[None])
            hd = cfg.resolved_head_dim
            kb_ = (h @ p_l["wk"]).reshape(1, chunk, cfg.num_kv_heads, hd)
            vb_ = (h @ p_l["wv"]).reshape(1, chunk, cfg.num_kv_heads, hd)
            if cfg.use_rope:
                from repro.core import rope as rope_lib
                kb_ = rope_lib.apply_rope(kb_, sin, cos)
            # all agents' residuals from the SAME x: (n_agents, chunk, r)
            a_k = lora_l["a_k"][adapter_ids]          # (K, d, r)
            a_v = lora_l["a_v"][adapter_ids]
            sc = lora_l["scaling"][adapter_ids].astype(x.dtype)
            kr_ = jnp.einsum("sd,kdr->ksr", h[0], a_k.astype(x.dtype)) \
                * sc[:, None, None]
            vr_ = jnp.einsum("sd,kdr->ksr", h[0], a_v.astype(x.dtype)) \
                * sc[:, None, None]
            wp_b = jnp.where(valid, wpages_b, self.dump_page)
            wp_r = jnp.where(valid[None], wpages_r, self.dump_page)
            kbp = new_pools.kb.at[li, wp_b, woff].set(kb_[0])
            vbp = new_pools.vb.at[li, wp_b, woff].set(vb_[0])
            krp = new_pools.kr.at[li, wp_r, woff[None]].set(kr_)
            vrp = new_pools.vr.at[li, wp_r, woff[None]].set(vr_)
            new_pools = Pools(kbp, vbp, krp, vrp)
            # attention over base cache only
            kc = kbp[li][bt_b].reshape(1, self.smax, cfg.num_kv_heads, -1)
            vc = vbp[li][bt_b].reshape(1, self.smax, cfg.num_kv_heads, -1)
            kmask_pos = jnp.arange(self.smax)[None]
            attn = tfm._attend(q, kc, vc, None, None, None, None, kmask_pos,
                               (start + n_valid)[None], positions[None],
                               cfg.sliding_window,
                               cfg.resolved_head_dim ** -0.5, cfg, False)
            x = x + attn.reshape(1, chunk, -1) @ p_l["wo"]
            h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
            x = x + tfm.ffn(p_l, h, cfg)
        return new_pools

    def prefill_broadcast(self, tokens, start, adapter_ids, bt_b,
                          wpages_b, wpages_r_list, chunk_size):
        n = len(tokens)
        pad = chunk_size - n
        toks = jnp.asarray(list(tokens) + [0] * pad, jnp.int32)
        wb = jnp.asarray(list(wpages_b) + [self.dump_page] * pad, jnp.int32)
        wr = jnp.asarray([list(w) + [self.dump_page] * pad
                          for w in wpages_r_list], jnp.int32)
        if not hasattr(self, "_broadcast_jit"):
            self._broadcast_jit = {}
        key = (chunk_size, len(adapter_ids))
        if key not in self._broadcast_jit:
            self._broadcast_jit[key] = jax.jit(
                self._prefill_broadcast_fn, donate_argnums=(0,),
                static_argnames=("chunk", "n_agents"))
        self.pools = self._broadcast_jit[key](
            self.pools, toks, jnp.asarray(start, jnp.int32),
            jnp.asarray(n, jnp.int32),
            jnp.asarray(list(adapter_ids), jnp.int32),
            jnp.asarray(bt_b, jnp.int32), wb, wr,
            chunk=chunk_size, n_agents=len(adapter_ids))

    def prefill_chunk(self, tokens, start, adapter_id, bt_b, bt_r,
                      wpages_b, wpages_r, chunk_size, temp=0.0, top_k=0,
                      top_p=1.0, seed=0, spos=0):
        n = len(tokens)
        pad = chunk_size - n
        toks = jnp.asarray(list(tokens) + [0] * pad, jnp.int32)
        wb = jnp.asarray(list(wpages_b) + [self.dump_page] * pad, jnp.int32)
        wr = jnp.asarray(list(wpages_r) + [self.dump_page] * pad, jnp.int32)
        self.pools, next_tok, logits = self._prefill(
            self.pools, toks, jnp.asarray(start, jnp.int32),
            jnp.asarray(n, jnp.int32), jnp.asarray(adapter_id, jnp.int32),
            jnp.asarray(bt_b, jnp.int32), jnp.asarray(bt_r, jnp.int32),
            wb, wr, jnp.asarray(temp, jnp.float32),
            jnp.asarray(top_k, jnp.int32), jnp.asarray(top_p, jnp.float32),
            jnp.asarray(seed, jnp.int32), jnp.asarray(spos, jnp.int32),
            chunk=chunk_size, sampled=temp > 0)
        return int(next_tok), logits
