"""Async HTTP serving frontend: SSE token streaming over the ForkServer
(DESIGN.md §15).

The single-pump design of :mod:`repro.serving.api` (§11) was built for
exactly this: ONE thread owns the engine and calls ``server.poll()``;
everything else talks to it through queues.  The frontend maps external
HTTP traffic onto that pump:

  * **pump thread** — the only thread that touches the engine.  It
    executes queued *ops* (submit / create session / fork / metrics),
    polls the server whenever work is in flight, and forwards each
    handle's :class:`~repro.serving.api.TokenEvent` s into per-request
    ``asyncio.Queue`` s via ``loop.call_soon_threadsafe``.
  * **asyncio event loop** — stdlib ``asyncio`` streams (no third-party
    HTTP dependency): parses requests, runs ops on the pump thread via
    ``asyncio.wrap_future``, and streams Server-Sent Events as tokens
    arrive.

API (JSON bodies; token ids, not text — the repo is tokenizer-free):

  ``POST /v1/completions``
      ``{"prompt": [ints], "adapter_id": 0, "tenant": "default",
      "max_new_tokens": 16, "temperature": 0.0, "top_k": 0,
      "top_p": 1.0, "seed": 0, "deadline_s": 0, "stream": false}``.
      ``stream=true`` responds ``text/event-stream``: one
      ``data: {"token": t, "index": i}`` event per token, then a
      terminal ``data: {"finished": true, "finish_reason": ...,
      "tokens": [...], "metrics": {...}}`` event.  ``stream=false``
      responds with the terminal JSON directly.
  ``POST /v1/sessions``
      ``{"context": [ints], "adapter_id": 0, "tenant": "default"}`` —
      prefills + pins the shared context (an :class:`AgentSession`),
      returns ``{"session_id": "..."}``.
  ``POST /v1/sessions/{id}/fork``
      completion body minus ``prompt`` plus ``"instruction": [ints]`` —
      forks the pinned context (CoW cache inheritance), same streaming
      semantics as completions.
  ``DELETE /v1/sessions/{id}``
      drops the session pin.
  ``GET /v1/metrics``
      ``Engine.metrics()`` as JSON (queue depth, admission waits,
      per-tenant counters, cache/tier/kernel metrics).
  ``GET /healthz``
      health states (DESIGN.md §17): ``healthy`` / ``overloaded`` (200),
      ``draining`` / ``stuck`` (503 — take the replica out of rotation).
  ``POST /v1/drain``
      graceful drain: stop admission (new work → 503 + Retry-After),
      finish everything in flight.  ``SIGTERM`` in ``launch/serve.py``
      triggers the same path.

Status mapping: admission rejects a request by FINISHING it (the engine
never throws at a tenant), and the frontend translates the terminal
state: overload shed → ``429`` with a ``Retry-After`` header (the
policy's deterministic backoff hint), impossible request (too long) →
``400``, queueing deadline expired → ``504``, stall-detection failure →
``503``.  A stream that already delivered tokens cannot change its
status retroactively — the terminal SSE event carries the finish reason
instead (standard SSE practice).

:class:`ForkClient` is the matching stdlib ``http.client`` client used
by the tests, the HTTP smoke stage and ``examples/http_client.py``.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import contextlib
import dataclasses
import http.client
import itertools
import json
import math
import queue
import random
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.serving.api import AgentSession, ForkServer, GenerationHandle
from repro.serving.sampling import SamplingParams

__all__ = ["HttpFrontend", "ForkClient"]


# every key a completion/fork body may carry; anything else is a typo the
# caller should hear about as a 400, not silently-ignored greedy sampling
_KNOWN_KEYS = frozenset({
    "prompt", "instruction", "adapter_id", "tenant", "deadline_s", "stream",
    "temperature", "top_k", "top_p", "seed", "max_new_tokens",
    "stop_token_ids", "speculate", "spec_k"})


def _sampling_from(body: Dict) -> SamplingParams:
    unknown = sorted(set(body) - _KNOWN_KEYS)
    if unknown:
        raise ValueError(f"unknown sampling key(s): {', '.join(unknown)}")
    spec = body.get("speculate")          # absent/None = engine default
    return SamplingParams(
        temperature=float(body.get("temperature", 0.0)),
        top_k=int(body.get("top_k", 0)),
        top_p=float(body.get("top_p", 1.0)),
        seed=int(body.get("seed", 0)),
        max_new_tokens=int(body.get("max_new_tokens", 16)),
        stop_token_ids=tuple(body.get("stop_token_ids", ())),
        speculate=None if spec is None else bool(spec),
        spec_k=int(body.get("spec_k", 0)))


def _status_for(finish_reason: str, retry_after_s: float) -> int:
    """HTTP status for a request that finished WITHOUT producing output
    (see module docstring)."""
    if finish_reason == "rejected":
        return 429 if retry_after_s > 0 else 400
    if finish_reason == "timeout":
        return 504
    if finish_reason in ("stalled", "draining"):
        return 503
    if finish_reason == "error":
        return 500
    return 200


@dataclasses.dataclass
class _Stream:
    """Pump-side bridge: one generation handle feeding one asyncio queue."""

    handle: GenerationHandle
    aq: asyncio.Queue
    loop: asyncio.AbstractEventLoop


class HttpFrontend:
    """HTTP gateway over one :class:`ForkServer` (DESIGN.md §15).

    ``serve_forever()`` runs in the calling thread (Ctrl-C to stop);
    ``start_background()`` / ``shutdown()`` run it in a daemon thread for
    tests and embedding.  ``port=0`` binds an ephemeral port, published
    as ``self.port`` once the listener is up.
    """

    def __init__(self, server: ForkServer, host: str = "127.0.0.1",
                 port: int = 0):
        self.server = server
        self.host = host
        self.port = port
        self._ops: "queue.Queue[Callable[[], None]]" = queue.Queue()
        self._streams: Dict[int, _Stream] = {}
        self._sessions: Dict[str, AgentSession] = {}
        self._session_ids = itertools.count(1)
        self._stop = threading.Event()
        self._ready = threading.Event()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._pump_thread: Optional[threading.Thread] = None
        self._watchdog_thread: Optional[threading.Thread] = None
        self._wd_tripped = False
        self._draining = False
        self.requests_served = 0

    # ------------------------------------------------------------ lifecycle
    def serve_forever(self) -> None:
        asyncio.run(self._amain())

    def start_background(self) -> "HttpFrontend":
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True, name="forkkv-http")
        self._thread.start()
        if not self._ready.wait(timeout=30):
            raise RuntimeError("HTTP frontend failed to start")
        return self

    def shutdown(self) -> None:
        self._stop.set()
        if self._loop is not None:
            with contextlib.suppress(RuntimeError):
                self._loop.call_soon_threadsafe(lambda: None)  # wake loop
        if self._thread is not None:
            self._thread.join(timeout=10)

    # --------------------------------------------------------------- drain
    def begin_drain(self) -> None:
        """Stop admitting new work; in-flight requests run to completion
        (DESIGN.md §17).  Non-blocking and signal-safe: the frontend flag
        flips immediately (new HTTP requests get 503) and the engine-side
        drain runs as a queued pump op (``queue.Queue.put`` is safe from
        a signal handler).  Idempotent."""
        if self._draining:
            return
        self._draining = True
        self._ops.put(self.server.drain)

    @property
    def drained(self) -> bool:
        """True once draining AND the engine is empty AND every SSE
        stream has delivered its terminal event."""
        return self._draining and self.server.engine.drained \
            and not self._streams

    async def _amain(self) -> None:
        self._loop = asyncio.get_running_loop()
        srv = await asyncio.start_server(self._handle_conn, self.host,
                                         self.port)
        self.port = srv.sockets[0].getsockname()[1]
        self._pump_thread = threading.Thread(target=self._pump, daemon=True,
                                             name="forkkv-pump")
        self._pump_thread.start()
        if self.server.engine.sc.watchdog_s > 0:
            self._watchdog_thread = threading.Thread(
                target=self._watchdog, daemon=True, name="forkkv-watchdog")
            self._watchdog_thread.start()
        self._ready.set()
        try:
            async with srv:
                while not self._stop.is_set():
                    await asyncio.sleep(0.05)
        finally:
            self._stop.set()
            self._pump_thread.join(timeout=10)

    def _watchdog(self) -> None:
        """Stuck-pump detector (DESIGN.md §17): with work in flight, the
        step loop should stamp ``engine.last_step_at`` continuously; a
        gap beyond ``watchdog_s`` means the pump wedged (deadlocked op,
        hung device call).  One trip per stall episode — the counter is
        a health signal surfaced via ``/healthz`` and metrics, not a
        kill switch (the operator decides whether to restart)."""
        eng = self.server.engine
        limit = eng.sc.watchdog_s
        while not self._stop.wait(max(0.01, limit / 4)):
            busy = bool(eng.waiting or eng.running)
            stalled = busy and (time.time() - eng.last_step_at) > limit
            if stalled and not self._wd_tripped:
                self._wd_tripped = True
                eng.watchdog_trips += 1
            elif not stalled:
                self._wd_tripped = False

    # ------------------------------------------------------------ pump side
    # The pump thread is the ONLY thread that touches the ForkServer /
    # Engine (they are single-threaded by design, §11).  Ops are plain
    # closures; results travel back on concurrent.futures.Futures.
    def _pump(self) -> None:
        while not self._stop.is_set():
            busy = False
            while True:
                try:
                    op = self._ops.get_nowait()
                except queue.Empty:
                    break
                op()
                busy = True
            eng = self.server.engine
            if eng.waiting or eng.running:
                self.server.poll()
                busy = True
            self._forward_events()
            if not busy:
                time.sleep(0.001)

    def _forward_events(self) -> None:
        done: List[int] = []
        for rid, st in self._streams.items():
            while st.handle._queue:
                ev = st.handle._queue.popleft()
                payload = {"rid": ev.rid, "index": ev.index,
                           "token": ev.token, "finished": ev.finished,
                           "finish_reason": ev.finish_reason,
                           "ts": ev.ts}
                st.loop.call_soon_threadsafe(st.aq.put_nowait, payload)
                if ev.finished:
                    done.append(rid)
        for rid in done:
            del self._streams[rid]

    async def _call(self, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` on the pump thread; await its result."""
        fut: "concurrent.futures.Future[Any]" = concurrent.futures.Future()

        def op() -> None:
            try:
                fut.set_result(fn())
            except BaseException as exc:   # travel back to the async side
                fut.set_exception(exc)

        self._ops.put(op)
        return await asyncio.wrap_future(fut)

    # --------------------------------------------------------- HTTP server
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            line = await asyncio.wait_for(reader.readline(), timeout=60)
            if not line:
                return
            try:
                method, target, _ = line.decode("latin1").split(None, 2)
            except ValueError:
                await self._respond(writer, 400, {"error": "bad request"})
                return
            headers: Dict[str, str] = {}
            while True:
                hline = await reader.readline()
                if hline in (b"\r\n", b"\n", b""):
                    break
                k, _, v = hline.decode("latin1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body: Dict = {}
            n = int(headers.get("content-length", "0") or 0)
            if n:
                raw = await reader.readexactly(n)
                try:
                    body = json.loads(raw)
                    if not isinstance(body, dict):
                        raise ValueError("body must be a JSON object")
                except (ValueError, UnicodeDecodeError):
                    # covers JSONDecodeError (a ValueError) AND invalid
                    # utf-8 — either way the caller hears 400, not a
                    # dropped connection (§17 satellite)
                    await self._respond(writer, 400,
                                        {"error": "invalid JSON body"})
                    return
            self.requests_served += 1
            await self._route(method.upper(), target.split("?")[0],
                              body, writer)
        except (asyncio.IncompleteReadError, asyncio.TimeoutError,
                ConnectionError):
            pass
        finally:
            with contextlib.suppress(Exception):
                writer.close()
                await writer.wait_closed()

    def _health(self) -> Tuple[int, Dict]:
        """Health snapshot (DESIGN.md §17).  Reads engine counters
        directly (benign racy reads — scalars under the GIL) so health
        stays answerable even when the pump is wedged, which is exactly
        when an orchestrator needs the answer.  States: ``healthy`` |
        ``overloaded`` (still 200 — serving, but shedding likely) |
        ``draining`` | ``stuck`` (503 — take it out of rotation)."""
        eng = self.server.engine
        wd = eng.sc.watchdog_s
        busy = bool(eng.waiting or eng.running)
        stuck = wd > 0 and busy and \
            (time.time() - eng.last_step_at) > wd
        if self._draining:
            state, status = "draining", 503
        elif stuck:
            state, status = "stuck", 503
        elif len(eng.waiting) > 2 * max(1, eng.sc.max_batch):
            state, status = "overloaded", 200
        else:
            state, status = "healthy", 200
        return status, {"ok": status == 200, "state": state,
                        "waiting": len(eng.waiting),
                        "running": len(eng.running),
                        "drained": self.drained,
                        "watchdog_trips": eng.watchdog_trips}

    async def _route(self, method: str, path: str, body: Dict,
                     writer: asyncio.StreamWriter) -> None:
        if method == "GET" and path == "/healthz":
            status, doc = self._health()
            await self._respond(writer, status, doc)
        elif method == "POST" and path == "/v1/drain":
            self.begin_drain()
            await self._respond(writer, 200,
                                {"draining": True, "drained": self.drained})
        elif method == "GET" and path == "/v1/metrics":
            m = await self._call(self.server.metrics)
            m["http_sessions"] = len(self._sessions)
            m["http_requests_served"] = self.requests_served
            await self._respond(writer, 200, m)
        elif method == "POST" and path == "/v1/completions":
            await self._completion(body, writer)
        elif method == "POST" and path == "/v1/sessions":
            await self._create_session(body, writer)
        elif method == "POST" and path.startswith("/v1/sessions/") and \
                path.endswith("/fork"):
            sid = path[len("/v1/sessions/"):-len("/fork")]
            await self._fork(sid, body, writer)
        elif method == "DELETE" and path.startswith("/v1/sessions/"):
            sid = path[len("/v1/sessions/"):]
            await self._close_session(sid, writer)
        else:
            await self._respond(writer, 404,
                                {"error": f"no route {method} {path}"})

    # ----------------------------------------------------------- endpoints
    def _register(self, handle: GenerationHandle,
                  aq: asyncio.Queue) -> None:
        """Pump-side: track a handle for event forwarding.  MUST run on
        the pump thread (inside the op that created the handle) so no
        event can slip between creation and registration."""
        self._streams[handle.rid] = _Stream(handle, aq,
                                            self._loop)  # type: ignore

    async def _refuse_if_draining(self,
                                  writer: asyncio.StreamWriter) -> bool:
        """Drain guard for work-submitting endpoints: 503 + Retry-After
        so well-behaved clients fail over to another replica instead of
        queueing behind a server that will never admit them."""
        if self._draining:
            await self._respond(writer, 503,
                                {"error": "server is draining",
                                 "finish_reason": "draining"},
                                extra_headers={"Retry-After": "1"})
            return True
        return False

    async def _completion(self, body: Dict,
                          writer: asyncio.StreamWriter) -> None:
        if await self._refuse_if_draining(writer):
            return
        prompt = body.get("prompt")
        if not isinstance(prompt, list) or \
                not all(isinstance(t, int) for t in prompt):
            await self._respond(writer, 400,
                                {"error": "prompt must be a list of ints"})
            return
        try:
            sp = _sampling_from(body)
        except ValueError as exc:
            await self._respond(writer, 400, {"error": str(exc)})
            return
        aq: asyncio.Queue = asyncio.Queue()

        def op() -> GenerationHandle:
            h = self.server.generate(
                int(body.get("adapter_id", 0)), prompt, sampling=sp,
                tenant=str(body.get("tenant", "default")),
                deadline_s=float(body.get("deadline_s", 0.0)))
            self._register(h, aq)
            return h

        handle = await self._call(op)
        await self._deliver(handle, aq, bool(body.get("stream", False)),
                            writer)

    async def _create_session(self, body: Dict,
                              writer: asyncio.StreamWriter) -> None:
        if await self._refuse_if_draining(writer):
            return
        context = body.get("context")
        if not isinstance(context, list) or \
                not all(isinstance(t, int) for t in context):
            await self._respond(writer, 400,
                                {"error": "context must be a list of ints"})
            return

        def op() -> AgentSession:
            return self.server.session(
                context, adapter_id=int(body.get("adapter_id", 0)),
                tenant=str(body.get("tenant", "default")))

        try:
            sess = await self._call(op)
        except RuntimeError as exc:      # context prefill failed
            await self._respond(writer, 503, {"error": str(exc)})
            return
        sid = f"s{next(self._session_ids)}"
        self._sessions[sid] = sess
        await self._respond(writer, 200,
                            {"session_id": sid,
                             "context_tokens": len(sess.context),
                             "adapter_id": sess.adapter_id,
                             "tenant": sess.tenant})

    async def _fork(self, sid: str, body: Dict,
                    writer: asyncio.StreamWriter) -> None:
        if await self._refuse_if_draining(writer):
            return
        sess = self._sessions.get(sid)
        if sess is None or not sess.alive:
            await self._respond(writer, 404,
                                {"error": f"no session {sid!r}"})
            return
        instruction = body.get("instruction", [])
        if not isinstance(instruction, list) or \
                not all(isinstance(t, int) for t in instruction):
            await self._respond(
                writer, 400, {"error": "instruction must be a list of ints"})
            return
        try:
            sp = _sampling_from(body)
        except ValueError as exc:
            await self._respond(writer, 400, {"error": str(exc)})
            return
        aq: asyncio.Queue = asyncio.Queue()

        def op() -> GenerationHandle:
            h = sess.fork(int(body.get("adapter_id", sess.adapter_id)),
                          instruction, sampling=sp,
                          deadline_s=float(body.get("deadline_s", 0.0)))
            self._register(h, aq)
            return h

        handle = await self._call(op)
        await self._deliver(handle, aq, bool(body.get("stream", False)),
                            writer)

    async def _close_session(self, sid: str,
                             writer: asyncio.StreamWriter) -> None:
        sess = self._sessions.pop(sid, None)
        if sess is None:
            await self._respond(writer, 404,
                                {"error": f"no session {sid!r}"})
            return
        await self._call(sess.close)
        await self._respond(writer, 200, {"closed": sid})

    # ------------------------------------------------------------ delivery
    async def _deliver(self, handle: GenerationHandle, aq: asyncio.Queue,
                       stream: bool, writer: asyncio.StreamWriter) -> None:
        """Forward one request's events: SSE when streaming, one JSON
        document otherwise.  The FIRST event decides the HTTP status —
        a request refused before any token (shed / too long / deadline)
        becomes a real error status even in stream mode, since no SSE
        bytes have been written yet."""
        first = await aq.get()
        if first["finished"] and first["index"] == 0:
            out = await self._call(handle.result)
            status = _status_for(out.finish_reason, out.retry_after_s)
            if status != 200 or not stream:
                extra = {}
                if status == 429:
                    # ceil with a floor of 1: round() turned any hint
                    # under 0.5 s into "Retry-After: 0", telling a
                    # compliant client (our own ForkClient backoff
                    # included) to retry IMMEDIATELY and hammer the
                    # already-overloaded server
                    extra["Retry-After"] = \
                        str(max(1, math.ceil(out.retry_after_s)))
                await self._respond(writer, status, self._final_doc(out),
                                    extra_headers=extra)
                return
            # legitimate zero-token completion on a stream request:
            # fall through to SSE so the client still gets its terminal
            # event in the format it asked for.
        if not stream:
            out = await self._call(handle.result)
            await self._respond(writer, 200, self._final_doc(out))
            return
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        ev = first
        while True:
            if ev["finished"]:
                out = await self._call(handle.result)
                doc = self._final_doc(out)
                doc["finished"] = True
                writer.write(b"data: " + json.dumps(doc).encode() +
                             b"\n\n")
                await writer.drain()
                return
            writer.write(b"data: " +
                         json.dumps({"token": ev["token"],
                                     "index": ev["index"],
                                     "ts": ev.get("ts", 0.0)}).encode() +
                         b"\n\n")
            await writer.drain()
            ev = await aq.get()

    @staticmethod
    def _final_doc(out) -> Dict:
        return {"rid": out.rid, "adapter_id": out.adapter_id,
                "tenant": out.tenant, "tokens": out.tokens,
                "finish_reason": out.finish_reason, "error": out.error,
                "retry_after_s": out.retry_after_s, "metrics": out.metrics}

    @staticmethod
    async def _respond(writer: asyncio.StreamWriter, status: int,
                       payload: Dict,
                       extra_headers: Optional[Dict[str, str]] = None
                       ) -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  408: "Request Timeout", 429: "Too Many Requests",
                  500: "Internal Server Error",
                  503: "Service Unavailable",
                  504: "Gateway Timeout"}.get(status, "Error")
        body = json.dumps(payload, default=str).encode()
        head = [f"HTTP/1.1 {status} {reason}",
                "Content-Type: application/json",
                f"Content-Length: {len(body)}",
                "Connection: close"]
        for k, v in (extra_headers or {}).items():
            head.append(f"{k}: {v}")
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + body)
        await writer.drain()


# --------------------------------------------------------------------------
# Client
# --------------------------------------------------------------------------
class ForkClient:
    """Minimal stdlib client for :class:`HttpFrontend` (tests + smoke +
    examples).  One connection per call — the server closes after each
    response.

    ``max_retries > 0`` turns on transient-failure retry for the
    non-streaming endpoints (``completion`` / ``fork`` /
    ``create_session``): a 429 or 503 is retried after a jittered
    exponential backoff, with a ``Retry-After`` header (the server's
    deterministic hint) overriding the computed delay when longer.
    Streams are never retried — tokens may already have been consumed.
    The attempt count is surfaced as ``client_retries`` in the returned
    document (or ``HttpError.retries`` on final failure)."""

    RETRYABLE = (429, 503)

    def __init__(self, host: str = "127.0.0.1", port: int = 8080,
                 timeout: float = 120.0, max_retries: int = 0,
                 backoff_s: float = 0.25, backoff_cap_s: float = 4.0,
                 retry_seed: int = 0):
        self.host, self.port, self.timeout = host, port, timeout
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self._rng = random.Random(retry_seed)

    def _retry_delay(self, attempt: int, headers: Dict[str, str]) -> float:
        base = min(self.backoff_cap_s, self.backoff_s * (2 ** attempt))
        # full-jitter-lite: [0.5, 1.0) x base decorrelates a thundering
        # herd of clients while keeping the delay seed-deterministic
        delay = base * (0.5 + self._rng.random() / 2)
        ra = headers.get("retry-after")
        if ra:
            try:
                delay = max(delay, float(ra))
            except ValueError:
                pass
        return delay

    def _with_retry(self, call: Callable[[], Dict]) -> Dict:
        """Run ``call`` with up to ``max_retries`` retries on 429/503."""
        attempt = 0
        while True:
            try:
                doc = call()
                if isinstance(doc, dict):
                    doc["client_retries"] = attempt
                return doc
            except HttpError as exc:
                if exc.status not in self.RETRYABLE or \
                        attempt >= self.max_retries:
                    exc.retries = attempt
                    raise
                time.sleep(self._retry_delay(attempt, exc.headers))
                attempt += 1

    # ------------------------------------------------------------- plumbing
    def _request(self, method: str, path: str,
                 payload: Optional[Dict] = None
                 ) -> Tuple[int, Dict[str, str], Dict]:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            body = json.dumps(payload).encode() if payload is not None \
                else None
            conn.request(method, path, body=body,
                         headers={"Content-Type": "application/json"}
                         if body else {})
            resp = conn.getresponse()
            data = resp.read()
            headers = {k.lower(): v for k, v in resp.getheaders()}
            return resp.status, headers, json.loads(data) if data else {}
        finally:
            conn.close()

    def _stream(self, method: str, path: str,
                payload: Dict) -> Iterator[Dict]:
        """Yield SSE ``data:`` events; raises on a non-200 response
        carrying the error document in ``args[1]``."""
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            conn.request(method, path, body=json.dumps(payload).encode(),
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            if resp.status != 200:
                doc = json.loads(resp.read() or b"{}")
                raise HttpError(resp.status, doc,
                                {k.lower(): v for k, v in
                                 resp.getheaders()})
            while True:
                line = resp.readline()
                if not line:
                    return
                line = line.strip()
                if not line.startswith(b"data: "):
                    continue
                ev = json.loads(line[len(b"data: "):])
                yield ev
                if ev.get("finished"):
                    return
        finally:
            conn.close()

    # ------------------------------------------------------------ endpoints
    def healthz(self) -> bool:
        status, _, doc = self._request("GET", "/healthz")
        return status == 200 and bool(doc.get("ok"))

    def metrics(self) -> Dict:
        status, _, doc = self._request("GET", "/v1/metrics")
        if status != 200:
            raise HttpError(status, doc, {})
        return doc

    def drain(self) -> Dict:
        status, _, doc = self._request("POST", "/v1/drain")
        if status != 200:
            raise HttpError(status, doc, {})
        return doc

    def completion(self, prompt: List[int], **kw) -> Dict:
        """Non-streaming completion; returns the final document.  Raises
        :class:`HttpError` for refused requests (429/400/500/503/504)
        after exhausting ``max_retries`` on the retryable ones."""
        def call() -> Dict:
            status, headers, doc = self._request(
                "POST", "/v1/completions", {"prompt": prompt, **kw})
            if status != 200:
                raise HttpError(status, doc, headers)
            return doc
        return self._with_retry(call)

    def stream_completion(self, prompt: List[int], **kw) -> Iterator[Dict]:
        return self._stream("POST", "/v1/completions",
                            {"prompt": prompt, "stream": True, **kw})

    def create_session(self, context: List[int], **kw) -> str:
        def call() -> Dict:
            status, headers, doc = self._request(
                "POST", "/v1/sessions", {"context": context, **kw})
            if status != 200:
                raise HttpError(status, doc, headers)
            return doc
        return self._with_retry(call)["session_id"]

    def fork(self, session_id: str, instruction: List[int], **kw) -> Dict:
        def call() -> Dict:
            status, headers, doc = self._request(
                "POST", f"/v1/sessions/{session_id}/fork",
                {"instruction": instruction, **kw})
            if status != 200:
                raise HttpError(status, doc, headers)
            return doc
        return self._with_retry(call)

    def stream_fork(self, session_id: str, instruction: List[int],
                    **kw) -> Iterator[Dict]:
        return self._stream("POST", f"/v1/sessions/{session_id}/fork",
                            {"instruction": instruction, "stream": True,
                             **kw})

    def close_session(self, session_id: str) -> None:
        status, _, doc = self._request("DELETE",
                                       f"/v1/sessions/{session_id}")
        if status != 200:
            raise HttpError(status, doc, {})


class HttpError(RuntimeError):
    """Non-200 response: ``status``, parsed ``doc``, response headers
    (lower-cased keys — ``retry-after`` for 429s)."""

    def __init__(self, status: int, doc: Dict, headers: Dict[str, str]):
        super().__init__(f"HTTP {status}: {doc.get('error', doc)}")
        self.status = status
        self.doc = doc
        self.headers = headers
        self.retries = 0        # attempts the client burned before giving up
