"""Paper Fig. 1 / Fig. 4 / Eq. 3 — context memory vs. number of agents.

Two parts:
  (a) closed-form at paper scale: Llama3-8B, 32K shared context, rank 16 —
      reproduces the paper's 4GB-per-agent vs 64MB-per-agent numbers and
      the 11.8x total saving at N=16 / 32x capacity at fixed 8GB;
  (b) measured on the CPU engine: peak pool bytes per mode as N grows.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, run_workflow
from repro.configs.paper_models import LLAMA3_8B
from repro.core.disagg import memory_ratio


def closed_form() -> None:
    cfg = LLAMA3_8B
    ctx = 32_768
    r = cfg.lora.rank
    kv_dim = cfg.kv_dim                      # n in Eq. 3 (per K or V proj)
    bytes_per_tok_unified = 2 * cfg.num_layers * kv_dim * 2     # K+V bf16
    bytes_per_tok_res = 2 * cfg.num_layers * r * 2
    unified_per_agent = ctx * bytes_per_tok_unified
    bcache = ctx * bytes_per_tok_unified
    rcache_per_agent = ctx * bytes_per_tok_res
    emit("memory.eq3.unified_GB_per_agent", 0,
         f"{unified_per_agent/2**30:.2f}")
    emit("memory.eq3.rcache_MB_per_agent", 0,
         f"{rcache_per_agent/2**20:.1f}")
    for n in (1, 4, 16, 64):
        unified = n * unified_per_agent
        disagg = bcache + n * rcache_per_agent
        mr = memory_ratio(n, r, kv_dim)
        emit(f"memory.eq3.N{n}", 0,
             f"unified_GB={unified/2**30:.1f};disagg_GB={disagg/2**30:.2f};"
             f"saving={unified/disagg:.1f}x;M_R={mr:.4f}")
    # capacity at fixed 8GB budget (paper Fig. 1: 32x more agents)
    budget = 8 * 2**30
    n_unified = budget // unified_per_agent
    n_disagg = (budget - bcache) // rcache_per_agent
    emit("memory.eq3.agents_at_8GB", 0,
         f"unified={n_unified};forkkv={n_disagg};"
         f"gain={n_disagg/max(n_unified,1):.0f}x")


def measured() -> None:
    for n_wf in (1, 2, 4):
        peaks = {}
        t0 = time.time()
        for mode in ("forkkv", "prefix"):
            rep = run_workflow(mode, "react", n_workflows=n_wf, agents=3,
                               context=256, max_new=6, max_pages=1024)
            peaks[mode] = rep["peak_cache_bytes"]
        ratio = peaks["prefix"] / max(peaks["forkkv"], 1)
        emit(f"memory.engine.workflows{n_wf}",
             (time.time() - t0) * 1e6,
             f"forkkv_MB={peaks['forkkv']/2**20:.1f};"
             f"prefix_MB={peaks['prefix']/2**20:.1f};saving={ratio:.2f}x")


def main() -> None:
    closed_form()
    measured()


if __name__ == "__main__":
    main()
