"""Paper Fig. 5 / Table 2 — generation-quality impact of cache sharing.

Offline analogue: a tiny base model is trained on synthetic data, two LoRA
agents are fine-tuned on distinct tasks, then agent B decodes with
  * exact       — its own unified cache (prefix-caching upper bound)
  * forkkv      — agent A's shared bCache + B's own rCache (the lossy step)
  * broadcast   — beyond-paper broadcast fork: bCache AND rCache both from
                  the BASE trajectory (one pass serves N agents)
  * full_reuse  — agent A's ENTIRE cache (the paper's collapsing baseline)
Metrics: greedy next-token agreement vs exact (the F1 proxy) and mean
logit cosine similarity (Fig. 5b analogue).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.config import LoRAConfig, ModelConfig
from repro.models import transformer as tfm
from repro.training import data, train_loop
from repro.models.registry import get_model

STEPS_BASE = 120
STEPS_LORA = 80
DECODE_STEPS = 12
N_CONTEXTS = 6


def train_tiny():
    cfg = ModelConfig(name="q", family="dense", num_layers=3, d_model=96,
                      num_heads=6, num_kv_heads=3, d_ff=192, vocab_size=256,
                      dtype="float32", lora=LoRAConfig(rank=8), remat=False)
    api = get_model(cfg)
    init, step = train_loop.make_train_step(cfg, lr=2e-3)
    params = api.init_params(jax.random.PRNGKey(0))
    opt = init(params)
    jstep = jax.jit(step)
    for _, b in zip(range(STEPS_BASE), data.make_stream(256, 32, 8)):
        params, opt, m = jstep(params, opt,
                               {k: jnp.asarray(v) for k, v in b.items()})
    lora = api.init_lora_stacks(jax.random.PRNGKey(1), 2, nonzero=False)
    for aid in (0, 1):
        linit, lstep = train_loop.make_lora_train_step(cfg, lr=5e-3,
                                                       adapter_id=aid)
        lopt = linit(lora)
        jl = jax.jit(lstep)
        for _, b in zip(range(STEPS_LORA),
                        data.make_stream(256, 32, 8, task_id=3 + 5 * aid)):
            lora, lopt, m = jl(lora, lopt, params,
                               {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, params, lora, float(m["loss"])


def decode(cfg, params, lora, cache, kv_len, ids, disagg, steps, first):
    toks, logits = [], []
    last = first
    kv = kv_len
    for _ in range(steps):
        lg, cache = tfm.decode_step(params, last, cache, kv, cfg,
                                    lora=lora, adapter_ids=ids,
                                    disagg=disagg)
        logits.append(np.asarray(lg[0], np.float64))
        last = jnp.argmax(lg, -1)
        toks.append(int(last[0]))
        kv = kv + 1
    return toks, logits


def _cs_pair(a, b):
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))


def main() -> None:
    t0 = time.time()
    cfg, params, lora, final_loss = train_tiny()
    emit("quality.train", (time.time() - t0) * 1e6,
         f"final_lora_loss={final_loss:.3f}")

    rng = np.random.default_rng(0)
    agree_fork, agree_full, cos_fork, cos_full = [], [], [], []
    agree_bcast, cos_bcast = [], []
    for c in range(N_CONTEXTS):
        ctx = jnp.asarray(rng.integers(0, 256, size=(1, 40)))
        ids_a = jnp.zeros((1,), jnp.int32)
        ids_b = jnp.ones((1,), jnp.int32)
        # exact: B's own unified cache
        cache = tfm.init_cache(cfg, 1, 96, dtype=jnp.float32)
        _, cache_exact = tfm.prefill(params, ctx, cache, cfg, lora=lora,
                                     adapter_ids=ids_b)
        # forkkv: bCache from A's trajectory + B's rCache
        cache = tfm.init_cache(cfg, 1, 96, disagg=True, dtype=jnp.float32)
        _, ca = tfm.prefill(params, ctx, cache, cfg, lora=lora,
                            adapter_ids=ids_a, disagg=True)
        cb = tfm.init_cache(cfg, 1, 96, disagg=True, dtype=jnp.float32)
        _, cb = tfm.prefill(params, ctx, cb, cfg, lora=lora,
                            adapter_ids=ids_b, disagg=True)
        cache_fork = dict(ca)
        cache_fork["k_res"], cache_fork["v_res"] = cb["k_res"], cb["v_res"]
        # broadcast fork: BASE-trajectory bCache + B's residuals computed
        # from the base x (A_B applied, B_B zeroed during the pass)
        lora_bc = dict(lora)
        for kname in ("b_q", "b_k", "b_v"):
            lora_bc[kname] = lora[kname].at[:, 1].set(0.0)
        cbc = tfm.init_cache(cfg, 1, 96, disagg=True, dtype=jnp.float32)
        _, cbc = tfm.prefill(params, ctx, cbc, cfg, lora=lora_bc,
                             adapter_ids=ids_b, disagg=True)
        cache_bcast = dict(cbc)   # base k/v == base trajectory; res == x@A_B
        # full reuse: A's whole unified cache
        cache = tfm.init_cache(cfg, 1, 96, dtype=jnp.float32)
        _, cache_full = tfm.prefill(params, ctx, cache, cfg, lora=lora,
                                    adapter_ids=ids_a)

        kv = jnp.full((1,), ctx.shape[1], jnp.int32)
        first = ctx[:, -1]
        ref_t, ref_l = decode(cfg, params, lora, cache_exact, kv, ids_b,
                              False, DECODE_STEPS, first)
        fk_t, fk_l = decode(cfg, params, lora, cache_fork, kv, ids_b,
                            True, DECODE_STEPS, first)
        bc_t, bc_l = decode(cfg, params, lora, cache_bcast, kv, ids_b,
                            True, DECODE_STEPS, first)
        fu_t, fu_l = decode(cfg, params, lora, cache_full, kv, ids_b,
                            False, DECODE_STEPS, first)
        agree_fork.append(np.mean([a == b for a, b in zip(ref_t, fk_t)]))
        agree_bcast.append(np.mean([a == b for a, b in zip(ref_t, bc_t)]))
        cos_bcast.append(np.mean([_cs_pair(a, b)
                                  for a, b in zip(ref_l, bc_l)]))
        agree_full.append(np.mean([a == b for a, b in zip(ref_t, fu_t)]))

        cos_fork.append(np.mean([_cs_pair(a, b)
                                 for a, b in zip(ref_l, fk_l)]))
        cos_full.append(np.mean([_cs_pair(a, b)
                                 for a, b in zip(ref_l, fu_l)]))

    emit("quality.token_agreement", 0,
         f"forkkv={np.mean(agree_fork):.3f};"
         f"broadcast={np.mean(agree_bcast):.3f};"
         f"full_reuse={np.mean(agree_full):.3f}")
    emit("quality.logit_cosine", 0,
         f"forkkv={np.mean(cos_fork):.4f};"
         f"broadcast={np.mean(cos_bcast):.4f};"
         f"full_reuse={np.mean(cos_full):.4f}")


if __name__ == "__main__":
    main()
