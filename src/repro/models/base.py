"""Shared model-zoo utilities: init helpers, norms, logical-axis pytrees.

Params are plain dict pytrees.  Every model also exposes a parallel pytree of
*logical axis names* (MaxText-style) consumed by ``repro.launch.sharding`` to
build PartitionSpecs with divisibility fallbacks.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def split_keys(key, n):
    return list(jax.random.split(key, n))


def count_params(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token cross entropy. logits: (B,S,V), labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
