"""Llama-family transformer: dense, MoE and VLM-backbone variants.

Covers llama3-405b, internlm2, starcoder2, h2o-danube (SWA), the mistral
backbone of llava-next, dbrx / llama4 (MoE), and the paper's own models
(llama3-8b, qwen2.5).  Single implementation, configured by
:class:`repro.core.config.ModelConfig`.

Three execution modes:
  * ``forward``      — full causal pass (training / teacher-forcing)
  * ``prefill``      — populate a KV cache (unified or disaggregated)
  * ``decode``       — one token per request against the cache

Multi-LoRA is first-class: all adapters live in stacked arrays and each batch
row selects its adapter (``adapter_ids``), the TPU analogue of Punica BGMV.
The disaggregated path stores rank-r residuals (rCache) next to the shared
base cache (bCache) and computes attention via ResidualAttention
(:mod:`repro.kernels.ops`).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import attention as attn_lib
from repro.core import rope as rope_lib
from repro.core.config import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models import base

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Parameter init / logical axes
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = cfg.activation_dtype
    d, L = cfg.d_model, cfg.num_layers
    ks = base.split_keys(key, 16)
    layers: Params = {
        "ln1": jnp.zeros((L, d), dt),
        "ln2": jnp.zeros((L, d), dt),
        "wq": base.dense_init(ks[0], (L, d, cfg.q_dim), dt),
        "wk": base.dense_init(ks[1], (L, d, cfg.kv_dim), dt),
        "wv": base.dense_init(ks[2], (L, d, cfg.kv_dim), dt),
        "wo": base.dense_init(ks[3], (L, cfg.q_dim, d), dt),
    }
    if cfg.num_experts:
        ffe = cfg.moe_d_ff or cfg.d_ff
        L_moe = L // cfg.moe_interleave
        layers.update({
            "router": base.dense_init(ks[4], (L_moe, d, cfg.num_experts), dt),
            "w_gate_e": base.dense_init(
                ks[5], (L_moe, cfg.num_experts, d, ffe), dt),
            "w_up_e": base.dense_init(
                ks[6], (L_moe, cfg.num_experts, d, ffe), dt),
            "w_down_e": base.dense_init(
                ks[7], (L_moe, cfg.num_experts, ffe, d), dt),
        })
        if cfg.moe_shared_expert:
            layers["w_gate_s"] = base.dense_init(ks[10], (L_moe, d, ffe), dt)
            layers["w_up_s"] = base.dense_init(ks[11], (L_moe, d, ffe), dt)
            layers["w_down_s"] = base.dense_init(ks[12], (L_moe, ffe, d), dt)
        if cfg.moe_interleave > 1:          # interleaved dense MLP layers
            L_dense = L - L_moe
            layers["w_gate"] = base.dense_init(ks[13], (L_dense, d, cfg.d_ff), dt)
            layers["w_up"] = base.dense_init(ks[14], (L_dense, d, cfg.d_ff), dt)
            layers["w_down"] = base.dense_init(ks[15], (L_dense, cfg.d_ff, d), dt)
    else:
        if cfg.mlp_activation == "silu":
            layers["w_gate"] = base.dense_init(ks[4], (L, d, cfg.d_ff), dt)
        layers["w_up"] = base.dense_init(ks[5], (L, d, cfg.d_ff), dt)
        layers["w_down"] = base.dense_init(ks[6], (L, cfg.d_ff, d), dt)
    params: Params = {
        "embed": base.dense_init(ks[8], (cfg.vocab_size, d), dt),
        "final_norm": jnp.zeros((d,), dt),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = base.dense_init(ks[9], (d, cfg.vocab_size), dt)
    if cfg.frontend == "vision_stub":
        # projector from (stubbed) vision features to d_model
        params["mm_projector"] = base.dense_init(ks[10], (d, d), dt)
    return params


def logical_axes(cfg: ModelConfig) -> Params:
    layers = {
        "ln1": ("layers", "embed"),
        "ln2": ("layers", "embed"),
        "wq": ("layers", "embed", "q_out"),
        "wk": ("layers", "embed", "kv_out"),
        "wv": ("layers", "embed", "kv_out"),
        "wo": ("layers", "q_out", "embed"),
    }
    if cfg.num_experts:
        layers.update({
            "router": ("layers", "embed", None),
            "w_gate_e": ("layers", "expert_w", "embed", "ff"),
            "w_up_e": ("layers", "expert_w", "embed", "ff"),
            "w_down_e": ("layers", "expert_w", "ff", "embed"),
        })
        if cfg.moe_shared_expert:
            layers["w_gate_s"] = ("layers", "embed", "ff")
            layers["w_up_s"] = ("layers", "embed", "ff")
            layers["w_down_s"] = ("layers", "ff", "embed")
        if cfg.moe_interleave > 1:
            layers["w_gate"] = ("layers", "embed", "ff")
            layers["w_up"] = ("layers", "embed", "ff")
            layers["w_down"] = ("layers", "ff", "embed")
    else:
        if cfg.mlp_activation == "silu":
            layers["w_gate"] = ("layers", "embed", "ff")
        layers["w_up"] = ("layers", "embed", "ff")
        layers["w_down"] = ("layers", "ff", "embed")
    axes = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("embed", "vocab")
    if cfg.frontend == "vision_stub":
        axes["mm_projector"] = ("embed", "embed")
    return axes


def init_lora_stacks(cfg: ModelConfig, key: jax.Array, n_adapters: int,
                     nonzero: bool = True) -> Params:
    """Stacked LoRA adapters for q/k/v over all layers: BGMV layout."""
    dt = cfg.activation_dtype
    d, L, r = cfg.d_model, cfg.num_layers, cfg.lora.rank
    ks = base.split_keys(key, 6)
    scale_b = 0.05 if nonzero else 0.0

    def mk(k1, k2, d_out):
        a = jax.random.normal(k1, (L, n_adapters, d, r), jnp.float32) / jnp.sqrt(d)
        b = jax.random.normal(k2, (L, n_adapters, r, d_out), jnp.float32)
        b = b * scale_b / jnp.sqrt(r)
        return a.astype(dt), b.astype(dt)

    a_q, b_q = mk(ks[0], ks[1], cfg.q_dim)
    a_k, b_k = mk(ks[2], ks[3], cfg.kv_dim)
    a_v, b_v = mk(ks[4], ks[5], cfg.kv_dim)
    return {"a_q": a_q, "b_q": b_q, "a_k": a_k, "b_k": b_k,
            "a_v": a_v, "b_v": b_v,
            # per-layer copy so every leaf carries the leading L (scan) dim
            "scaling": jnp.full((L, n_adapters), cfg.lora.scaling,
                                jnp.float32)}


def lora_logical_axes() -> Params:
    return {"a_q": ("layers", None, "embed", "rank"),
            "b_q": ("layers", None, "rank", "q_out"),
            "a_k": ("layers", None, "embed", "rank"),
            "b_k": ("layers", None, "rank", "kv_out"),
            "a_v": ("layers", None, "embed", "rank"),
            "b_v": ("layers", None, "rank", "kv_out"),
            "scaling": ("layers", None)}


# --------------------------------------------------------------------------
# KV-cache int8 quantization (beyond-paper, see EXPERIMENTS.md §Perf)
# --------------------------------------------------------------------------
def quantize_kv(x):
    """Per-(position, head) symmetric int8.  x: (..., Hkv, hd)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------
def _bgmv(x, a_l, b_l, scaling, adapter_ids):
    """Per-row LoRA offset: x (B,S,d) -> (B,S,d_out); a_l (N,d,r), b_l (N,r,o)."""
    a = a_l[adapter_ids]                      # (B, d, r)
    b = b_l[adapter_ids]                      # (B, r, o)
    s = scaling[adapter_ids].astype(x.dtype)  # (B,)
    r = jnp.einsum("bsd,bdr->bsr", x, a.astype(x.dtype))
    return jnp.einsum("bsr,bro->bso", r, b.astype(x.dtype)) * s[:, None, None]


def _bgmv_down(x, a_l, scaling, adapter_ids):
    a = a_l[adapter_ids]
    s = scaling[adapter_ids].astype(x.dtype)
    return jnp.einsum("bsd,bdr->bsr", x, a.astype(x.dtype)) * s[:, None, None]


def mlp(p_l, x, cfg: ModelConfig):
    if cfg.mlp_activation == "silu":
        h = jax.nn.silu(x @ p_l["w_gate"]) * (x @ p_l["w_up"])
    else:
        h = jax.nn.gelu(x @ p_l["w_up"])
    return h @ p_l["w_down"]


def moe_ffn(p_l, x, cfg: ModelConfig, capacity_factor: float = 0.0):
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    """Scatter-based capacity MoE (tensor-parallel friendly, see DESIGN.md).

    Expert weights are sharded along the ff dim; tokens are dispatched to an
    (E, C, d) buffer with a capacity of ``k*t/E * cf`` and gathered back.
    FLOP overcount vs. perfectly-dropless is bounded by cf.
    """
    bsz, s, d = x.shape
    t = bsz * s
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(t, d)
    logits = (xf @ p_l["router"]).astype(jnp.float32)        # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (t, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(max(8, ((t * k / E) * capacity_factor + 7) // 8 * 8))
    flat_e = idx.reshape(-1)                                  # (t*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    valid = pos < cap
    dest = jnp.where(valid, flat_e * cap + pos, E * cap)      # overflow slot
    token_of = jnp.arange(t * k) // k
    buf = jnp.zeros((E * cap + 1, d), x.dtype).at[dest].set(xf[token_of])
    h = buf[:-1].reshape(E, cap, d)
    a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p_l["w_gate_e"]))
    a = a * jnp.einsum("ecd,edf->ecf", h, p_l["w_up_e"])
    o = jnp.einsum("ecf,efd->ecd", a, p_l["w_down_e"])
    o_flat = jnp.concatenate([o.reshape(E * cap, d),
                              jnp.zeros((1, d), x.dtype)], axis=0)
    y = o_flat[dest] * (gates.reshape(-1) * valid).astype(x.dtype)[:, None]
    y = y.reshape(t, k, d).sum(axis=1)
    # load-balance aux loss (returned via closure-free side channel not
    # needed for serving; training uses aux from `moe_aux_loss`)
    y = y.reshape(bsz, s, d)
    if "w_gate_s" in p_l:   # shared (always-on) expert, llama4-style
        y = y + (jax.nn.silu(x @ p_l["w_gate_s"]) *
                 (x @ p_l["w_up_s"])) @ p_l["w_down_s"]
    return y


def moe_aux_loss(p_l, x, cfg: ModelConfig) -> jnp.ndarray:
    """Switch-style load-balance loss for one layer."""
    bsz, s, d = x.shape
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax((xf @ p_l["router"]).astype(jnp.float32), -1)
    _, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac_tokens * frac_probs)


def ffn(p_l, x, cfg: ModelConfig):
    # dispatch on the params present so interleaved MoE (dense sublayers
    # between MoE sublayers, llama4-style) works inside one scan body
    return moe_ffn(p_l, x, cfg) if "router" in p_l else mlp(p_l, x, cfg)


# --------------------------------------------------------------------------
# Attention with unified / disaggregated caches
# --------------------------------------------------------------------------
def _qkv(p_l, x, cfg, lora, adapter_ids, positions):
    """Project q (RoPE'd, with LoRA) and raw k/v base projections."""
    bsz, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p_l["wq"]
    if lora is not None:
        q = q + _bgmv(x, lora["a_q"], lora["b_q"], lora["scaling"], adapter_ids)
    q = q.reshape(bsz, s, cfg.num_heads, hd)
    if cfg.use_rope:
        sin, cos = rope_lib.rope_sincos(positions, hd, cfg.rope_theta)
        q = rope_lib.apply_rope(q, sin.astype(x.dtype), cos.astype(x.dtype))
    else:
        # identity rotation so the deferred-RoPE reconstruction is a no-op
        sin = jnp.zeros(positions.shape + (hd // 2,), jnp.float32)
        cos = jnp.ones(positions.shape + (hd // 2,), jnp.float32)
    return q, sin.astype(x.dtype), cos.astype(x.dtype)


_EMPTY_POS = 1 << 30


def _ring_kpos(kv_len: jnp.ndarray, window: int) -> jnp.ndarray:
    """Absolute positions held by each slot of a ring buffer. (B, W).

    Slot s holds the largest position p < n with p ≡ s (mod W); empty slots
    (p < 0, i.e. cache not yet wrapped) get a sentinel that fails every
    causal mask.
    """
    slots = jnp.arange(window)[None, :]
    n = kv_len[:, None]
    p = (n - 1) - (n - 1 - slots) % window
    return jnp.where(p >= 0, p, _EMPTY_POS)


def attention(p_l, x, cfg: ModelConfig, *, positions, mode: str,
              cache=None, kv_len=None, lora=None, adapter_ids=None,
              disagg: bool = False, window: int = 0,
              chunk_start=None):
    """One attention layer.  Returns (out, new_cache).

    mode: "full"    — no cache, causal over x (training)
          "prefill" — write cache for positions, causal (+ q_offset)
          "decode"  — x is (B, 1, d), read/update cache at kv_len
    cache: dict with "k","v" [, "k_res","v_res"] (layer slice, no L dim)
    """
    bsz, s, d = x.shape
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    if positions.ndim == 1:
        positions = positions[:, None]            # decode: (B,) -> (B, 1)
    q, sin, cos = _qkv(p_l, x, cfg, lora, adapter_ids, positions)

    k_base = (x @ p_l["wk"]).reshape(bsz, s, cfg.num_kv_heads, hd)
    v_base = (x @ p_l["wv"]).reshape(bsz, s, cfg.num_kv_heads, hd)
    if cfg.use_rope:
        k_base = rope_lib.apply_rope(k_base, sin, cos)

    if disagg and lora is not None:
        k_res = _bgmv_down(x, lora["a_k"], lora["scaling"], adapter_ids)
        v_res = _bgmv_down(x, lora["a_v"], lora["scaling"], adapter_ids)
        bk_rows = lora["b_k"][adapter_ids].reshape(bsz, cfg.lora.rank, -1)
        bv_rows = lora["b_v"][adapter_ids].reshape(bsz, cfg.lora.rank, -1)
    else:
        if lora is not None:   # unified: fold LoRA into cached K/V exactly
            k_off = _bgmv(x, lora["a_k"], lora["b_k"], lora["scaling"],
                          adapter_ids).reshape(bsz, s, cfg.num_kv_heads, hd)
            v_off = _bgmv(x, lora["a_v"], lora["b_v"], lora["scaling"],
                          adapter_ids).reshape(bsz, s, cfg.num_kv_heads, hd)
            if cfg.use_rope:
                k_off = rope_lib.apply_rope(k_off, sin, cos)
            k_base = k_base + k_off
            v_base = v_base + v_off
        k_res = v_res = bk_rows = bv_rows = None

    if mode == "full":
        if disagg and lora is not None:
            if s >= attn_lib.FLASH_THRESHOLD:
                if window > 0:
                    out = attn_lib.banded_window_attention(
                        q, k_base, v_base, window=window, scale=scale,
                        k_res=k_res, v_res=v_res, b_k=bk_rows, b_v=bv_rows,
                        rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
                else:
                    out = attn_lib.flash_attention(
                        q, k_base, v_base, qpos=positions, kpos=positions,
                        window=window, causal=True, scale=scale, k_res=k_res,
                        v_res=v_res, b_k=bk_rows, b_v=bv_rows,
                        rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
            else:
                # attention over reconstructed K/V: train/serve parity
                out = kernel_ops.residual_attention(
                    q, k_base, v_base, k_res, v_res, bk_rows, bv_rows, sin,
                    cos, qpos=positions, kv_len=None, window=window,
                    causal=True, scale=scale)
        else:
            out = attn_lib.mha(q, k_base, v_base, causal=True, window=window,
                               scale=scale)
        return out, None

    assert cache is not None
    smax = cache["k"].shape[1]
    is_ring = window > 0 and smax == window

    if mode == "prefill":
        # write positions [kv_start, kv_start + s) ; assume batch-uniform
        # start offset = positions[:, 0]
        bidx = jnp.arange(bsz)[:, None]
        new_cache = dict(cache)
        if is_ring and s >= window:
            # only the last `window` chunk tokens survive: write exactly one
            # token per ring slot (duplicate scatter indices are UB)
            slot = positions[:, -window:] % window
            wr = lambda t: t[:, -window:]
        else:
            slot = (positions % window) if is_ring else positions
            wr = lambda t: t
        if cfg.kv_quant == "int8":
            kq, ks_ = quantize_kv(k_base)
            vq, vs_ = quantize_kv(v_base)
            new_cache["k"] = cache["k"].at[bidx, slot].set(wr(kq))
            new_cache["v"] = cache["v"].at[bidx, slot].set(wr(vq))
            new_cache["k_scale"] = cache["k_scale"].at[bidx, slot].set(wr(ks_))
            new_cache["v_scale"] = cache["v_scale"].at[bidx, slot].set(wr(vs_))
        else:
            new_cache["k"] = cache["k"].at[bidx, slot].set(wr(k_base))
            new_cache["v"] = cache["v"].at[bidx, slot].set(wr(v_base))
        if k_res is not None:
            new_cache["k_res"] = cache["k_res"].at[bidx, slot].set(wr(k_res))
            new_cache["v_res"] = cache["v_res"].at[bidx, slot].set(wr(v_res))
        new_len = positions[:, -1] + 1
        use_dis = disagg and lora is not None
        if is_ring and chunk_start == 0 and s >= attn_lib.FLASH_THRESHOLD \
                and s >= window:
            # first chunk fills the whole ring: banded self-attention over
            # the fresh chunk (no old cache to attend to) — §Perf pair B
            out = attn_lib.banded_window_attention(
                q, k_base, v_base, window=window, scale=scale,
                k_res=k_res if use_dis else None,
                v_res=v_res if use_dis else None,
                b_k=bk_rows, b_v=bv_rows, rope_theta=cfg.rope_theta,
                use_rope=cfg.use_rope)
        elif is_ring:
            # a chunk may overwrite ring slots its own earlier queries still
            # need — attend over [old cache ‖ fresh chunk] instead
            old_kpos = _ring_kpos(positions[:, 0], window)       # state@start
            k_all = jnp.concatenate([cache["k"], k_base], axis=1)
            v_all = jnp.concatenate([cache["v"], v_base], axis=1)
            kpos_all = jnp.concatenate([old_kpos, positions], axis=1)
            if use_dis:
                kr_all = jnp.concatenate([cache["k_res"], k_res], axis=1)
                vr_all = jnp.concatenate([cache["v_res"], v_res], axis=1)
            else:
                kr_all = vr_all = None
            out = _attend(q, k_all, v_all, kr_all, vr_all, bk_rows, bv_rows,
                          kpos_all, None, positions, window, scale, cfg,
                          use_dis)
        else:
            # attention over the *updated* cache (covers chunked prefill)
            out = _cached_attention(q, new_cache, positions, new_len, cfg,
                                    bk_rows, bv_rows, window, is_ring, scale,
                                    use_dis)
        return out, new_cache

    # decode: s == 1
    pos = kv_len                                  # (B,) next position
    slot = (pos % window) if is_ring else pos
    bidx = jnp.arange(bsz)
    new_cache = dict(cache)
    if cfg.kv_quant == "int8":
        kq, ks_ = quantize_kv(k_base[:, 0])
        vq, vs_ = quantize_kv(v_base[:, 0])
        new_cache["k"] = cache["k"].at[bidx, slot].set(kq)
        new_cache["v"] = cache["v"].at[bidx, slot].set(vq)
        new_cache["k_scale"] = cache["k_scale"].at[bidx, slot].set(ks_)
        new_cache["v_scale"] = cache["v_scale"].at[bidx, slot].set(vs_)
    else:
        new_cache["k"] = cache["k"].at[bidx, slot].set(k_base[:, 0])
        new_cache["v"] = cache["v"].at[bidx, slot].set(v_base[:, 0])
    if k_res is not None:
        new_cache["k_res"] = cache["k_res"].at[bidx, slot].set(k_res[:, 0])
        new_cache["v_res"] = cache["v_res"].at[bidx, slot].set(v_res[:, 0])
    out = _cached_attention(q, new_cache, positions, kv_len + 1,
                            cfg, bk_rows, bv_rows, window, is_ring, scale,
                            disagg and lora is not None)
    return out, new_cache


def _cached_attention(q, cache, qpos, kv_len, cfg, bk_rows, bv_rows,
                      window, is_ring, scale, use_disagg):
    """Attention of q against a (possibly ring) cache."""
    k, v = cache["k"], cache["v"]
    if cfg.kv_quant == "int8":
        # dequantize on the fly; XLA fuses the convert+scale into the
        # attention matmul's operand, so HBM traffic stays int8
        k = dequantize_kv(k, cache["k_scale"], q.dtype)
        v = dequantize_kv(v, cache["v_scale"], q.dtype)
    bsz, smax = k.shape[0], k.shape[1]
    if is_ring:
        kmask_pos = _ring_kpos(kv_len, smax)      # (B, W) absolute positions
        valid_len = None
    else:
        kmask_pos = jnp.broadcast_to(jnp.arange(smax)[None], (bsz, smax))
        valid_len = kv_len
    return _attend(q, k, v, cache.get("k_res"), cache.get("v_res"),
                   bk_rows, bv_rows, kmask_pos, valid_len, qpos, window,
                   scale, cfg, use_disagg)


def _attend(q, k, v, k_res, v_res, bk_rows, bv_rows, kmask_pos, valid_len,
            qpos, window, scale, cfg, use_disagg):
    hd = cfg.resolved_head_dim
    if valid_len is not None:
        in_range = jnp.arange(k.shape[1])[None] < valid_len[:, None]
        kmask_pos_f = jnp.where(in_range, kmask_pos, _EMPTY_POS)
    else:
        kmask_pos_f = kmask_pos
    if q.shape[1] >= attn_lib.FLASH_THRESHOLD and \
            k.shape[1] >= attn_lib.FLASH_THRESHOLD:
        return attn_lib.flash_attention(
            q, k, v, qpos=qpos, kpos=kmask_pos_f, window=window, causal=True,
            scale=scale,
            k_res=k_res if use_disagg else None,
            v_res=v_res if use_disagg else None,
            b_k=bk_rows, b_v=bv_rows, rope_theta=cfg.rope_theta,
            use_rope=cfg.use_rope)
    if use_disagg:
        if cfg.use_rope:
            sin_k, cos_k = rope_lib.rope_sincos(
                jnp.where(kmask_pos >= _EMPTY_POS, 0, kmask_pos), hd,
                cfg.rope_theta)
        else:
            sin_k = jnp.zeros(kmask_pos.shape + (hd // 2,), jnp.float32)
            cos_k = jnp.ones(kmask_pos.shape + (hd // 2,), jnp.float32)
        return _masked_residual_attention(
            q, k, v, k_res, v_res, bk_rows, bv_rows,
            sin_k.astype(q.dtype), cos_k.astype(q.dtype), qpos, kmask_pos,
            valid_len, window, scale)
    return _masked_mha(q, k, v, qpos, kmask_pos, valid_len, window, scale)


def _build_mask(qpos, kmask_pos, valid_len, window, bsz, sq, sk):
    qp = qpos[:, :, None]                          # (B, Sq, 1)
    kp = kmask_pos[:, None, :]                     # (B, 1, Sk)
    mask = kp <= qp
    if window > 0:
        mask &= kp > qp - window
    if valid_len is not None:
        mask &= kp < valid_len[:, None, None]
    return mask[:, None]                           # (B, 1, Sq, Sk)


def _masked_mha(q, k, v, qpos, kmask_pos, valid_len, window, scale):
    s = attn_lib._gqa_scores(q, k) * scale
    mask = _build_mask(qpos, kmask_pos, valid_len, window,
                       q.shape[0], q.shape[1], k.shape[1])
    s = jnp.where(mask, s, attn_lib.NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
    return attn_lib._gqa_out(p, v).astype(q.dtype)


def _masked_residual_attention(q, k_base, v_base, k_res, v_res, b_k, b_v,
                               sin, cos, qpos, kmask_pos, valid_len, window,
                               scale):
    from repro.kernels import ref as ref_mod
    k, v = ref_mod.reconstruct(k_base, v_base, k_res, v_res, b_k, b_v,
                               sin, cos)
    return _masked_mha(q, k, v, qpos, kmask_pos, valid_len, window, scale)


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------
def _layer_window(cfg: ModelConfig) -> int:
    return cfg.sliding_window


def embed_tokens(params, tokens, cfg: ModelConfig,
                 extra_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = params["embed"][tokens]
    if extra_embeds is not None:
        if "mm_projector" in params:
            extra_embeds = extra_embeds @ params["mm_projector"]
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def unembed(params, x, cfg: ModelConfig) -> jnp.ndarray:
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["unembed"]


def _layer_fn(x, p_l, cfg, *, positions, mode, cache_l, kv_len, lora_l,
              adapter_ids, disagg, chunk_start=None):
    h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
    attn_out, new_cache = attention(
        p_l, h, cfg, positions=positions, mode=mode, cache=cache_l,
        kv_len=kv_len, lora=lora_l, adapter_ids=adapter_ids, disagg=disagg,
        window=_layer_window(cfg), chunk_start=chunk_start)
    wo = p_l["wo"]
    x = x + attn_out.reshape(x.shape[0], x.shape[1], -1) @ wo
    h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
    x = x + ffn(p_l, h, cfg)
    return x, new_cache


def apply_layers(params, x, cfg: ModelConfig, *, positions, mode: str,
                 cache=None, kv_len=None, lora=None, adapter_ids=None,
                 disagg: bool = False, remat: Optional[bool] = None,
                 chunk_start=None):
    """Scan over the layer stack.  cache/lora leaves carry a leading L dim."""
    remat = cfg.remat if remat is None else remat
    layer_params = params["layers"]

    def body(carry, xs):
        xc = carry
        p_l, cache_l, lora_l = xs
        out, new_cache = _layer_fn(
            xc, p_l, cfg, positions=positions, mode=mode, cache_l=cache_l,
            kv_len=kv_len, lora_l=lora_l, adapter_ids=adapter_ids,
            disagg=disagg, chunk_start=chunk_start)
        return out, new_cache

    body_fn = jax.checkpoint(body) if (remat and mode == "full") else body

    L = cfg.num_layers
    iv = cfg.moe_interleave if cfg.num_experts else 1
    if iv > 1:
        return _apply_layers_interleaved(
            params, x, cfg, positions=positions, mode=mode, cache=cache,
            kv_len=kv_len, lora=lora, adapter_ids=adapter_ids,
            disagg=disagg, remat=remat)
    dummy_cache = cache if cache is not None else jnp.zeros((L,), x.dtype)
    dummy_lora = lora if lora is not None else jnp.zeros((L,), x.dtype)

    def scan_body(carry, xs):
        p_l, c_l, l_l = xs
        c_in = c_l if cache is not None else None
        l_in = l_l if lora is not None else None
        out, new_c = body_fn(carry, (p_l, c_in, l_in))
        return out, (new_c if new_c is not None else jnp.zeros((), x.dtype))

    if cfg.scan_layers:
        groups = cfg.scan_groups
        if groups and groups > 1 and L % groups == 0 and mode == "full":
            # two-level scan: outer over groups (remat'd), inner over layers
            inner = L // groups
            resh = lambda t: t.reshape((groups, inner) + t.shape[1:])
            lp = jax.tree_util.tree_map(resh, layer_params)
            lc = jax.tree_util.tree_map(resh, dummy_cache)
            ll = jax.tree_util.tree_map(resh, dummy_lora)

            def outer_body(carry, xs):
                p_g, c_g, l_g = xs

                def inner_scan(carry2, xs2):
                    return scan_body(carry2, xs2)

                out, cs = jax.lax.scan(inner_scan, carry, (p_g, c_g, l_g))
                return out, cs

            outer = jax.checkpoint(outer_body) if remat else outer_body
            x, new_caches = jax.lax.scan(outer, x, (lp, lc, ll))
            new_caches = jax.tree_util.tree_map(
                lambda t: t.reshape((L,) + t.shape[2:]), new_caches)
        else:
            x, new_caches = jax.lax.scan(
                scan_body, x, (layer_params, dummy_cache, dummy_lora))
    else:
        new_list = []
        for i in range(L):
            p_l = jax.tree_util.tree_map(lambda t: t[i], layer_params)
            c_l = jax.tree_util.tree_map(lambda t: t[i], cache) \
                if cache is not None else None
            l_l = jax.tree_util.tree_map(lambda t: t[i], lora) \
                if lora is not None else None
            x, nc = body_fn(x, (p_l, c_l, l_l))
            new_list.append(nc)
        if cache is not None:
            new_caches = jax.tree_util.tree_map(
                lambda *ts: jnp.stack(ts), *new_list)
        else:
            new_caches = None
    if cache is None:
        new_caches = None
    return x, new_caches


_ATTN_KEYS = ("ln1", "ln2", "wq", "wk", "wv", "wo")
_DENSE_KEYS = ("w_gate", "w_up", "w_down")
_MOE_KEYS = ("router", "w_gate_e", "w_up_e", "w_down_e",
             "w_gate_s", "w_up_s", "w_down_s")


def _apply_layers_interleaved(params, x, cfg: ModelConfig, *, positions,
                              mode, cache, kv_len, lora, adapter_ids,
                              disagg, remat):
    """Scan over groups of ``moe_interleave`` layers: (iv-1) dense-MLP
    sublayers followed by one MoE sublayer (llama4-style)."""
    lp = params["layers"]
    L, iv = cfg.num_layers, cfg.moe_interleave
    G = L // iv

    def resh(n):
        return lambda t: t.reshape((G, n) + t.shape[1:])

    attn_tree = {k: resh(iv)(lp[k]) for k in _ATTN_KEYS}
    dense_tree = {k: resh(iv - 1)(lp[k]) for k in _DENSE_KEYS}
    moe_tree = {k: lp[k] for k in _MOE_KEYS if k in lp}       # (G, ...)
    cache_g = jax.tree_util.tree_map(resh(iv), cache) \
        if cache is not None else jnp.zeros((G,), x.dtype)
    lora_g = jax.tree_util.tree_map(resh(iv), lora) \
        if lora is not None else jnp.zeros((G,), x.dtype)

    def group_body(carry, xs):
        at, dn, mo, c_g, l_g = xs
        xc = carry
        ncs = []
        for j in range(iv):
            p_att = {k: at[k][j] for k in _ATTN_KEYS}
            p_mlp = mo if j == iv - 1 else {k: dn[k][j] for k in _DENSE_KEYS}
            p_l = {**p_att, **p_mlp}
            c_l = jax.tree_util.tree_map(lambda t: t[j], c_g) \
                if cache is not None else None
            l_l = jax.tree_util.tree_map(lambda t: t[j], l_g) \
                if lora is not None else None
            xc, nc = _layer_fn(xc, p_l, cfg, positions=positions, mode=mode,
                               cache_l=c_l, kv_len=kv_len, lora_l=l_l,
                               adapter_ids=adapter_ids, disagg=disagg)
            ncs.append(nc if nc is not None else jnp.zeros((), xc.dtype))
        if cache is not None:
            out_c = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *ncs)
        else:
            out_c = jnp.zeros((), xc.dtype)
        return xc, out_c

    fn = jax.checkpoint(group_body) if (remat and mode == "full") \
        else group_body
    x, new_caches = jax.lax.scan(
        fn, x, (attn_tree, dense_tree, moe_tree, cache_g, lora_g))
    if cache is not None:
        new_caches = jax.tree_util.tree_map(
            lambda t: t.reshape((L,) + t.shape[2:]), new_caches)
    else:
        new_caches = None
    return x, new_caches


def forward(params, tokens, cfg: ModelConfig, *, extra_embeds=None,
            lora=None, adapter_ids=None, disagg: bool = False) -> jnp.ndarray:
    """Full causal pass -> logits (B, S_total, V)."""
    x = embed_tokens(params, tokens, cfg, extra_embeds)
    bsz, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (bsz, s))
    x, _ = apply_layers(params, x, cfg, positions=positions, mode="full",
                        lora=lora, adapter_ids=adapter_ids, disagg=disagg)
    return unembed(params, x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               disagg: bool = False, dtype=None) -> Params:
    dt = dtype or cfg.activation_dtype
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    w = cfg.sliding_window
    smax = min(max_len, w) if w else max_len
    if cfg.kv_quant == "int8":
        cache = {
            "k": jnp.zeros((L, batch, smax, cfg.num_kv_heads, hd), jnp.int8),
            "v": jnp.zeros((L, batch, smax, cfg.num_kv_heads, hd), jnp.int8),
            "k_scale": jnp.zeros((L, batch, smax, cfg.num_kv_heads),
                                 jnp.float32),
            "v_scale": jnp.zeros((L, batch, smax, cfg.num_kv_heads),
                                 jnp.float32),
        }
    else:
        cache = {
            "k": jnp.zeros((L, batch, smax, cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, smax, cfg.num_kv_heads, hd), dt),
        }
    if disagg:
        cache["k_res"] = jnp.zeros((L, batch, smax, cfg.lora.rank), dt)
        cache["v_res"] = jnp.zeros((L, batch, smax, cfg.lora.rank), dt)
    return cache


def cache_logical_axes(cfg: ModelConfig, disagg: bool = False) -> Params:
    axes = {"k": ("layers", "batch", None, "kv_heads", "kv_head_dim"),
            "v": ("layers", "batch", None, "kv_heads", "kv_head_dim")}
    if cfg.kv_quant == "int8":
        axes["k_scale"] = ("layers", "batch", None, "kv_heads")
        axes["v_scale"] = ("layers", "batch", None, "kv_heads")
    if disagg:
        axes["k_res"] = ("layers", "batch", None, "rank")
        axes["v_res"] = ("layers", "batch", None, "rank")
    return axes


def prefill(params, tokens, cache, cfg: ModelConfig, *, start: int = 0,
            extra_embeds=None, lora=None, adapter_ids=None,
            disagg: bool = False):
    """Populate cache with the prompt; returns (last-token logits, cache)."""
    x = embed_tokens(params, tokens, cfg, extra_embeds)
    bsz, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(start, start + s), (bsz, s))
    x, cache = apply_layers(params, x, cfg, positions=positions,
                            mode="prefill", cache=cache, lora=lora,
                            adapter_ids=adapter_ids, disagg=disagg,
                            chunk_start=start)
    return unembed(params, x[:, -1:], cfg), cache


def decode_step(params, tokens, cache, kv_len, cfg: ModelConfig, *,
                lora=None, adapter_ids=None, disagg: bool = False):
    """One decode step. tokens: (B,), kv_len: (B,). Returns (logits, cache)."""
    x = params["embed"][tokens][:, None]          # (B, 1, d)
    positions = kv_len
    x, cache = apply_layers(params, x, cfg, positions=positions,
                            mode="decode", cache=cache, kv_len=kv_len,
                            lora=lora, adapter_ids=adapter_ids, disagg=disagg)
    return unembed(params, x, cfg)[:, 0], cache
