"""Disaggregated KV cache math (paper §5.1).

For a LoRA-adapted K/V projection ``Y = xW + (x A_i) B_i * s``:

* ``bCache``: base projection.  For K, RoPE is applied *before* caching
  (positions are absolute, so the cached entry is final).  For V the base
  projection is cached as-is.
* ``rCache``: the rank-r residual ``x A_i * s`` — stored WITHOUT RoPE
  (dimension mismatch).  Reconstruction up-projects with ``B`` and applies
  RoPE then (deferred RoPE, exact by linearity).

This module is the *pure math* layer used by the model zoo, the Pallas kernel
oracle and the tests; the serving runtime stores these tensors in paged pools.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core import rope as rope_lib
from repro.core.lora import LoRAWeights, lora_down, lora_up


class DisaggKV(NamedTuple):
    """Disaggregated cache entries for one attention layer / one request."""

    k_base: jnp.ndarray    # (seq, kv_heads, head_dim)   — RoPE applied
    v_base: jnp.ndarray    # (seq, kv_heads, head_dim)
    k_res: jnp.ndarray     # (seq, r)                    — no RoPE, scaled
    v_res: jnp.ndarray     # (seq, r)


def project_base(x: jnp.ndarray, w_k: jnp.ndarray, w_v: jnp.ndarray,
                 sin: jnp.ndarray, cos: jnp.ndarray,
                 kv_heads: int, head_dim: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Base projections -> (k_base with RoPE, v_base). x: (..., seq, d)."""
    k = (x @ w_k).reshape(x.shape[:-1] + (kv_heads, head_dim))
    v = (x @ w_v).reshape(x.shape[:-1] + (kv_heads, head_dim))
    k = rope_lib.apply_rope(k, sin, cos)
    return k, v


def project_residual(x: jnp.ndarray, lora_k: LoRAWeights,
                     lora_v: LoRAWeights) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Residual (rCache) projections: ``x A * s`` for K and V."""
    return lora_down(x, lora_k), lora_down(x, lora_v)


def reconstruct_k(k_base: jnp.ndarray, k_res: jnp.ndarray,
                  lora_k: LoRAWeights, sin: jnp.ndarray, cos: jnp.ndarray,
                  kv_heads: int, head_dim: int) -> jnp.ndarray:
    """K = K_base + RoPE(K_res @ B_k)  (paper Alg. 1 lines 8-9)."""
    k_lora = lora_up(k_res, lora_k)
    k_lora = k_lora.reshape(k_res.shape[:-1] + (kv_heads, head_dim))
    k_lora = rope_lib.apply_rope(k_lora, sin, cos)
    return (k_base + k_lora).astype(k_base.dtype)


def reconstruct_v(v_base: jnp.ndarray, v_res: jnp.ndarray,
                  lora_v: LoRAWeights, kv_heads: int,
                  head_dim: int) -> jnp.ndarray:
    """V = V_base + V_res @ B_v."""
    v_lora = lora_up(v_res, lora_v)
    v_lora = v_lora.reshape(v_res.shape[:-1] + (kv_heads, head_dim))
    return (v_base + v_lora).astype(v_base.dtype)


def unified_kv(x: jnp.ndarray, w_k: jnp.ndarray, w_v: jnp.ndarray,
               lora_k: Optional[LoRAWeights], lora_v: Optional[LoRAWeights],
               sin: jnp.ndarray, cos: jnp.ndarray,
               kv_heads: int, head_dim: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The unified (baseline) cache: RoPE(xW_k + xA_kB_k), xW_v + xA_vB_v."""
    k = x @ w_k
    v = x @ w_v
    if lora_k is not None:
        k = k + lora_up(lora_down(x, lora_k), lora_k)
    if lora_v is not None:
        v = v + lora_up(lora_down(x, lora_v), lora_v)
    k = k.reshape(x.shape[:-1] + (kv_heads, head_dim))
    v = v.reshape(x.shape[:-1] + (kv_heads, head_dim))
    k = rope_lib.apply_rope(k, sin, cos)
    return k.astype(x.dtype), v.astype(x.dtype)


def memory_ratio(n_agents: int, rank: int, kv_dim: int) -> float:
    """Paper Eq. 3: M_R = 1/N + r/n."""
    return 1.0 / n_agents + rank / kv_dim
