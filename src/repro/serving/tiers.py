"""Tiered KV offload: CoW-aware HBM→host demotion/promotion (DESIGN.md §10).

The seed engine destroyed KV pages on LRU eviction, forcing a full
re-prefill of the shared bCache whenever device pages ran out.  This module
adds a second storage tier so eviction becomes *demotion*:

  * :class:`HostTier` — a numpy-backed page store with its own byte budget
    and LRU.  Entries hold the exact bytes of one KV page (all layers, K and
    V), so a later promotion restores the device cache bit-identically.
  * :class:`TieredPagePool` — a façade wrapping the existing
    :class:`~repro.serving.pool.PagePool`.  It keeps the whole refcounted
    device-page API (``alloc``/``incref``/``decref``/…) and adds the tier
    transitions used by the radix trees:

      - ``demote_node(node)``   device pages → host blobs; the radix node
        stays alive with ``tier == "host"`` and its ``pages`` list holding
        host *handles* instead of device page ids.
      - ``promote_node(node)``  host blobs → freshly allocated device pages
        (applying back-pressure through ``pressure_fn`` when the device
        pool is full); the node returns to ``tier == "device"``.

CoW invariants across tiers (DESIGN.md §10):
  * only pages whose sole reference is the radix tree (refcount == 1) are
    demoted — pages shared with in-flight requests never leave the device;
  * a demoted page is immutable in host memory; one demoted bCache page
    serves every agent that later re-forks it (the promotion re-creates a
    shared, refcounted device page);
  * nodes on a locked radix path (``lock_ref > 0``) are pinned in whichever
    tier they occupy: device eviction skips them and the host LRU refuses
    to drop their entries.

When the host budget is also exhausted the tier degrades to the seed
behaviour: true eviction (the node and its bytes are destroyed).
"""
from __future__ import annotations

import itertools
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

# A blob is one page's worth of cache bytes: a dict of numpy arrays
# (e.g. {"k": (L, page, Hkv, hd), "v": ...}) produced by the executor's
# export_pages and consumed by import_pages.
Blob = Dict[str, np.ndarray]


def blob_bytes(blob: Blob) -> int:
    return sum(int(a.nbytes) for a in blob.values())


class HostTier:
    """Numpy-backed second-tier page store: byte budget + LRU.

    Handles are opaque ints.  Entries carry their *owner* (the
    :class:`TieredPagePool` that demoted them) so a shared HostTier can
    serve several device pools (bCache + rCache) under ONE host budget —
    host DRAM is a single resource.  When the budget overflows, the least
    recently used evictable entry is dropped and the owner is notified via
    ``owner._on_host_evict(handle)`` so it can unlink the radix node.
    """

    def __init__(self, budget_bytes: int):
        self.budget_bytes = int(budget_bytes)
        self.used_bytes = 0
        self._entries: Dict[int, tuple] = {}   # handle -> (blob, nbytes, owner)
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._handles = itertools.count(1)
        # counters
        self.put_count = 0
        self.get_count = 0
        self.evicted_entries = 0
        self.evicted_bytes = 0

    def __contains__(self, handle: int) -> bool:
        return handle in self._entries

    @property
    def num_entries(self) -> int:
        return len(self._entries)

    def put(self, blob: Blob, owner=None) -> Optional[int]:
        """Store one page blob; LRU-evict unpinned entries to make room.

        Returns a handle, or None when the blob cannot fit even after
        evicting everything evictable (budget exhausted → caller falls
        back to true eviction).
        """
        nbytes = blob_bytes(blob)
        if nbytes > self.budget_bytes:
            return None
        if self.used_bytes + nbytes > self.budget_bytes:
            # one forward pass over an LRU snapshot — never rescan pinned
            # entries; eviction hooks may drop collateral handles, so
            # skip any that vanished under us
            for h in list(self._lru):
                if self.used_bytes + nbytes <= self.budget_bytes:
                    break
                if h not in self._entries:
                    continue
                _, _, own = self._entries[h]
                if own is None or own.host_can_evict(h):
                    self._evict(h)
            if self.used_bytes + nbytes > self.budget_bytes:
                return None
        handle = next(self._handles)
        self._entries[handle] = (blob, nbytes, owner)
        self._lru[handle] = None
        self.used_bytes += nbytes
        self.put_count += 1
        return handle

    def _evict(self, handle: int) -> None:
        blob, nbytes, owner = self._entries.pop(handle)
        self._lru.pop(handle, None)
        self.used_bytes -= nbytes
        self.evicted_entries += 1
        self.evicted_bytes += nbytes
        if owner is not None:
            owner._on_host_evict(handle)

    def get(self, handle: int) -> Blob:
        blob, _, _ = self._entries[handle]
        self._lru.move_to_end(handle)
        self.get_count += 1
        return blob

    def touch(self, handle: int) -> None:
        if handle in self._lru:
            self._lru.move_to_end(handle)

    def can_admit(self, nbytes: int) -> bool:
        """Could ``nbytes`` fit after evicting every unpinned entry?

        Demotion reserves its FULL blob total through this before storing
        anything: pinned (locked-node) entries don't count as evictable,
        so a demote that cannot complete never destroys other nodes'
        entries as collateral on the way to failing.
        """
        free = self.budget_bytes - self.used_bytes
        if nbytes <= free:
            return True
        evictable = sum(nb for h, (_, nb, own) in self._entries.items()
                        if own is None or own.host_can_evict(h))
        return nbytes <= free + evictable

    def free(self, handle: int) -> None:
        """Idempotent: freeing an already-evicted handle is a no-op."""
        if handle not in self._entries:
            return
        _, nbytes, _ = self._entries.pop(handle)
        self._lru.pop(handle, None)
        self.used_bytes -= nbytes


class TieredPagePool:
    """Façade over a device :class:`PagePool` adding a host demotion tier.

    Exposes the full PagePool API (the radix trees and the engine keep
    using it unchanged) plus the demote/promote transitions.  Device↔host
    byte movement is delegated to callbacks bound by the engine:

      export_fn(pages)        -> [blob, ...]   device → host copies
      import_fn(pages, blobs)                  host → device copies
      pressure_fn(n)                           free ≥ n device pages
                                               (tree LRU evict/demote)
    """

    is_tiered = True

    def __init__(self, pool, host: HostTier,
                 export_fn: Optional[Callable] = None,
                 import_fn: Optional[Callable] = None,
                 pressure_fn: Optional[Callable[[int], int]] = None,
                 promote_limit: int = 0):
        self.pool = pool
        self.host = host
        self.export_fn = export_fn
        self.import_fn = import_fn
        self.pressure_fn = pressure_fn
        self.promote_limit = promote_limit   # max pages promoted per match
        self._node_of: Dict[int, object] = {}  # handle -> radix Node
        self._match_promoted = 0
        self._page_nbytes: Optional[int] = None  # learned on first export
        # counters
        self.tier_hits = 0            # promote events (one per node)
        self.demoted_pages = 0
        self.demoted_bytes = 0
        self.promoted_pages = 0
        self.promoted_bytes = 0
        self.host_evicted_pages = 0   # pages truly lost from the host tier
        self.dropped_device_pages = 0  # device pages lost to host-LRU cascade
        self.demote_failures = 0
        self.promote_failures = 0
        self.io_errors = 0            # export/import raised (DESIGN.md §17)

    def bind(self, export_fn: Callable, import_fn: Callable,
             pressure_fn: Optional[Callable[[int], int]] = None) -> None:
        self.export_fn = export_fn
        self.import_fn = import_fn
        self.pressure_fn = pressure_fn

    # -------------------------------------------------- PagePool façade
    def can_alloc(self, n: int) -> bool:
        return self.pool.can_alloc(n)

    def alloc(self, n: int) -> Optional[List[int]]:
        return self.pool.alloc(n)

    def incref(self, pages: Sequence[int]) -> None:
        self.pool.incref(pages)

    def decref(self, pages: Sequence[int]) -> List[int]:
        return self.pool.decref(pages)

    def refcount(self, page: int) -> int:
        return self.pool.refcount(page)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return self.pool.pages_for_tokens(n_tokens)

    @property
    def num_pages(self) -> int:
        return self.pool.num_pages

    @property
    def page_size(self) -> int:
        return self.pool.page_size

    @property
    def name(self) -> str:
        return self.pool.name

    @property
    def used_pages(self) -> int:
        return self.pool.used_pages

    @property
    def free_pages(self) -> int:
        return self.pool.free_pages

    @property
    def utilization(self) -> float:
        return self.pool.utilization

    @property
    def alloc_count(self) -> int:
        return self.pool.alloc_count

    @property
    def oom_count(self) -> int:
        return self.pool.oom_count

    # ---------------------------------------------------- tier bridging
    def begin_match(self) -> None:
        """Reset the per-match promotion budget (``tier_promote_limit``)."""
        self._match_promoted = 0

    def promote_room(self) -> Optional[int]:
        """Pages the current match may still promote (None = unlimited).
        The matcher splits oversized host nodes at this boundary so a node
        larger than the whole limit still promotes incrementally."""
        if not self.promote_limit:
            return None
        return max(0, self.promote_limit - self._match_promoted)

    def host_can_evict(self, handle: int) -> bool:
        """Host LRU guard: entries of locked (in-use) or session-pinned
        nodes are untouchable."""
        node = self._node_of.get(handle)
        return node is None or (node.lock_ref == 0 and node.pin_ref == 0)

    def demote_node(self, node) -> bool:
        """Copy a node's device pages to the host tier and free them.

        CoW guard: only applies when the tree is the sole owner of every
        page (refcount == 1).  On success the node survives with
        ``tier == "host"`` and ``pages`` holding host handles.  Returns
        False (caller falls back to true eviction) when the export path is
        unbound, a page is still shared, or the host budget is exhausted.
        """
        pages = list(node.pages)
        if not pages or self.export_fn is None:
            return False
        if node.pin_ref > 0:
            # session-pinned context: immune to demotion too — a live
            # session's whole point is keeping its prefix hot on device
            return False
        if any(self.pool.refcount(p) != 1 for p in pages):
            return False
        # Pin the WHOLE ancestor chain, not just the victim: host.put may
        # LRU-evict a host-tier ancestor, whose _drop_subtree would reach
        # down and free this node's device pages mid-demote (double free).
        # Locks cover the whole path — same convention as match_prefix.
        chain = []
        n = node
        while n is not None:
            n.lock_ref += 1
            chain.append(n)
            n = n.parent
        try:
            # blob size per page is deterministic (pool bytes / num_pages):
            # once learned, a doomed demote is rejected BEFORE paying the
            # device→host export it would only throw away
            if self._page_nbytes is not None and not self.host.can_admit(
                    len(pages) * self._page_nbytes):
                self.demote_failures += 1
                return False
            try:
                blobs = self.export_fn(pages)
            except Exception:
                # IO fault (DESIGN.md §17): nothing was moved — the node
                # keeps its device pages and the caller falls back to
                # true eviction, so a flaky export degrades to the seed's
                # destroy-on-evict instead of crashing the pump
                self.io_errors += 1
                self.demote_failures += 1
                return False
            self._page_nbytes = blob_bytes(blobs[0])
            if not self.host.can_admit(sum(blob_bytes(b) for b in blobs)):
                # the node cannot fit (budget too small, or the remainder
                # is pinned): fail before the put loop evicts other nodes'
                # entries as collateral for a doomed demote
                self.demote_failures += 1
                return False
            handles: List[int] = []
            nbytes = 0
            for blob in blobs:
                h = self.host.put(blob, self)
                if h is None:
                    for hh in handles:
                        self._node_of.pop(hh, None)
                        self.host.free(hh)
                    self.demote_failures += 1
                    return False
                self._node_of[h] = node
                handles.append(h)
                nbytes += blob_bytes(blob)
            self.pool.decref(pages)              # device pages become free
            node.pages = handles
            node.tier = "host"
            self.demoted_pages += len(pages)
            self.demoted_bytes += nbytes
            return True
        finally:
            for n in chain:
                n.lock_ref -= 1

    def promote_node(self, node) -> bool:
        """Copy a host-tier node back into freshly allocated device pages.

        The caller must hold a lock on the node (match does), which pins
        its host entries while ``pressure_fn`` makes room on the device.
        On success the node is a normal device node again, its pages owned
        by the tree (refcount 1).  Returns False when the promote budget
        for this match is spent or the device pool stays full — the match
        then truncates (partial hit), never corrupts.
        """
        handles = list(node.pages)
        n = len(handles)
        if n == 0 or self.import_fn is None:
            return False
        if self.promote_limit and self._match_promoted + n > self.promote_limit:
            self.promote_failures += 1
            return False
        for h in handles:
            self.host.touch(h)
        pages = self.pool.alloc(n)
        if pages is None and self.pressure_fn is not None:
            self.pressure_fn(n - self.pool.free_pages)
            pages = self.pool.alloc(n)
        if pages is None:
            self.promote_failures += 1
            return False
        blobs = [self.host.get(h) for h in handles]
        try:
            self.import_fn(pages, blobs)
        except Exception:
            # IO fault: give back the device pages just allocated; the
            # host entries are untouched, so the node stays a valid
            # host-tier node and the match truncates (partial hit) —
            # the request recomputes the suffix instead of dying
            self.pool.decref(pages)
            self.io_errors += 1
            self.promote_failures += 1
            return False
        for h in handles:
            self._node_of.pop(h, None)
            self.host.free(h)
        node.pages = pages
        node.tier = "device"
        self.tier_hits += 1
        self.promoted_pages += n
        self._match_promoted += n
        self.promoted_bytes += sum(blob_bytes(b) for b in blobs)
        return True

    def retarget(self, handles: Sequence[int], node) -> None:
        """Re-own handles after a radix node split moved them to a new node."""
        for h in handles:
            if h in self._node_of:
                self._node_of[h] = node

    def _on_host_evict(self, handle: int) -> None:
        """Host LRU dropped one of our entries: the owning radix node (and
        any children — all host-tier by construction) must go with it."""
        node = self._node_of.pop(handle, None)
        if node is None:
            return
        self._drop_subtree(node)

    def _drop_subtree(self, node) -> None:
        """Destroy a radix subtree whose bytes are gone (true eviction of
        host-tier state).  Safe on mixed subtrees: device descendants give
        their pages back to the device pool.

        Never reachable for in-use state: a locked node implies a locked
        ancestor chain (match and demote both pin root→node), so
        ``host_can_evict`` refuses every entry above it — asserted here
        so a future violation fails loudly instead of double-freeing."""
        assert node.lock_ref == 0, "dropping a locked (in-use) radix node"
        assert node.pin_ref == 0, "dropping a session-pinned radix node"
        for child in list(node.children.values()):
            self._drop_subtree(child)
        if node.tier == "host":
            self.host_evicted_pages += len(node.pages)
            for h in node.pages:
                self._node_of.pop(h, None)
                self.host.free(h)       # idempotent: triggering handle gone
        elif node.pages:
            self.dropped_device_pages += len(node.pages)
            self.pool.decref(node.pages)
        if node.parent is not None:
            node.parent.children.pop(node.key[0], None)
        node.pages = []
        node.children = {}

    def stats(self) -> Dict[str, int]:
        return {
            "tier_hits": self.tier_hits,
            "demoted_pages": self.demoted_pages,
            "demoted_bytes": self.demoted_bytes,
            "promoted_pages": self.promoted_pages,
            "promoted_bytes": self.promoted_bytes,
            "host_evicted_pages": self.host_evicted_pages,
            "dropped_device_pages": self.dropped_device_pages,
            "demote_failures": self.demote_failures,
            "promote_failures": self.promote_failures,
            "tier_io_errors": self.io_errors,
        }
