"""HTTP frontend tests (DESIGN.md §15): token parity with the in-process
API, SSE streaming, session/fork routes, overload shedding (429 +
Retry-After), queueing deadlines (504), and /v1/metrics."""
import concurrent.futures
import math
import threading

import jax
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer, SamplingParams
from repro.serving.frontend import ForkClient, HttpError, HttpFrontend


@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def make_server(model, **kw):
    cfg, params, lora = model
    base = dict(page_size=16, max_pages=256, max_batch=4,
                max_prefill_tokens=64, mode="forkkv", max_pages_per_req=12)
    base.update(kw)
    return ForkServer(cfg, params, lora, ServeConfig(**base)), cfg


@pytest.fixture(scope="module")
def frontend(model):
    server, cfg = make_server(model)
    fe = HttpFrontend(server).start_background()
    yield fe, ForkClient(port=fe.port), cfg
    fe.shutdown()


def prompt_tokens(cfg, n, seed=0):
    return [int(t) for t in
            np.random.default_rng(seed).integers(0, cfg.vocab_size, n)]


def test_healthz_and_metrics(frontend):
    _, client, _ = frontend
    assert client.healthz()
    m = client.metrics()
    for key in ("admission", "queue_depth", "admission_wait_p50_ms",
                "admission_wait_p99_ms", "timeouts", "shed", "tenants",
                "fallback_gather_calls", "http_sessions"):
        assert key in m, key


def test_http_parity_with_in_process(frontend, model):
    """Acceptance: greedy tokens over HTTP == the in-process API, with
    zero gather fallbacks."""
    fe, client, cfg = frontend
    prompt = prompt_tokens(cfg, 40, seed=7)
    doc = client.completion(prompt, max_new_tokens=8, adapter_id=2)
    assert doc["finish_reason"] == "length" and len(doc["tokens"]) == 8

    ref_server, _ = make_server(model)
    expected = ref_server.generate(
        2, prompt, SamplingParams(max_new_tokens=8)).result().tokens
    assert doc["tokens"] == expected
    assert client.metrics()["fallback_gather_calls"] == 0


def test_sse_stream_matches_terminal_event(frontend):
    # one streamed request: the per-token SSE events must agree with the
    # terminal event's token list exactly (fresh prompt — replaying an
    # identical prompt continues from the cached suffix by design)
    _, client, cfg = frontend
    prompt = prompt_tokens(cfg, 32, seed=11)
    events = list(client.stream_completion(prompt, max_new_tokens=6))
    streamed = [e["token"] for e in events if not e.get("finished")]
    final = events[-1]
    assert final["finished"] and final["finish_reason"] == "length"
    assert streamed == final["tokens"] and len(streamed) == 6
    assert [e["index"] for e in events[:-1]] == list(range(6))


def test_session_fork_routes(frontend, model):
    """Forked agents over HTTP share the pinned context (CoW) and match
    the in-process session API token-for-token."""
    _, client, cfg = frontend
    ctx = prompt_tokens(cfg, 48, seed=3)
    sid = client.create_session(ctx, adapter_id=1)
    via_http = client.fork(sid, [5, 6, 7], max_new_tokens=5)["tokens"]
    sibling = client.fork(sid, [5, 6, 8], max_new_tokens=5)["tokens"]

    ref_server, _ = make_server(model)
    sess = ref_server.session(ctx, adapter_id=1)
    expected = sess.fork(1, [5, 6, 7],
                         SamplingParams(max_new_tokens=5)).result().tokens
    assert via_http == expected
    assert via_http != sibling or ctx[:1]  # siblings diverge on last token
    client.close_session(sid)
    with pytest.raises(HttpError) as ei:
        client.fork(sid, [1, 2])
    assert ei.value.status == 404


def test_shedding_returns_429_with_retry_after(model):
    """Overload: queue bound 1, batch 1 — a burst must shed with 429 and
    a Retry-After hint while admitted requests still finish."""
    server, cfg = make_server(model, max_batch=1, max_queue_depth=1)
    fe = HttpFrontend(server).start_background()
    client = ForkClient(port=fe.port)
    prompt = prompt_tokens(cfg, 40, seed=1)

    def one(i):
        try:
            return ("ok", client.completion(prompt[:32 + i],
                                            max_new_tokens=4))
        except HttpError as exc:
            return ("err", exc)

    try:
        with concurrent.futures.ThreadPoolExecutor(8) as pool:
            results = list(pool.map(one, range(8)))
        oks = [r for kind, r in results if kind == "ok"]
        errs = [r for kind, r in results if kind == "err"]
        assert oks, "at least one request must be admitted and finish"
        assert all(len(d["tokens"]) == 4 for d in oks)
        shed = [e for e in errs if e.status == 429]
        assert shed, f"burst of 8 over bound 1 must shed ({results})"
        for e in shed:
            # RFC 9110 Retry-After is integer seconds; the header is the
            # CEIL of the engine hint with a floor of 1 — round() turned
            # sub-0.5 s hints into "0" (retry immediately, hammering an
            # already-overloaded server)
            hdr = e.headers["retry-after"]
            assert hdr == str(int(hdr)), "must be integer seconds"
            assert int(hdr) == max(1, math.ceil(e.doc["retry_after_s"]))
            assert e.doc["finish_reason"] == "rejected"
        assert client.metrics()["shed"] == len(shed)
    finally:
        fe.shutdown()


def test_deadline_returns_504(model):
    """A queued request whose deadline lapses before admission finishes
    with 504, while the running request is unaffected."""
    server, cfg = make_server(model, max_batch=1)
    fe = HttpFrontend(server).start_background()
    client = ForkClient(port=fe.port)
    prompt = prompt_tokens(cfg, 40, seed=2)
    try:
        blocker = threading.Thread(
            target=lambda: client.completion(prompt, max_new_tokens=8))
        blocker.start()
        statuses = []
        # keep poking until one lands while the blocker occupies the
        # batch slot (the first may sneak in before the blocker)
        for _ in range(4):
            try:
                client.completion(prompt[:36], max_new_tokens=4,
                                  deadline_s=1e-3)
                statuses.append(200)
            except HttpError as exc:
                statuses.append(exc.status)
            if 504 in statuses:
                break
        blocker.join()
        assert 504 in statuses, statuses
        assert client.metrics()["timeouts"] >= 1
    finally:
        fe.shutdown()


def test_bad_requests_are_4xx(frontend):
    _, client, _ = frontend
    with pytest.raises(HttpError) as ei:
        client.completion(["not", "ints"])
    assert ei.value.status == 400
    with pytest.raises(HttpError) as ei:
        client.fork("missing", [1, 2, 3])
    assert ei.value.status == 404


def test_malformed_json_body_is_400(frontend):
    """Regression (§17 satellite): a syntactically broken JSON body must
    come back 400 with an error document — not a 500 or a dropped
    connection."""
    import http.client
    import json as json_mod
    fe, _, _ = frontend
    for raw in (b"{not json", b'{"prompt": [1,2,', b"\xff\xfe\x00"):
        conn = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=30)
        try:
            conn.request("POST", "/v1/completions", body=raw,
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            doc = json_mod.loads(resp.read())
            assert resp.status == 400, raw
            assert "error" in doc
        finally:
            conn.close()


def test_unknown_sampling_keys_are_400(frontend):
    """Regression (§17 satellite): a typoed sampling key is refused with
    400 naming the key, instead of being silently dropped into greedy
    defaults."""
    _, client, cfg = frontend
    prompt = prompt_tokens(cfg, 24, seed=13)
    with pytest.raises(HttpError) as ei:
        client.completion(prompt, max_new_tokens=4, temprature=0.7)
    assert ei.value.status == 400
    assert "temprature" in ei.value.doc["error"]
    with pytest.raises(HttpError) as ei:
        client.completion(prompt, max_new_tokens=4, top_K=5, banana=1)
    assert ei.value.status == 400
    # valid keys still pass
    doc = client.completion(prompt, max_new_tokens=3, temperature=0.0,
                            top_k=0, top_p=1.0, seed=0)
    assert len(doc["tokens"]) == 3


def test_fairshare_light_tenant_not_starved(model):
    """Acceptance (engine+HTTP integration): with fair share, a light
    tenant's request admitted behind a hog burst must not wait for the
    hog's whole backlog."""
    server, cfg = make_server(model, admission="fairshare", max_batch=2,
                              tenant_max_concurrent=1)
    fe = HttpFrontend(server).start_background()
    client = ForkClient(port=fe.port)
    prompt = prompt_tokens(cfg, 32, seed=5)

    def hog(i):
        try:
            return client.completion(prompt[:24 + i], max_new_tokens=4,
                                     tenant="hog")
        except HttpError:
            return None

    try:
        with concurrent.futures.ThreadPoolExecutor(7) as pool:
            hogs = [pool.submit(hog, i) for i in range(6)]
            light = pool.submit(
                lambda: client.completion(prompt, max_new_tokens=4,
                                          tenant="light"))
            light_doc = light.result()
            assert len(light_doc["tokens"]) == 4
            [f.result() for f in hogs]
        tenants = client.metrics()["tenants"]
        assert tenants["light"]["accepted"] == 1
        assert tenants["hog"]["accepted"] >= 1
    finally:
        fe.shutdown()
