"""Uniform model API over the six architecture families.

``get_model(cfg)`` returns a :class:`ModelApi` with
init_params / logical_axes / forward / init_cache / prefill / decode_step /
init_lora_stacks, dispatched on ``cfg.family``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.config import ModelConfig
from repro.models import encdec, hybrid, ssm
from repro.models import transformer as tfm


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init_params: Callable
    logical_axes: Callable
    forward: Callable
    init_cache: Callable
    cache_logical_axes: Callable
    prefill: Callable
    decode_step: Callable
    init_lora_stacks: Optional[Callable]
    lora_logical_axes: Optional[Callable]
    supports_forkkv: bool      # does the family have a LoRA'd KV cache?


def get_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = tfm
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: mod.init_params(cfg, key),
            logical_axes=lambda: mod.logical_axes(cfg),
            forward=lambda params, tokens, **kw: mod.forward(
                params, tokens, cfg, **kw),
            init_cache=lambda batch, max_len, **kw: mod.init_cache(
                cfg, batch, max_len, **kw),
            cache_logical_axes=lambda **kw: mod.cache_logical_axes(cfg, **kw),
            prefill=lambda params, tokens, cache, **kw: mod.prefill(
                params, tokens, cache, cfg, **kw),
            decode_step=lambda params, tokens, cache, kv_len, **kw:
                mod.decode_step(params, tokens, cache, kv_len, cfg, **kw),
            init_lora_stacks=lambda key, n, **kw: mod.init_lora_stacks(
                cfg, key, n, **kw),
            lora_logical_axes=lambda: mod.lora_logical_axes(),
            supports_forkkv=True)
    if fam == "ssm":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: ssm.init_params(cfg, key),
            logical_axes=lambda: ssm.logical_axes(cfg),
            forward=lambda params, tokens, **kw: ssm.forward(
                params, tokens, cfg, **kw),
            init_cache=lambda batch, max_len, **kw: ssm.init_cache(
                cfg, batch, max_len, **kw),
            cache_logical_axes=lambda **kw: ssm.cache_logical_axes(cfg, **kw),
            prefill=lambda params, tokens, cache, **kw: ssm.prefill(
                params, tokens, cache, cfg, **kw),
            decode_step=lambda params, tokens, cache, kv_len, **kw:
                ssm.decode_step(params, tokens, cache, kv_len, cfg, **kw),
            init_lora_stacks=None,
            lora_logical_axes=None,
            supports_forkkv=False)    # attention-free: ForkKV N/A
    if fam == "hybrid":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: hybrid.init_params(cfg, key),
            logical_axes=lambda: hybrid.logical_axes(cfg),
            forward=lambda params, tokens, **kw: hybrid.forward(
                params, tokens, cfg, **kw),
            init_cache=lambda batch, max_len, **kw: hybrid.init_cache(
                cfg, batch, max_len, **kw),
            cache_logical_axes=lambda **kw: hybrid.cache_logical_axes(
                cfg, **kw),
            prefill=lambda params, tokens, cache, **kw: hybrid.prefill(
                params, tokens, cache, cfg, **kw),
            decode_step=lambda params, tokens, cache, kv_len, **kw:
                hybrid.decode_step(params, tokens, cache, kv_len, cfg, **kw),
            init_lora_stacks=lambda key, n, **kw: hybrid.init_lora_stacks(
                cfg, key, n, **kw),
            lora_logical_axes=lambda: tfm.lora_logical_axes(),
            supports_forkkv=True)     # on the local-attention layers
    if fam == "audio":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: encdec.init_params(cfg, key),
            logical_axes=lambda: encdec.logical_axes(cfg),
            forward=lambda params, tokens, **kw: encdec.forward(
                params, tokens, cfg, **kw),
            init_cache=lambda batch, max_len, **kw: encdec.init_cache(
                cfg, batch, max_len, **kw),
            cache_logical_axes=lambda **kw: encdec.cache_logical_axes(
                cfg, **kw),
            prefill=lambda params, tokens, cache, **kw: encdec.prefill(
                params, tokens, cache, cfg, **kw),
            decode_step=lambda params, tokens, cache, kv_len, **kw:
                encdec.decode_step(params, tokens, cache, kv_len, cfg, **kw),
            init_lora_stacks=lambda key, n, **kw: tfm.init_lora_stacks(
                cfg, key, n, **kw),
            lora_logical_axes=lambda: tfm.lora_logical_axes(),
            supports_forkkv=True)     # decoder self-attention
    raise ValueError(f"unknown family {fam!r}")
