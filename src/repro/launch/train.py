"""Training launcher.

CPU-scale real run:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --tiny --steps 50 --batch 8 --seq 128

Production mesh dry-run of the same step is `repro.launch.dryrun`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.models.registry import get_model
from repro.training import checkpoint, data, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(cfg_lib.ARCH_IDS))
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced smoke-test variant (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = cfg_lib.get_tiny_config(args.arch) if args.tiny \
        else cfg_lib.get_config(args.arch)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    init_opt, step = train_loop.make_train_step(cfg, lr=args.lr)
    opt = init_opt(params)
    jstep = jax.jit(step)
    stream = data.make_stream(cfg.vocab_size, args.seq, args.batch)

    extra = None
    if cfg.frontend == "vision_stub":
        extra = jnp.zeros((args.batch, min(cfg.num_patches, 8), cfg.d_model),
                          cfg.activation_dtype)
    if cfg.frontend == "audio_stub":
        extra = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                          cfg.activation_dtype)

    t0 = time.time()
    for i, batch in zip(range(args.steps), stream):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if extra is not None:
            b["extra_embeds"] = extra
            if cfg.frontend == "vision_stub":
                b["tokens"] = b["tokens"]
                b["labels"] = b["labels"]
        params, opt, m = jstep(params, opt, b)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        path = checkpoint.save(params, args.ckpt_dir, f"{cfg.name}-final")
        print(f"saved {path}")


if __name__ == "__main__":
    main()
