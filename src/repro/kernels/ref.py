"""Pure-jnp oracle for ResidualAttention (paper §5.3, Algorithm 1).

Computes attention over a *disaggregated* KV cache:

    K = K_base + RoPE(K_res @ B_k)
    V = V_base + V_res @ B_v
    O = softmax(Q K^T / sqrt(d)) V

The kernel implements this with on-chip reconstruction and a dual
accumulator; the oracle materializes everything, which is exactly the
"naive HBM reconstruction" the paper argues against — perfect as a
correctness reference.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import attention as attn_lib
from repro.core import rope as rope_lib


def reconstruct(k_base, v_base, k_res, v_res, b_k, b_v, sin, cos):
    """Materialize full K, V from disaggregated parts.

    k_base/v_base: (B, Sk, Hkv, D); k_res/v_res: (B, Sk, R)
    b_k/b_v: (B, R, Hkv*D) per-request adapter up-projections
    sin/cos: (B, Sk, D//2)
    """
    bsz, sk, hkv, d = k_base.shape
    k_lora = jnp.einsum("bsr,brn->bsn", k_res.astype(jnp.float32),
                        b_k.astype(jnp.float32)).reshape(bsz, sk, hkv, d)
    k_lora = rope_lib.apply_rope(k_lora, sin, cos)
    v_lora = jnp.einsum("bsr,brn->bsn", v_res.astype(jnp.float32),
                        b_v.astype(jnp.float32)).reshape(bsz, sk, hkv, d)
    k = k_base.astype(jnp.float32) + k_lora
    v = v_base.astype(jnp.float32) + v_lora
    return k.astype(k_base.dtype), v.astype(v_base.dtype)


def residual_attention_ref(q, k_base, v_base, k_res, v_res, b_k, b_v,
                           sin, cos, *, qpos: jnp.ndarray,
                           kv_len: Optional[jnp.ndarray] = None,
                           window: int = 0, causal: bool = True,
                           scale: Optional[float] = None) -> jnp.ndarray:
    """Reference residual attention.

    q: (B, Sq, Hq, D) — RoPE already applied (queries are computed fresh).
    qpos: (B, Sq) absolute positions of the query rows.
    kv_len: (B,) valid cache lengths (<= Sk).
    Returns (B, Sq, Hq, D).
    """
    k, v = reconstruct(k_base, v_base, k_res, v_res, b_k, b_v, sin, cos)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    s = attn_lib._gqa_scores(q, k) * scale          # (B, Hq, Sq, Sk)
    kpos = jnp.arange(k.shape[1])[None, None, None, :]
    qp = qpos[:, None, :, None]
    mask = jnp.ones(s.shape, dtype=bool)
    if causal:
        mask &= kpos <= qp
    if window > 0:
        mask &= kpos > qp - window
    if kv_len is not None:
        mask &= kpos < kv_len[:, None, None, None]
    s = jnp.where(mask, s, attn_lib.NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return attn_lib._gqa_out(p, v).astype(q.dtype)
