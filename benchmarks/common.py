"""Shared benchmark utilities: ForkServer runner + CSV emission.

Benchmarks run purely through the session/fork API (``repro.serving.api``)
— no ``Request`` construction or ``engine.step()`` loops outside
``src/repro/serving``.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import jax

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.workflows import WorkflowConfig, WorkflowDriver

_MODEL_CACHE: Dict = {}


def get_tiny_model(rank: int = 8, n_adapters: int = 32):
    key = (rank, n_adapters)
    if key not in _MODEL_CACHE:
        cfg = tiny_serving_model(rank=rank)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1),
                                    n_adapters=n_adapters)
        _MODEL_CACHE[key] = (cfg, params, lora)
    return _MODEL_CACHE[key]


def build_server(mode: str, *, rank: int = 8, max_pages: int = 256,
                 max_batch: int = 8, max_pages_per_req: int = 48,
                 host_tier_bytes: int = 0, kv_codec: str = "identity",
                 disk_tier_bytes: int = 0, persist_dir: str = ""):
    """ForkServer with the full tiering surface (DESIGN.md §18) exposed —
    codec, disk tier and persist dir — for benchmarks that restart the
    server or sweep codecs."""
    cfg, params, lora = get_tiny_model(rank=rank)
    sc = ServeConfig(page_size=16, max_pages=max_pages, max_batch=max_batch,
                     max_prefill_tokens=128, mode=mode,
                     max_pages_per_req=max_pages_per_req,
                     host_tier_bytes=host_tier_bytes, kv_codec=kv_codec,
                     disk_tier_bytes=disk_tier_bytes,
                     persist_dir=persist_dir)
    return ForkServer(cfg, params, lora, sc)


def run_workflow(mode: str, workflow: str = "react", *, rank: int = 8,
                 n_workflows: int = 2, agents: int = 3, context: int = 256,
                 max_new: int = 8, max_pages: int = 256,
                 max_batch: int = 8, seed: int = 0, rounds: int = 1,
                 max_pages_per_req: int = 48,
                 host_tier_bytes: int = 0, instr_len: int = 24,
                 tool_obs_len: int = 50, kv_codec: str = "identity",
                 disk_tier_bytes: int = 0, persist_dir: str = "",
                 server=None) -> Dict:
    cfg, _, _ = get_tiny_model(rank=rank)
    if server is None:
        server = build_server(mode, rank=rank, max_pages=max_pages,
                              max_batch=max_batch,
                              max_pages_per_req=max_pages_per_req,
                              host_tier_bytes=host_tier_bytes,
                              kv_codec=kv_codec,
                              disk_tier_bytes=disk_tier_bytes,
                              persist_dir=persist_dir)
    wf = WorkflowConfig(n_workflows=n_workflows, agents_per_workflow=agents,
                        shared_context_len=context, max_new_tokens=max_new,
                        vocab=cfg.vocab_size, seed=seed, rounds=rounds,
                        instr_len=instr_len, tool_obs_len=tool_obs_len)
    driver = WorkflowDriver(server, wf)
    return driver.run_react() if workflow == "react" \
        else driver.run_mapreduce()


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row in the required ``name,us_per_call,derived`` format."""
    print(f"{name},{us_per_call:.1f},{derived}")
