"""Optimizers: AdamW (fp32 moments) and Adafactor (factored second moments).

llama3-405b uses Adafactor in this repo — AdamW's 12 bytes/param does not fit
the 512×16GB v5e footprint at our sharding (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1):
    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), {"m": zeros, "v": zeros})

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** t)
            vhat = v2 / (1 - b2 ** t)
            delta = mhat / (jnp.sqrt(vhat) + eps) + \
                weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

        out = jax.tree_util.tree_map(upd, grads, state.inner["m"],
                                     state.inner["v"], params)
        new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, {"m": new_m, "v": new_v})

    return init, update


def adafactor(lr: float = 1e-3, eps: float = 1e-30, decay: float = 0.8,
              clip_threshold: float = 1.0):
    """Factored Adafactor for >=2D params, full second moment for 1D."""
    def init(params):
        def per_param(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree_util.tree_map(per_param, params,
                                               is_leaf=None))

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                row_factor = jax.lax.rsqrt(vr / denom)        # same shape as vr
                u = g * row_factor[..., None] \
                    * jax.lax.rsqrt(vc[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.inner)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_s = treedef.unflatten([o[1] for o in outs])
        return new_p, OptState(step, new_s)

    return init, update


def get_optimizer(name: str, lr: float = 3e-4):
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    raise ValueError(name)


def opt_state_logical_axes(name: str, param_axes):
    """Logical axes for optimizer state, mirroring the param axes."""
    if name == "adamw":
        return {"m": param_axes, "v": param_axes}

    def per_param(ax):
        ax = tuple(ax) if ax is not None else None
        if ax is None:
            return {"v": None}
        if len(ax) >= 2:
            return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
        return {"v": ax}

    return jax.tree_util.tree_map(
        per_param, param_axes,
        is_leaf=lambda x: isinstance(x, tuple) or x is None)
