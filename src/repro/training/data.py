"""Synthetic, deterministic, shardable data pipeline.

No datasets ship offline, so the pipeline synthesizes token streams with a
fixed PRNG — deterministic per (seed, step, shard), which makes multi-host
sharding trivial: every host computes only its shard of the global batch.
Structure (Zipfian ids + repeated n-grams) gives the LoRA fine-tune examples
something learnable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    # task flavour for LoRA fine-tuning: each "adapter id" gets its own
    # deterministic mapping so adapters learn distinguishable behaviour.
    task_id: int = 0


class SyntheticStream:
    """Iterator of {tokens, labels} batches (next-token prediction)."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self._step = 0

    def _batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.shard_index)
        v = cfg.vocab_size
        # zipfian base stream
        ranks = rng.zipf(cfg.zipf_a, size=(self.local_batch, cfg.seq_len + 1))
        toks = (ranks + cfg.task_id * 7919) % v
        # inject learnable bigram structure: token after marker M is f(M)
        marker = (13 + cfg.task_id) % v
        is_marker = toks[:, :-1] == marker
        follow = (marker * 31 + 7) % v
        toks[:, 1:][is_marker] = follow
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self._batch(self._step)
        self._step += 1
        return b


def make_stream(vocab_size: int, seq_len: int, global_batch: int,
                seed: int = 0, task_id: int = 0, shard_index: int = 0,
                num_shards: int = 1) -> SyntheticStream:
    return SyntheticStream(
        DataConfig(vocab_size=vocab_size, seq_len=seq_len,
                   global_batch=global_batch, seed=seed, task_id=task_id),
        shard_index=shard_index, num_shards=num_shards)
