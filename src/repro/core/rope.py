"""Rotary position embeddings with support for *deferred* application.

ForkKV stores the base Key cache with RoPE already applied and the residual
cache *without* RoPE (the rank-r output dimension of ``xA_i`` mismatches the
rotation matrix).  RoPE is a per-position linear map, so
``RoPE(K_base + K_lora) == RoPE(K_base) + RoPE(K_lora)`` — applying it to the
up-projected residual at reconstruction time (paper Alg. 1, line 8) is exact.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_sincos(positions: jnp.ndarray, head_dim: int, theta: float = 10_000.0,
                dtype=jnp.float32):
    """Return (sin, cos) tables of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles).astype(dtype), jnp.cos(angles).astype(dtype)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """Rotate ``x`` (..., seq, heads, head_dim) by per-position (sin, cos).

    ``sin``/``cos`` have shape (..., seq, head_dim//2) and broadcast over the
    heads axis.  Uses the "split-half" convention (first/second half pairs),
    matching Llama-family checkpoints.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]   # broadcast over heads
    cos = cos[..., None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def apply_rope_flat(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """Same as :func:`apply_rope` but for (..., seq, head_dim) (no heads axis)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
