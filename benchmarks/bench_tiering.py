"""Tiered KV offload benchmark (DESIGN.md §10/§18).

ReAct under device-memory pressure — the device page budget barely covers
one request's footprint, so the seed engine's destroy-on-evict forces
re-prefills.  Three row groups on the identical workload:

  * ``tier_off`` / ``tier_on`` — the original §10 comparison:
    ``prefilled_tokens`` drops and ``tier_hits`` appear when demoted
    pages are promoted instead of recomputed;
  * ``codec_<name>`` — identity/int8/zstd on the demote path (§18): the
    achieved ``compression_ratio`` (logical/stored host bytes) against
    the tier hits the workload still gets;
  * ``persist`` — persist the hot trees, build a FRESH server on the
    same directory, restore, and re-run: ``restored_pages`` and the
    warm run's prefill savings measure restart-rehydration.

``--codec`` limits the codec sweep; ``--persist-dir`` reuses a directory
across invocations (default: a throwaway temp dir per run).
"""
from __future__ import annotations

import argparse
import tempfile
import time

from benchmarks.common import build_server, emit, run_workflow

# device budget of 26 pages vs a working set of ~6 live agent contexts;
# rounds=2 lets each adapter re-fork its grown context (the reuse the
# host tier preserves across evictions).
_PRESSURE = dict(n_workflows=3, agents=2, rounds=2, context=256,
                 max_new=4, max_pages=26, max_pages_per_req=24,
                 max_batch=4, instr_len=16, tool_obs_len=24)
_SERVER = dict(max_pages=26, max_pages_per_req=24, max_batch=4)


def _tier_rows() -> None:
    for label, host_bytes in (("off", 0), ("on", 64 << 20)):
        t0 = time.time()
        m = run_workflow("forkkv", "react", host_tier_bytes=host_bytes,
                         **_PRESSURE)
        wall_us = (time.time() - t0) * 1e6
        emit(f"tiering.react.tier_{label}.prefilled_tokens", wall_us,
             f"{m['prefilled_tokens']}")
        emit(f"tiering.react.tier_{label}.prefill_saved_frac", wall_us,
             f"{m['prefill_saved_frac']:.4f}")
        emit(f"tiering.react.tier_{label}.tier_hits", 0,
             f"{m['tier_hits']}")
        emit(f"tiering.react.tier_{label}.demoted_pages", 0,
             f"{m['demoted_pages']}")
        emit(f"tiering.react.tier_{label}.evicted_pages", 0,
             f"{m['evicted_pages']}")
        emit(f"tiering.react.tier_{label}.promoted_bytes", 0,
             f"{m['promoted_bytes']}")
        emit(f"tiering.react.tier_{label}.preemptions", 0,
             f"{m['preemptions']}")


def _codec_rows(codecs) -> None:
    for codec in codecs:
        t0 = time.time()
        m = run_workflow("forkkv", "react", host_tier_bytes=64 << 20,
                         kv_codec=codec, **_PRESSURE)
        wall_us = (time.time() - t0) * 1e6
        emit(f"tiering.react.codec_{codec}.compression_ratio", wall_us,
             f"{m['compression_ratio']:.4f}")
        emit(f"tiering.react.codec_{codec}.host_compressed_bytes", 0,
             f"{m['host_compressed_bytes']}")
        emit(f"tiering.react.codec_{codec}.codec_stored_bytes", 0,
             f"{m['codec_stored_bytes']}")
        emit(f"tiering.react.codec_{codec}.tier_hits", 0,
             f"{m['tier_hits']}")
        emit(f"tiering.react.codec_{codec}.prefill_saved_frac", 0,
             f"{m['prefill_saved_frac']:.4f}")


def _persist_rows(persist_dir: str) -> None:
    common = dict(host_tier_bytes=64 << 20, persist_dir=persist_dir,
                  kv_codec="zstd")
    cold_server = build_server("forkkv", **_SERVER, **common)
    t0 = time.time()
    cold = run_workflow("forkkv", "react", server=cold_server, **_PRESSURE)
    cold_us = (time.time() - t0) * 1e6
    persisted = cold_server.engine.persist()
    # a FRESH server on the same directory: rehydrate, then the identical
    # workload — restored context serves as tier hits, not re-prefill
    warm_server = build_server("forkkv", **_SERVER, **common)
    restored = warm_server.engine.restore()
    t0 = time.time()
    warm = run_workflow("forkkv", "react", server=warm_server, **_PRESSURE)
    warm_us = (time.time() - t0) * 1e6
    emit("tiering.react.persist.pages_persisted", cold_us, f"{persisted}")
    emit("tiering.react.persist.pages_restored", 0, f"{restored}")
    emit("tiering.react.persist.cold_prefilled_tokens", cold_us,
         f"{cold['prefilled_tokens']}")
    emit("tiering.react.persist.warm_prefilled_tokens", warm_us,
         f"{warm['prefilled_tokens']}")
    emit("tiering.react.persist.warm_tier_hits", 0, f"{warm['tier_hits']}")
    emit("tiering.react.persist.warm_prefill_saved_frac", 0,
         f"{warm['prefill_saved_frac']:.4f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--codec", choices=["identity", "int8", "zstd", "all"],
                    default="all", help="codec sweep selection")
    ap.add_argument("--persist-dir", default="",
                    help="persist/restore directory (default: temp dir)")
    args = ap.parse_args([] if argv is None else argv)
    _tier_rows()
    codecs = (["identity", "int8", "zstd"] if args.codec == "all"
              else [args.codec])
    _codec_rows(codecs)
    pdir = args.persist_dir or tempfile.mkdtemp(prefix="forkkv-bench-")
    _persist_rows(pdir)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
