"""Multi-tenant admission control: fair-share scheduling + overload
shedding (DESIGN.md §15).

The engine's admission loop was FIFO: ``waiting[0]`` or nothing.  That is
fine for one cooperative caller, but the HTTP frontend
(:mod:`repro.serving.frontend`) turns the engine into a shared service —
and with FIFO a single tenant flooding requests owns every batch slot
while everyone else queues behind its backlog.  This module makes
admission a pluggable policy object:

  * :class:`FIFOAdmission` — the seed behaviour, bit-compatible: strict
    arrival order, stop at the first request that does not fit.
  * :class:`FairShareAdmission` — weighted fair queuing across tenants
    with an SRPT bias, aging, per-tenant budgets and a prefix-hit
    discount.

Admission score (lower = admitted sooner)::

    vtime_t  = service_t / weight_t          # WFQ virtual service
    miss_r   = len(prompt) * (1 - hit_prob)  # expected prefill compute
    cost_r   = miss_r + max_new_tokens       # SRPT proxy (total compute)
    score_r  = vtime_t + srpt_weight * cost_r - aging_rate * wait_s

``vtime_t`` is the tenant's admitted compute divided by its weight — the
classic WFQ virtual clock, so a tenant that has consumed little service
wins ties regardless of arrival order.  ``cost_r`` biases toward short
requests (SRPT keeps mean latency low), and the prefix-hit probe
(``hit_prob`` from a radix ``match_prefix`` walk) recognises that a
request landing on warm cache is cheaper than its token count suggests —
admit it sooner.  ``aging_rate`` (cost-tokens of credit per waiting
second) bounds starvation: any request's score eventually goes negative,
so a long job cannot be SRPT-starved forever.

Budgets gate a tenant out of ``select()`` entirely (its requests keep
waiting, other tenants proceed): concurrent admitted requests
(``tenant_max_concurrent``), tokens in flight — prompt + max_new of
admitted, unfinished requests — (``tenant_max_tokens_in_flight``), and
pinned device pages held by the tenant's live sessions
(``tenant_max_pinned_pages``, probed via a callback so the policy stays
pool-agnostic).

Overload shedding is explicit, not emergent: once ``max_queue_depth`` or
``max_queue_wait_s`` is exceeded, ``shed()`` names victims — worst score
first for fair share, newest first for FIFO — and the engine finishes
them with ``finish_reason="rejected"`` and a retry-after hint the HTTP
layer surfaces as ``429`` + ``Retry-After``.  Shedding is deterministic:
same queue, same clock, same victims.

Pure control plane: no jax, no pools — unit-testable without a model
(``tests/test_fairshare.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.config import ServeConfig

__all__ = ["AdmissionPolicy", "FIFOAdmission", "FairShareAdmission",
           "TenantState", "make_policy"]


@dataclasses.dataclass
class TenantState:
    """Per-tenant accounting the fair-share score reads."""

    weight: float = 1.0
    service: float = 0.0          # admitted cost-tokens (WFQ service)
    concurrent: int = 0           # admitted, unfinished requests
    tokens_in_flight: int = 0     # prompt + max_new of those requests
    accepted: int = 0
    rejected: int = 0             # shed / impossible
    timeouts: int = 0             # deadline expiries while waiting

    @property
    def vtime(self) -> float:
        return self.service / max(self.weight, 1e-9)


class AdmissionPolicy:
    """Admission-order + overload-shedding interface (DESIGN.md §15).

    The engine calls, per step: :meth:`shed` (victims to reject),
    :meth:`select` repeatedly (next request to try admitting; ``None``
    ends the admission loop), then :meth:`on_admit` /
    :meth:`on_finish` / :meth:`on_reject` as lifecycle notifications.
    Policies never mutate the queue — the engine owns request state.
    """

    name = "base"

    def __init__(self, sc: ServeConfig,
                 probe_hit: Optional[Callable[[Any], float]] = None,
                 pinned_pages: Optional[Callable[[str], int]] = None):
        self.sc = sc
        self._probe_hit = probe_hit or (lambda req: 0.0)
        self._pinned_pages = pinned_pages or (lambda tenant: 0)
        self.tenants: Dict[str, TenantState] = {}
        self._weights = dict(sc.tenant_weights)
        self._hit_cache: Dict[int, float] = {}

    # ------------------------------------------------------------ helpers
    def tenant(self, name: str) -> TenantState:
        st = self.tenants.get(name)
        if st is None:
            st = TenantState(weight=float(self._weights.get(name, 1.0)))
            self.tenants[name] = st
        return st

    def hit_prob(self, req) -> float:
        """Prefix-hit probability for ``req``, probed once and cached —
        the radix walk is cheap but not free, and the fraction only
        changes while the request waits if OTHER traffic warms its
        prefix (a staleness we accept)."""
        p = self._hit_cache.get(req.rid)
        if p is None:
            p = min(1.0, max(0.0, float(self._probe_hit(req))))
            self._hit_cache[req.rid] = p
        return p

    def cost(self, req) -> float:
        """Expected compute in tokens: prefill the radix cache will not
        cover, plus the decode budget."""
        miss = len(req.prompt) * (1.0 - self.hit_prob(req))
        return miss + req.max_new_tokens

    def over_budget(self, tenant: str) -> bool:
        sc, st = self.sc, self.tenant(tenant)
        if sc.tenant_max_concurrent > 0 and \
                st.concurrent >= sc.tenant_max_concurrent:
            return True
        if sc.tenant_max_tokens_in_flight > 0 and \
                st.tokens_in_flight >= sc.tenant_max_tokens_in_flight:
            return True
        if sc.tenant_max_pinned_pages > 0 and \
                self._pinned_pages(tenant) > sc.tenant_max_pinned_pages:
            return True
        return False

    # ---------------------------------------------------------- interface
    def select(self, waiting: Sequence[Any], now: float) -> Optional[Any]:
        raise NotImplementedError

    def shed(self, waiting: Sequence[Any],
             now: float) -> List[Tuple[Any, float]]:
        """Victims to reject as ``(request, retry_after_s)``, computed
        against the configured queue-depth and wait-time bounds.  The
        base rule is shared; subclasses define victim ORDER via
        :meth:`_shed_order`."""
        sc = self.sc
        victims: List[Tuple[Any, float]] = []
        shed_set = set()
        if sc.max_queue_wait_s > 0:
            for req in waiting:
                if now - req.arrival > sc.max_queue_wait_s:
                    victims.append((req, self._retry_after(len(waiting))))
                    shed_set.add(req.rid)
        if sc.max_queue_depth > 0:
            depth = len(waiting) - len(shed_set)
            if depth > sc.max_queue_depth:
                for req in self._shed_order(waiting, now):
                    if req.rid in shed_set:
                        continue
                    victims.append((req, self._retry_after(depth)))
                    shed_set.add(req.rid)
                    depth -= 1
                    if depth <= sc.max_queue_depth:
                        break
        return victims

    def _shed_order(self, waiting: Sequence[Any],
                    now: float) -> List[Any]:
        """Depth-bound victim preference; FIFO sheds newest first."""
        return sorted(waiting, key=lambda r: r.arrival, reverse=True)

    def _retry_after(self, depth: int) -> float:
        """Deterministic backoff hint: half a second per queued request
        beyond the bound, floored at 1s."""
        excess = max(0, depth - max(self.sc.max_queue_depth, 0))
        return max(1.0, 0.5 * excess)

    # --------------------------------------------------------- lifecycle
    def on_admit(self, req, now: float) -> None:
        st = self.tenant(req.tenant)
        st.concurrent += 1
        st.tokens_in_flight += len(req.prompt) + req.max_new_tokens
        st.accepted += 1
        st.service += self.cost(req)
        self._hit_cache.pop(req.rid, None)

    def on_finish(self, req, now: float) -> None:
        """An ADMITTED request finished (any reason)."""
        st = self.tenant(req.tenant)
        st.concurrent = max(0, st.concurrent - 1)
        st.tokens_in_flight = max(
            0, st.tokens_in_flight - (len(req.prompt) + req.max_new_tokens))
        # settle decode billing against tokens ACTUALLY generated: admission
        # charged the full max_new budget up front; a stop-token finish (or
        # a speculative run whose rejected drafts were never committed)
        # generated fewer.  Proposed-but-rejected draft tokens are never
        # billed — only the committed stream counts as service.
        out = getattr(req, "output", None)
        if out is not None:
            gen = max(0, len(out) - 1)
            st.service += gen - req.max_new_tokens

    def on_reject(self, req, now: float, timeout: bool = False) -> None:
        """A WAITING request was refused (shed / impossible / deadline)."""
        st = self.tenant(req.tenant)
        if timeout:
            st.timeouts += 1
        else:
            st.rejected += 1
        self._hit_cache.pop(req.rid, None)

    # -------------------------------------------------------- preemption
    def preempt_order(self, running: Sequence[Any],
                      now: float) -> List[Any]:
        """Victim preference for preempt–restore (DESIGN.md §17): the
        engine preempts the FIRST feasible candidate in this order when
        the device pool stays exhausted.  The base (FIFO-compatible) rule
        is newest-admitted first — the request that has invested the
        least compute loses it.  Accounting is NOT touched here; the
        engine calls :meth:`on_preempt` once a victim is actually
        checkpointed."""
        return sorted(running, key=lambda r: r.arrival, reverse=True)

    def on_preempt(self, req, now: float) -> None:
        """An admitted request went back to the waiting queue.  Reverse
        the in-flight budgets (it no longer holds batch/page resources)
        but KEEP the service already billed: the tenant paid for compute
        that really ran, and keeping it billed makes the same tenant's
        requests the natural next victims under fair share instead of a
        preempt–readmit livelock."""
        st = self.tenant(req.tenant)
        st.concurrent = max(0, st.concurrent - 1)
        st.tokens_in_flight = max(
            0, st.tokens_in_flight - (len(req.prompt) + req.max_new_tokens))

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {name: {"weight": st.weight, "service": round(st.service, 2),
                       "vtime": round(st.vtime, 2),
                       "concurrent": st.concurrent,
                       "tokens_in_flight": st.tokens_in_flight,
                       "accepted": st.accepted, "rejected": st.rejected,
                       "timeouts": st.timeouts}
                for name, st in sorted(self.tenants.items())}


class FIFOAdmission(AdmissionPolicy):
    """The seed behaviour: strict arrival order, head-of-line blocking
    and all.  Budgets still apply (a head request from an over-budget
    tenant blocks the queue exactly as a too-big one does — FIFO is
    FIFO), which keeps the two policies comparable under one config."""

    name = "fifo"

    def select(self, waiting: Sequence[Any], now: float) -> Optional[Any]:
        if not waiting:
            return None
        head = waiting[0]
        if self.over_budget(head.tenant):
            return None
        return head


class FairShareAdmission(AdmissionPolicy):
    """Weighted fair queuing + SRPT bias + aging (module docstring has
    the score formula).  ``select`` returns the eligible waiting request
    with the LOWEST score; tenants over budget are skipped, not
    blocking."""

    name = "fairshare"

    def score(self, req, now: float) -> float:
        sc = self.sc
        wait_s = max(0.0, now - req.arrival)
        return (self.tenant(req.tenant).vtime
                + sc.fair_srpt_weight * self.cost(req)
                - sc.fair_aging_tokens_per_s * wait_s)

    def select(self, waiting: Sequence[Any], now: float) -> Optional[Any]:
        best, best_key = None, None
        for i, req in enumerate(waiting):
            if self.over_budget(req.tenant):
                continue
            key = (self.score(req, now), i)   # index: deterministic ties
            if best_key is None or key < best_key:
                best, best_key = req, key
        return best

    def _shed_order(self, waiting: Sequence[Any],
                    now: float) -> List[Any]:
        """Depth-bound victims: worst score first — the request fair
        share would have admitted LAST is the one shed first."""
        scored = sorted(((self.score(r, now), i, r)
                         for i, r in enumerate(waiting)), reverse=True)
        return [r for _, _, r in scored]

    def preempt_order(self, running: Sequence[Any],
                      now: float) -> List[Any]:
        """Preemption victims: worst fair-share score first — the same
        ordering shedding uses, so the request fair share values least
        is the one that loses its batch slot under pressure."""
        scored = sorted(((self.score(r, now), i, r)
                         for i, r in enumerate(running)), reverse=True)
        return [r for _, _, r in scored]


def make_policy(sc: ServeConfig,
                probe_hit: Optional[Callable[[Any], float]] = None,
                pinned_pages: Optional[Callable[[str], int]] = None
                ) -> AdmissionPolicy:
    """Build the policy named by ``ServeConfig.admission``."""
    if sc.admission == "fifo":
        return FIFOAdmission(sc, probe_hit, pinned_pages)
    if sc.admission == "fairshare":
        return FairShareAdmission(sc, probe_hit, pinned_pages)
    raise ValueError(f"unknown admission policy {sc.admission!r}")
