"""Decode step latency vs context length: paged kernel vs legacy gather.

The point of the page-native decode path (DESIGN.md §12): the gather path
materializes every request's FULL block table — O(smax) HBM traffic per
step regardless of how many tokens the request actually has — while the
paged path's traffic tracks the live page count (bucketed to powers of
two).  So with ``smax`` fixed, gather step time should stay ~flat as the
context shrinks, and paged step time should drop with it.

Method: for each (mode, path, ctx) cell, one ForkServer with a FIXED
``max_pages_per_req`` (so ``smax`` is identical across ctx values) runs the
same fork twice — the first pass builds the cache and compiles every
bucket, the second is a full prefix hit, i.e. a pure-decode run — and the
cell's cost is the delta of the engine's step-phase wall-clock metrics
(``decode_ms + sync_ms``, the satellite of the same PR) over the delta of
decode steps.

Emits CSV rows (benchmarks.run harness format) AND writes
``BENCH_decode.json`` — the start of this repo's recorded perf trajectory.

  python -m benchmarks.bench_decode             # full sweep
  python -m benchmarks.bench_decode --smoke     # CI-sized, same JSON
"""
from __future__ import annotations

import argparse
import gc
import json
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import emit, get_tiny_model
from repro.core.config import ServeConfig
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams

FULL = dict(ctxs=(64, 128, 256, 448), max_pages_per_req=32, max_new=48,
            max_pages=640)
SMOKE = dict(ctxs=(48, 96), max_pages_per_req=8, max_new=16, max_pages=192)


def _measure_cell(mode: str, paged: bool, ctx: int, knobs: Dict) -> Dict:
    cfg, params, lora = get_tiny_model(rank=8)
    sc = ServeConfig(page_size=16, max_pages=knobs["max_pages"],
                     max_batch=4, max_prefill_tokens=128, mode=mode,
                     max_pages_per_req=knobs["max_pages_per_req"],
                     use_paged_kernel=paged)
    server = ForkServer(cfg, params, lora, sc)
    rng = np.random.default_rng(0)
    context = list(rng.integers(0, cfg.vocab_size, ctx))
    instr = list(rng.integers(0, cfg.vocab_size, 8))
    sp = SamplingParams(max_new_tokens=knobs["max_new"])
    with server.session(context, adapter_id=0) as sess:
        # pass 1: prefill + decode — compiles every bucket, fills the cache
        warm = server.wait([sess.fork(1, instr, sp)])[0]
        # measured passes: full prefix hits -> pure decode, identical
        # greedy tokens.  min-of-N is robust to scheduler/GC noise spikes
        # (compile time dominates the cell anyway, not these steps).
        per_step_ms = []
        steps = 0
        m1 = server.metrics()
        for _ in range(3):
            m0 = m1
            out = server.wait([sess.fork(1, instr, sp)])[0]
            m1 = server.metrics()
            assert out.tokens == warm.tokens, "warm/measured runs diverged"
            steps = m1["decode_steps"] - m0["decode_steps"]
            ms = (m1["decode_ms"] - m0["decode_ms"] +
                  m1["sync_ms"] - m0["sync_ms"])
            per_step_ms.append(ms / max(1, steps))
    return {
        "mode": mode,
        "path": "paged" if paged else "gather",
        "ctx_tokens": ctx,
        "smax_tokens": knobs["max_pages_per_req"] * sc.page_size,
        "decode_steps": steps,
        "us_per_decode_step": min(per_step_ms) * 1e3,
        "decode_jit_variants": m1["decode_jit_variants"],
    }


def run(smoke: bool) -> Dict:
    knobs = SMOKE if smoke else FULL
    rows: List[Dict] = []
    for mode in ("forkkv", "prefix"):
        for paged in (True, False):
            for ctx in knobs["ctxs"]:
                cell = _measure_cell(mode, paged, ctx, knobs)
                # each cell owns ~100MB of pools + its own jit cache;
                # drop both so later cells aren't measured under the
                # accumulated allocation pressure of earlier ones
                gc.collect()
                jax.clear_caches()
                rows.append(cell)
                emit(f"decode.{mode}.{cell['path']}.ctx{ctx}",
                     cell["us_per_decode_step"],
                     f"smax={cell['smax_tokens']};steps="
                     f"{cell['decode_steps']}")
    # scaling summary: per (mode, path), step time at the shortest context
    # over step time at the longest — paged should be well below 1 (cost
    # tracks kv_len), gather should hover near 1 (cost pinned to smax)
    summary: Dict[str, float] = {}
    for mode in ("forkkv", "prefix"):
        for path in ("paged", "gather"):
            sel = [r for r in rows
                   if r["mode"] == mode and r["path"] == path]
            lo = min(sel, key=lambda r: r["ctx_tokens"])
            hi = max(sel, key=lambda r: r["ctx_tokens"])
            ratio = lo["us_per_decode_step"] / \
                max(hi["us_per_decode_step"], 1e-9)
            summary[f"{mode}.{path}.short_over_long_step_ratio"] = \
                round(ratio, 4)
            emit(f"decode.{mode}.{path}.short_over_long", 0,
                 f"{ratio:.3f}")
    return {"smoke": smoke, "knobs": {k: list(v) if isinstance(v, tuple)
                                      else v for k, v in knobs.items()},
            "rows": rows, "summary": summary}


def main(argv=None) -> None:
    # benchmarks.run calls main() with no args while holding its own CLI
    # flags in sys.argv — parse only what we are explicitly handed
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (same JSON output)")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args([] if argv is None else argv)
    report = run(args.smoke)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
