"""ResidualAttention — Pallas TPU kernels (paper §5.3, Algorithm 1).

Flash-attention-style kernels that compute attention directly over the
*disaggregated* KV cache, reconstructing K on-chip and deferring the V
up-projection out of the online-softmax loop:

  Stage 1 (per KV block, in VMEM):  K = K_base + RoPE(K_res @ B_k)
  Stage 2 (online softmax):         acc   += P @ V_base      (M x D)
                                    acc_r += P @ V_res       (M x R)
  Stage 3 (once, at loop exit):     O = (acc + acc_r @ B_v) / l

TPU adaptation of the paper's Triton kernel (see DESIGN.md §3): the KV-block
loop is the innermost grid dimension (TPU executes the grid sequentially per
core), so the softmax state (m, l, acc, acc_r) lives in VMEM scratch across
iterations.  Matmuls use f32 accumulation on the MXU.  Validated on CPU with
``interpret=True``; block shapes are (8,128)-aligned for the MXU when the
inputs allow it.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INIT = -1e30


def _rope_flat(x, sin, cos):
    half = x.shape[-1] // 2
    x1, x2 = x[:, :half], x[:, half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------------
# Prefill kernel
# --------------------------------------------------------------------------
def _prefill_kernel(qpos_ref, kvlen_ref, q_ref, kb_ref, vb_ref, kr_ref,
                    vr_ref, bk_ref, bv_ref, sin_ref, cos_ref, out_ref,
                    m_scr, l_scr, acc_scr, accr_scr, *, scale: float,
                    causal: bool, window: int, block_k: int):
    j = pl.program_id(3)
    nj = pl.num_programs(3)
    g, bm, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    rows = g * bm

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accr_scr[...] = jnp.zeros_like(accr_scr)

    # ---- Stage 1: on-the-fly K reconstruction with deferred RoPE ----------
    k_b = kb_ref[0, 0].astype(jnp.float32)                 # (BN, D)
    k_r = kr_ref[0].astype(jnp.float32)                    # (BN, R)
    b_k = bk_ref[0, 0].astype(jnp.float32)                 # (R, D)
    sin = sin_ref[0].astype(jnp.float32)                   # (BN, D/2)
    cos = cos_ref[0].astype(jnp.float32)
    k_lora = jnp.dot(k_r, b_k, preferred_element_type=jnp.float32)
    k = k_b + _rope_flat(k_lora, sin, cos)                 # (BN, D)

    # ---- Stage 2: separate attention scores (base / residual) -------------
    q = q_ref[0, 0].astype(jnp.float32).reshape(rows, d)   # (G*BM, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qp = qpos_ref[0].astype(jnp.int32)                     # (BM,)
    rowpos = jnp.broadcast_to(qp[None, :], (g, bm)).reshape(rows, 1)
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, s.shape[1]), 1)
    mask = kpos < kvlen_ref[0, 0]
    if causal:
        mask = mask & (kpos <= rowpos)
    if window > 0:
        mask = mask & (kpos > rowpos - window)
    s = jnp.where(mask, s, NEG_INIT)

    m_prev = m_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new) * mask                          # masked probs
    l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)

    v_b = vb_ref[0, 0].astype(jnp.float32)                 # (BN, D)
    v_r = vr_ref[0].astype(jnp.float32)                    # (BN, R)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v_b, preferred_element_type=jnp.float32)
    accr_scr[...] = accr_scr[...] * alpha + jnp.dot(
        p, v_r, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    # ---- Stage 3: fuse via matrix associativity (once, at loop exit) ------
    @pl.when(j == nj - 1)
    def _fini():
        b_v = bv_ref[0, 0].astype(jnp.float32)             # (R, D)
        acc = acc_scr[...] + jnp.dot(accr_scr[...], b_v,
                                     preferred_element_type=jnp.float32)
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out = (acc / l).reshape(g, bm, d)
        out_ref[0, 0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "block_q", "block_k",
                     "interpret"))
def residual_attention_prefill(q, k_base, v_base, k_res, v_res, b_k, b_v,
                               sin, cos, qpos, kv_len, *, scale: float,
                               causal: bool = True, window: int = 0,
                               block_q: int = DEFAULT_BLOCK_Q,
                               block_k: int = DEFAULT_BLOCK_K,
                               interpret: bool = True):
    """Prefill ResidualAttention.

    q:           (B, Sq, Hq, D)   RoPE'd queries
    k_base:      (B, Sk, Hkv, D)  RoPE'd base keys
    v_base:      (B, Sk, Hkv, D)
    k_res/v_res: (B, Sk, R)       scaled LoRA residuals (no RoPE)
    b_k/b_v:     (B, R, Hkv*D)    per-request up-projections
    sin/cos:     (B, Sk, D//2)    RoPE tables for *cache* positions
    qpos:        (B, Sq) int32    absolute positions of query rows
    kv_len:      (B,) int32       valid cache length per request
    Returns (B, Sq, Hq, D).
    """
    bsz, sq, hq, d = q.shape
    sk, hkv = k_base.shape[1], k_base.shape[2]
    g = hq // hkv
    r = k_res.shape[-1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)

    # pad seq dims to block multiples
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pq)))
    if pk:
        k_base = jnp.pad(k_base, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v_base = jnp.pad(v_base, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_res = jnp.pad(k_res, ((0, 0), (0, pk), (0, 0)))
        v_res = jnp.pad(v_res, ((0, 0), (0, pk), (0, 0)))
        sin = jnp.pad(sin, ((0, 0), (0, pk), (0, 0)))
        cos = jnp.pad(cos, ((0, 0), (0, pk), (0, 0)))
    sqp, skp = sq + pq, sk + pk

    # layouts: q -> (B, Hkv, G, Sq, D); kv -> (B, Hkv, Sk, D)
    qt = q.reshape(bsz, sqp, hkv, g, d).transpose(0, 2, 3, 1, 4)
    kbt = k_base.transpose(0, 2, 1, 3)
    vbt = v_base.transpose(0, 2, 1, 3)
    bkt = b_k.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)   # (B,Hkv,R,D)
    bvt = b_v.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)
    kvl = kv_len.reshape(bsz, 1).astype(jnp.int32)

    grid = (bsz, hkv, sqp // block_q, skp // block_k)
    half = d // 2
    kernel = functools.partial(_prefill_kernel, scale=scale, causal=causal,
                               window=window, block_k=block_k)
    out = _call_prefill(kernel, grid, qpos, kvl, qt, kbt, vbt,
                        k_res, v_res, bkt, bvt, sin, cos,
                        bsz, hkv, g, sqp, d, r, block_q, block_k,
                        half, q.dtype, interpret)
    out = out.transpose(0, 3, 1, 2, 4).reshape(bsz, sqp, hq, d)
    return out[:, :sq]


def _call_prefill(kernel, grid, qpos, kvl, qt, kbt, vbt, k_res, v_res, bkt,
                  bvt, sin, cos, bsz, hkv, g, sqp, d, r, block_q, block_k,
                  half, dtype, interpret):
    from jax.experimental.pallas import tpu as pltpu
    rows = g * block_q
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, 1), lambda b, h, i, j: (b, 0)),
            pl.BlockSpec((1, 1, g, block_q, d), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k, r), lambda b, h, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, r), lambda b, h, i, j: (b, j, 0)),
            pl.BlockSpec((1, 1, r, d), lambda b, h, i, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, r, d), lambda b, h, i, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, half), lambda b, h, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, half), lambda b, h, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, block_q, d),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, sqp, d), dtype),
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),   # m
            pltpu.VMEM((rows, 128), jnp.float32),   # l
            pltpu.VMEM((rows, d), jnp.float32),     # acc
            pltpu.VMEM((rows, r), jnp.float32),     # acc_r
        ],
        interpret=interpret,
    )(qpos, kvl, qt, kbt, vbt, k_res, v_res, bkt, bvt, sin, cos)


# --------------------------------------------------------------------------
# Decode kernel (Sq == 1)
# --------------------------------------------------------------------------
def _decode_kernel(kvlen_ref, q_ref, kb_ref, vb_ref, kr_ref, vr_ref, bk_ref,
                   bv_ref, sin_ref, cos_ref, out_ref, m_scr, l_scr, acc_scr,
                   accr_scr, *, scale: float, window: int, block_k: int):
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g, d = q_ref.shape[2], q_ref.shape[3]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INIT)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accr_scr[...] = jnp.zeros_like(accr_scr)

    k_b = kb_ref[0, 0].astype(jnp.float32)
    k_r = kr_ref[0].astype(jnp.float32)
    b_k = bk_ref[0, 0].astype(jnp.float32)
    sin = sin_ref[0].astype(jnp.float32)
    cos = cos_ref[0].astype(jnp.float32)
    k = k_b + _rope_flat(
        jnp.dot(k_r, b_k, preferred_element_type=jnp.float32), sin, cos)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    kvlen = kvlen_ref[0, 0]
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, s.shape[1]), 1)
    mask = kpos < kvlen                                    # causal: qpos = kvlen-1
    if window > 0:
        mask = mask & (kpos > kvlen - 1 - window)
    s = jnp.where(mask, s, NEG_INIT)

    m_prev = m_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new) * mask
    l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)

    v_b = vb_ref[0, 0].astype(jnp.float32)
    v_r = vr_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v_b, preferred_element_type=jnp.float32)
    accr_scr[...] = accr_scr[...] * alpha + jnp.dot(
        p, v_r, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == nj - 1)
    def _fini():
        b_v = bv_ref[0, 0].astype(jnp.float32)
        acc = acc_scr[...] + jnp.dot(accr_scr[...], b_v,
                                     preferred_element_type=jnp.float32)
        l = jnp.maximum(l_scr[:, :1], 1e-20)
        out_ref[0, 0] = (acc / l).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "block_k", "interpret"))
def residual_attention_decode(q, k_base, v_base, k_res, v_res, b_k, b_v,
                              sin, cos, kv_len, *, scale: float,
                              window: int = 0,
                              block_k: int = DEFAULT_BLOCK_K,
                              interpret: bool = True):
    """Decode-phase ResidualAttention: one query token per request.

    q: (B, Hq, D); caches as in prefill; returns (B, Hq, D).
    """
    bsz, hq, d = q.shape
    sk, hkv = k_base.shape[1], k_base.shape[2]
    g = hq // hkv
    r = k_res.shape[-1]
    block_k = min(block_k, sk)
    pk = (-sk) % block_k
    if pk:
        k_base = jnp.pad(k_base, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v_base = jnp.pad(v_base, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_res = jnp.pad(k_res, ((0, 0), (0, pk), (0, 0)))
        v_res = jnp.pad(v_res, ((0, 0), (0, pk), (0, 0)))
        sin = jnp.pad(sin, ((0, 0), (0, pk), (0, 0)))
        cos = jnp.pad(cos, ((0, 0), (0, pk), (0, 0)))
    skp = sk + pk

    from jax.experimental.pallas import tpu as pltpu
    qt = q.reshape(bsz, hkv, g, d)
    kbt = k_base.transpose(0, 2, 1, 3)
    vbt = v_base.transpose(0, 2, 1, 3)
    bkt = b_k.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)
    bvt = b_v.reshape(bsz, r, hkv, d).transpose(0, 2, 1, 3)
    kvl = kv_len.reshape(bsz, 1).astype(jnp.int32)
    half = d // 2

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, hkv, skp // block_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k, r), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, r), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, 1, r, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, r, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, half), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, half), lambda b, h, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, r), jnp.float32),
        ],
        interpret=interpret,
    )(kvl, qt, kbt, vbt, k_res, v_res, bkt, bvt, sin, cos)
    return out.reshape(bsz, hq, d)
