"""Session-centric serving API: fork() handles + streaming (DESIGN.md §11).

The paper's headline primitive is OS-style ``fork()`` with copy-on-write,
and this module is its client-facing surface.  Nothing outside
``repro/serving`` needs to construct :class:`~repro.serving.engine.Request`
objects or busy-poll ``engine.step()`` any more:

  * :class:`ForkServer` wraps an :class:`~repro.serving.engine.Engine` and
    owns the step loop: ``poll()`` advances the engine one step and
    dispatches :class:`TokenEvent` s to live handles.
  * :class:`AgentSession` (``server.session(context_tokens)``) prefills a
    shared context ONCE and holds a radix *pin* for its whole lifetime —
    the context is immune to eviction while the session is live, so every
    later ``fork()`` hits it (pins are distinct from the transient
    per-request locks admission takes; see ``RadixTree.pin``).
  * ``session.fork(adapter_id, instruction_tokens, sampling)`` returns a
    :class:`GenerationHandle` whose ``stream()`` yields tokens as decode
    steps produce them and whose ``result()`` blocks (pumping the engine)
    until the request finishes.
  * :class:`~repro.serving.sampling.SamplingParams` selects greedy argmax
    (default — bit-for-bit the seed behaviour) or seeded
    temperature/top-k/top-p sampling, executed inside the jitted executor.

Event semantics: the engine's convention generates ``max_new_tokens + 1``
tokens and discards the trailing one (its KV is never written), and a stop
token ends generation without being returned.  Both reduce to the same
rule — the definitive output is always ``req.output[:-1]`` — so the stream
emits token *i* once token *i+1* exists (a one-step lag) and therefore
yields exactly ``result().tokens``, incrementally, followed by one terminal
event carrying the finish reason.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Sequence

from repro.serving.engine import Engine, Request
from repro.serving.sampling import GREEDY, SamplingParams

__all__ = ["ForkServer", "AgentSession", "GenerationHandle", "TokenEvent",
           "RequestOutput", "SamplingParams", "GREEDY"]


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One unit of streaming progress for a request."""

    rid: int
    index: int                   # position in the generated sequence
    token: Optional[int]         # None on the terminal event
    finished: bool = False
    finish_reason: str = ""      # stop | length | rejected | stalled |
                                 # timeout
    ts: float = 0.0              # when the token was committed (epoch s);
                                 # a multi-token speculative commit emits
                                 # one event per token with interpolated
                                 # stamps, so TPOT stays honest


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """Final result of one generation request."""

    rid: int
    adapter_id: int
    tokens: List[int]
    finish_reason: str           # stop | length | rejected | stalled |
                                 # timeout
    error: str                   # non-empty for rejected/stalled/timeout
    metrics: Dict[str, float]    # per-request counters (prefill, latency)
    tenant: str = "default"      # tenant billed for this request (§15)
    retry_after_s: float = 0.0   # overload-shed backoff hint (HTTP 429)


class GenerationHandle:
    """Handle to one in-flight generation (returned by ``fork()``).

    ``stream()`` yields :class:`TokenEvent` s incrementally;
    ``result()`` pumps the server until the request completes.  Both may
    be used on the same handle (events are consumed exactly once by
    whichever iterator pops them first; ``result()`` never consumes the
    event queue).
    """

    def __init__(self, server: "ForkServer", req: Request):
        self._server = server
        self._req = req
        self._queue: Deque[TokenEvent] = deque()
        self._emitted = 0
        self._terminal_sent = False

    # ------------------------------------------------------------- status
    @property
    def rid(self) -> int:
        return self._req.rid

    @property
    def adapter_id(self) -> int:
        return self._req.adapter_id

    @property
    def done(self) -> bool:
        return self._req.state == "done"

    # ------------------------------------------------------------ events
    def _drain_new(self) -> List[TokenEvent]:
        """Called by ``ForkServer.poll``: turn engine progress since the
        last poll into events.  Emits token *i* once token *i+1* exists
        (lag-one — see module docstring), so the stream always equals the
        final ``result().tokens``."""
        req = self._req
        out: List[TokenEvent] = []
        limit = max(0, len(req.output) - 1)
        times = req.token_times
        for i in range(self._emitted, limit):
            out.append(TokenEvent(rid=req.rid, index=i,
                                  token=req.output[i],
                                  ts=times[i] if i < len(times) else 0.0))
        self._emitted = max(self._emitted, limit)
        if req.state == "done" and not self._terminal_sent:
            out.append(TokenEvent(rid=req.rid, index=self._emitted,
                                  token=None, finished=True,
                                  finish_reason=req.finish_reason,
                                  ts=req.finished_at))
            self._terminal_sent = True
        self._queue.extend(out)
        return out

    def stream(self) -> Iterator[TokenEvent]:
        """Yield this request's TokenEvents as the engine produces them,
        pumping ``server.poll()`` whenever none are pending.  Ends after
        the terminal (``finished=True``) event."""
        while True:
            while self._queue:
                ev = self._queue.popleft()
                yield ev
                if ev.finished:
                    return
            if self._terminal_sent:
                return               # terminal already consumed elsewhere
            self._server.poll()

    def result(self) -> RequestOutput:
        """Pump the server until this request finishes; return its output.
        Does not consume the event queue — a concurrent ``stream()`` still
        sees every event."""
        req = self._req
        while req.state != "done":
            self._server.poll()
        if not self._terminal_sent:
            self._drain_new()
        tokens = list(req.output[:-1]) if req.output else []
        latency = max(0.0, req.finished_at - req.arrival) \
            if req.finished_at else 0.0
        # per-request latency breakdown (DESIGN.md §14): TTFT from arrival
        # to the first sampled token, TPOT the per-token mean after it
        ttft_s = max(0.0, req.first_token_at - req.arrival) \
            if req.first_token_at else 0.0
        # TPOT from the per-token commit stamps when available (multi-token
        # speculative commits interpolate within the step); fall back to
        # span/(n-1) for requests without stamps
        if len(req.token_times) >= 2:
            tpot_s = ((req.token_times[-1] - req.token_times[0]) /
                      (len(req.token_times) - 1))
        else:
            tpot_s = (max(0.0, req.finished_at - req.first_token_at) /
                      max(1, len(req.output) - 1)) if req.first_token_at \
                else 0.0
        return RequestOutput(
            rid=req.rid, adapter_id=req.adapter_id, tokens=tokens,
            finish_reason=req.finish_reason or "length", error=req.error,
            tenant=req.tenant, retry_after_s=req.retry_after_s,
            metrics={"prompt_tokens": len(req.prompt),
                     "prefilled_tokens": req.prefilled_tokens,
                     "prefill_share": req.prefill_share,
                     "kv_len": req.kv_len,
                     "latency_s": latency,
                     "ttft_ms": ttft_s * 1e3,
                     "tpot_ms": tpot_s * 1e3,
                     "spec_proposed": req.spec_proposed,
                     "spec_accepted": req.spec_accepted})


class AgentSession:
    """A pinned shared context plus the forks spawned from it.

    Created via :meth:`ForkServer.session` — the context is prefilled once
    (a context-only request) and its radix path pinned for the session's
    lifetime, so concurrent load can never evict it out from under the
    agent tree.  ``close()`` (or use as a context manager) drops the pin.
    """

    def __init__(self, server: "ForkServer", context: Sequence[int],
                 adapter_id: int, pin_handle, tenant: str = "default"):
        self._server = server
        self.context = list(context)
        self.adapter_id = adapter_id
        self.tenant = tenant
        self._pin = pin_handle
        self._closed = False
        self.forks = 0

    @property
    def alive(self) -> bool:
        return not self._closed

    def fork(self, adapter_id: int, instruction_tokens: Sequence[int],
             sampling: Optional[SamplingParams] = None,
             deadline_s: float = 0.0) -> GenerationHandle:
        """Fork the pinned context: new request = context ‖ instruction,
        served under ``adapter_id`` with CoW cache inheritance.  The fork
        bills against the session's tenant."""
        if self._closed:
            raise RuntimeError("fork() on a closed AgentSession")
        self.forks += 1
        return self._server.generate(
            adapter_id, self.context + list(instruction_tokens),
            sampling=sampling, tenant=self.tenant, deadline_s=deadline_s)

    def close(self) -> None:
        """Drop the session pin; the context becomes evictable again."""
        if not self._closed:
            self._closed = True
            self._server.engine.unpin(self._pin)
            self._server._sessions.discard(id(self))

    def __enter__(self) -> "AgentSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ForkServer:
    """Client-facing serving frontend over the ForkKV :class:`Engine`.

    One ``poll()`` call advances the engine one step (admission + at most
    one batched prefill call + one decode round) and dispatches TokenEvents to
    every live handle — the single pump replacing the per-caller busy
    loops of the seed (``WorkflowDriver._run_request`` et al.).
    """

    def __init__(self, cfg, params, lora, sc):
        self.engine = Engine(cfg, params, lora, sc)
        self._init_state()

    @classmethod
    def from_engine(cls, engine: Engine) -> "ForkServer":
        srv = cls.__new__(cls)
        srv.engine = engine
        srv._init_state()
        return srv

    def _init_state(self) -> None:
        self._rids = itertools.count(1)
        self._handles: Dict[int, GenerationHandle] = {}
        self._sessions = set()
        self.events_dispatched = 0

    # ---------------------------------------------------------- sessions
    def session(self, context_tokens: Sequence[int],
                adapter_id: int = 0,
                tenant: str = "default") -> AgentSession:
        """Prefill ``context_tokens`` once and pin the result for the
        session's lifetime.  Synchronous: pumps the engine until the
        context cache is built (concurrent handles keep streaming).
        ``tenant`` owns the session: forks bill against it and the pinned
        pages count toward its ``tenant_max_pinned_pages`` budget."""
        req = Request(rid=next(self._rids), adapter_id=adapter_id,
                      prompt=list(context_tokens), max_new_tokens=0,
                      is_context=True, arrival=time.time(), tenant=tenant)
        self.engine.submit(req)
        while req.state != "done":
            self.poll()
        if req.error:
            raise RuntimeError(f"session context failed: {req.error}")
        pin = self.engine.pin_prefix(req.prompt, adapter_id, tenant=tenant)
        sess = AgentSession(self, context_tokens, adapter_id, pin,
                            tenant=tenant)
        self._sessions.add(id(sess))
        return sess

    # --------------------------------------------------------- generation
    def generate(self, adapter_id: int, prompt_tokens: Sequence[int],
                 sampling: Optional[SamplingParams] = None,
                 tenant: str = "default", deadline_s: float = 0.0
                 ) -> GenerationHandle:
        """Submit a generation request; returns immediately with a handle.
        (Session-less entry point — ``session.fork`` builds on it.)
        ``deadline_s`` bounds QUEUEING time: a request still waiting that
        long after arrival finishes with ``finish_reason="timeout"``
        instead of waiting forever (DESIGN.md §15)."""
        sp = sampling if sampling is not None else GREEDY
        req = Request(rid=next(self._rids), adapter_id=adapter_id,
                      prompt=list(prompt_tokens),
                      max_new_tokens=sp.max_new_tokens, sampling=sp,
                      arrival=time.time(), tenant=tenant,
                      deadline_s=deadline_s)
        self.engine.submit(req)
        handle = GenerationHandle(self, req)
        self._handles[req.rid] = handle
        return handle

    # ``submit`` is the historical name for the session-less entry point;
    # keep it as an alias so callers reading the paper-facing docs
    # (``ForkServer.submit(..., deadline_s=...)``) land on generate().
    submit = generate

    # --------------------------------------------------------------- pump
    def poll(self) -> List[TokenEvent]:
        """Advance the engine one step and dispatch new TokenEvents to
        their handles.  Returns the events dispatched by this call."""
        eng = self.engine
        if eng.waiting or eng.running:
            eng.step()
        events: List[TokenEvent] = []
        for rid, handle in list(self._handles.items()):
            events.extend(handle._drain_new())
            if handle._terminal_sent:
                del self._handles[rid]     # handle keeps its own queue
        self.events_dispatched += len(events)
        return events

    def wait(self, handles: Optional[Sequence[GenerationHandle]] = None
             ) -> List[RequestOutput]:
        """Pump until the given handles (default: everything in flight)
        complete; returns their outputs in order."""
        if handles is None:
            handles = list(self._handles.values())
        while any(not h.done for h in handles):
            self.poll()
        return [h.result() for h in handles]

    def run(self, max_polls: int = 1_000_000) -> None:
        """Pump until the engine is idle."""
        for _ in range(max_polls):
            if not self.engine.waiting and not self.engine.running:
                break
            self.poll()

    # -------------------------------------------------------------- drain
    def drain(self) -> None:
        """Stop admitting new work (DESIGN.md §17): every request still in
        ``waiting`` finishes with ``finish_reason="draining"`` on the next
        poll; in-flight requests run to completion.  Idempotent."""
        self.engine.drain()

    @property
    def drained(self) -> bool:
        """True once draining AND nothing is waiting or running."""
        return self.engine.drained

    # ------------------------------------------------------------ metrics
    def metrics(self) -> Dict:
        m = self.engine.metrics()
        m["events_dispatched"] = self.events_dispatched
        m["live_sessions"] = len(self._sessions)
        return m
