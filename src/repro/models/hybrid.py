"""Griffin-style hybrid blocks (RecurrentGemma): RG-LRU + local attention.

Layer pattern (cfg.block_pattern, default ("rglru", "rglru", "local")) is
tiled over cfg.num_layers.  RG-LRU layers carry a fixed-size recurrent state
(no KV cache → ForkKV N/A, DESIGN.md §5); local-attention layers use a
sliding-window ring KV cache where ForkKV's disaggregation DOES apply — they
reuse the transformer attention implementation including LoRA + rCache.
[arXiv:2402.19427]
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import base
from repro.models import transformer as tfm

Params = Dict[str, Any]

LRU_C = 8.0


def layer_kinds(cfg: ModelConfig):
    pat = cfg.block_pattern or ("rglru", "rglru", "local")
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = cfg.activation_dtype
    d = cfg.d_model
    w = _lru_width(cfg)
    kinds = layer_kinds(cfg)
    ks = iter(base.split_keys(key, 12 * cfg.num_layers + 8))
    layers = []
    for kind in kinds:
        l: Params = {"ln1": jnp.zeros((d,), dt), "ln2": jnp.zeros((d,), dt)}
        if kind == "rglru":
            l.update({
                "w_gelu": base.dense_init(next(ks), (d, w), dt),
                "w_rec": base.dense_init(next(ks), (d, w), dt),
                "conv_w": base.dense_init(next(ks), (4, w), dt, 0.2),
                "conv_b": jnp.zeros((w,), dt),
                "w_rgate": base.dense_init(next(ks), (w, w), dt),
                "b_rgate": jnp.zeros((w,), jnp.float32),
                "w_igate": base.dense_init(next(ks), (w, w), dt),
                "b_igate": jnp.zeros((w,), jnp.float32),
                "lam": jnp.full((w,), -1.0, jnp.float32),   # softplus'd
                "w_out": base.dense_init(next(ks), (w, d), dt),
            })
        else:                                       # local attention
            l.update({
                "wq": base.dense_init(next(ks), (d, cfg.q_dim), dt),
                "wk": base.dense_init(next(ks), (d, cfg.kv_dim), dt),
                "wv": base.dense_init(next(ks), (d, cfg.kv_dim), dt),
                "wo": base.dense_init(next(ks), (cfg.q_dim, d), dt),
            })
        # MLP after every mixer
        l.update({
            "w_gate": base.dense_init(next(ks), (d, cfg.d_ff), dt),
            "w_up": base.dense_init(next(ks), (d, cfg.d_ff), dt),
            "w_down": base.dense_init(next(ks), (cfg.d_ff, d), dt),
        })
        layers.append(l)
    return {
        "embed": base.dense_init(next(ks), (cfg.vocab_size, d), dt),
        "final_norm": jnp.zeros((d,), dt),
        "layers": layers,                            # heterogeneous: a list
        "unembed": base.dense_init(next(ks), (d, cfg.vocab_size), dt),
    }


def logical_axes(cfg: ModelConfig) -> Params:
    kinds = layer_kinds(cfg)
    layers = []
    for kind in kinds:
        l = {"ln1": ("embed",), "ln2": ("embed",)}
        if kind == "rglru":
            l.update({
                "w_gelu": ("embed", "inner"), "w_rec": ("embed", "inner"),
                "conv_w": (None, "inner"), "conv_b": ("inner",),
                "w_rgate": ("inner_in", "inner"), "b_rgate": ("inner",),
                "w_igate": ("inner_in", "inner"), "b_igate": ("inner",),
                "lam": ("inner",), "w_out": ("inner", "embed"),
            })
        else:
            l.update({"wq": ("embed", "q_out"), "wk": ("embed", "kv_out"),
                      "wv": ("embed", "kv_out"), "wo": ("q_out", "embed")})
        l.update({"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
                  "w_down": ("ff", "embed")})
        layers.append(l)
    return {"embed": ("vocab", "embed"), "final_norm": ("embed",),
            "layers": layers, "unembed": ("embed", "vocab")}


LRU_CHUNK = 256


def _rglru_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray):
    """h_t = a_t * h_{t-1} + b_t.  Chunked: sequential lax.scan over chunks
    of LRU_CHUNK with an associative scan inside each chunk — bounds the
    O(S log S) temporaries of a full-sequence associative scan (which blew
    per-device training memory at 4k x 4096-wide states).  On real TPU the
    inner loop becomes a Pallas linear-scan kernel (Griffin's approach)."""
    def op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    bsz, s, w = a.shape
    q = min(LRU_CHUNK, s)
    if s % q:
        # ragged tail: fall back to the direct associative scan
        b = b.at[:, 0].add(a[:, 0] * h0)
        _, bv = jax.lax.associative_scan(op, (a, b), axis=1)
        return bv, bv[:, -1]
    nc = s // q
    ac = a.reshape(bsz, nc, q, w).transpose(1, 0, 2, 3)
    bc = b.reshape(bsz, nc, q, w).transpose(1, 0, 2, 3)

    def chunk_body(h, inp):
        a_i, b_i = inp                               # (B, Q, W)
        b_i = b_i.at[:, 0].add(a_i[:, 0] * h)
        _, states = jax.lax.associative_scan(op, (a_i, b_i), axis=1)
        return states[:, -1], states

    h_last, states = jax.lax.scan(chunk_body, h0, (ac, bc))
    return states.transpose(1, 0, 2, 3).reshape(bsz, s, w), h_last


def _rglru_block(p_l, x, cfg, cache_l, mode):
    """Recurrent mixer.  cache_l: {"conv": (B,3,W), "h": (B,W)}."""
    w = _lru_width(cfg)
    gelu_branch = jax.nn.gelu(x @ p_l["w_gelu"])
    y = x @ p_l["w_rec"]
    conv_state = cache_l["conv"] if cache_l is not None else None
    # linear causal conv (no activation)
    k = p_l["conv_w"].shape[0]
    pad = conv_state if conv_state is not None else \
        jnp.zeros(y.shape[:1] + (k - 1,) + y.shape[2:], y.dtype)
    yp = jnp.concatenate([pad, y], axis=1)
    y = sum(yp[:, i:i + x.shape[1]] * p_l["conv_w"][i] for i in range(k)) \
        + p_l["conv_b"]
    new_conv = yp[:, -(k - 1):]

    r = jax.nn.sigmoid((y @ p_l["w_rgate"]).astype(jnp.float32) + p_l["b_rgate"])
    i = jax.nn.sigmoid((y @ p_l["w_igate"]).astype(jnp.float32) + p_l["b_igate"])
    log_a = -LRU_C * jax.nn.softplus(p_l["lam"]) * r      # (B,S,W), <0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * y.astype(jnp.float32))
    h0 = cache_l["h"].astype(jnp.float32) if cache_l is not None else \
        jnp.zeros((x.shape[0], w), jnp.float32)
    if mode == "decode":
        h = a[:, 0] * h0 + gated[:, 0]
        states, h_last = h[:, None], h
    else:
        states, h_last = _rglru_scan(a, gated, h0)
    out = (states.astype(x.dtype) * gelu_branch) @ p_l["w_out"]
    new_cache = None
    if cache_l is not None:
        new_cache = {"conv": new_conv.astype(cache_l["conv"].dtype),
                     "h": h_last.astype(cache_l["h"].dtype)}
    return out, new_cache


def _layer(p_l, kind, x, cfg, *, positions, mode, cache_l, kv_len, lora_l,
           adapter_ids, disagg, chunk_start=None):
    h = base.rms_norm(x, p_l["ln1"], cfg.norm_eps)
    if kind == "rglru":
        mix, new_cache = _rglru_block(p_l, h, cfg, cache_l, mode)
        x = x + mix
    else:
        attn_out, new_cache = tfm.attention(
            p_l, h, cfg, positions=positions, mode=mode, cache=cache_l,
            kv_len=kv_len, lora=lora_l, adapter_ids=adapter_ids,
            disagg=disagg, window=cfg.local_window,
            chunk_start=chunk_start)
        x = x + attn_out.reshape(x.shape[0], x.shape[1], -1) @ p_l["wo"]
    h = base.rms_norm(x, p_l["ln2"], cfg.norm_eps)
    x = x + (jax.nn.silu(h @ p_l["w_gate"]) * (h @ p_l["w_up"])) @ p_l["w_down"]
    return x, new_cache


def _apply(params, x, cfg, *, positions, mode, cache, kv_len, lora,
           adapter_ids, disagg, chunk_start=None):
    kinds = layer_kinds(cfg)
    new_caches = []
    attn_idx = 0
    for li, (p_l, kind) in enumerate(zip(params["layers"], kinds)):
        c_l = cache[li] if cache is not None else None
        l_l = None
        if lora is not None and kind == "local":
            l_l = jax.tree_util.tree_map(lambda t: t[attn_idx], lora)
        if kind == "local":
            attn_idx += 1
        def run(x_, p_, c_, l_, pos_, kvl_, ids_, _kind=kind):
            return _layer(p_, _kind, x_, cfg, positions=pos_, mode=mode,
                          cache_l=c_, kv_len=kvl_, lora_l=l_,
                          adapter_ids=ids_, disagg=disagg,
                          chunk_start=chunk_start)

        fn = jax.checkpoint(run) if (cfg.remat and mode == "full") else run
        x, nc = fn(x, p_l, c_l, l_l, positions, kv_len, adapter_ids)
        new_caches.append(nc)
    return x, (new_caches if cache is not None else None)


def num_attention_layers(cfg: ModelConfig) -> int:
    return sum(1 for k in layer_kinds(cfg) if k == "local")


def init_lora_stacks(cfg: ModelConfig, key, n_adapters: int,
                     nonzero: bool = True) -> Params:
    """LoRA stacks for the attention layers only (leading dim = #attn layers)."""
    import dataclasses
    sub = dataclasses.replace(cfg, num_layers=num_attention_layers(cfg))
    return tfm.init_lora_stacks(sub, key, n_adapters, nonzero)


def forward(params, tokens, cfg: ModelConfig, *, lora=None, adapter_ids=None,
            disagg=False, extra_embeds=None) -> jnp.ndarray:
    x = params["embed"][tokens]
    bsz, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (bsz, s))
    x, _ = _apply(params, x, cfg, positions=positions, mode="full",
                  cache=None, kv_len=None, lora=lora,
                  adapter_ids=adapter_ids, disagg=disagg)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["unembed"]


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               disagg: bool = False, dtype=None) -> list:
    dt = dtype or cfg.activation_dtype
    w = _lru_width(cfg)
    hd = cfg.resolved_head_dim
    smax = min(max_len, cfg.local_window) if cfg.local_window else max_len
    caches = []
    for kind in layer_kinds(cfg):
        if kind == "rglru":
            caches.append({"conv": jnp.zeros((batch, 3, w), dt),
                           "h": jnp.zeros((batch, w), jnp.float32)})
        else:
            c = {"k": jnp.zeros((batch, smax, cfg.num_kv_heads, hd), dt),
                 "v": jnp.zeros((batch, smax, cfg.num_kv_heads, hd), dt)}
            if disagg:
                c["k_res"] = jnp.zeros((batch, smax, cfg.lora.rank), dt)
                c["v_res"] = jnp.zeros((batch, smax, cfg.lora.rank), dt)
            caches.append(c)
    return caches


def cache_logical_axes(cfg: ModelConfig, disagg: bool = False) -> list:
    axes = []
    for kind in layer_kinds(cfg):
        if kind == "rglru":
            axes.append({"conv": ("batch", None, "inner"),
                         "h": ("batch", "inner")})
        else:
            c = {"k": ("batch", None, "kv_heads", "kv_head_dim"),
                 "v": ("batch", None, "kv_heads", "kv_head_dim")}
            if disagg:
                c["k_res"] = ("batch", None, "rank")
                c["v_res"] = ("batch", None, "rank")
            axes.append(c)
    return axes


def prefill(params, tokens, cache, cfg: ModelConfig, *, start: int = 0,
            lora=None, adapter_ids=None, disagg=False, extra_embeds=None):
    x = params["embed"][tokens]
    bsz, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(start, start + s), (bsz, s))
    x, cache = _apply(params, x, cfg, positions=positions, mode="prefill",
                      cache=cache, kv_len=None, lora=lora,
                      adapter_ids=adapter_ids, disagg=disagg,
                      chunk_start=start)
    x = base.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return x @ params["unembed"], cache


def decode_step(params, tokens, cache, kv_len, cfg: ModelConfig, *,
                lora=None, adapter_ids=None, disagg=False):
    x = params["embed"][tokens][:, None]
    x, cache = _apply(params, x, cfg, positions=kv_len, mode="decode",
                      cache=cache, kv_len=kv_len, lora=lora,
                      adapter_ids=adapter_ids, disagg=disagg)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["unembed"])[:, 0], cache
