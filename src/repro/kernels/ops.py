"""Public entry points for ResidualAttention.

``residual_attention(...)`` dispatches between the Pallas kernel (TPU target,
validated on CPU via ``interpret=True``) and the pure-jnp oracle in
:mod:`repro.kernels.ref`.  The jitted model code calls these wrappers so the
backend can be swapped with one flag.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels import paged_residual_attention as pra
from repro.kernels import ref as ref_mod
from repro.kernels import residual_attention as ra

# Backend selection: "pallas" (interpret on CPU, compiled on TPU) or "ref".
# Unset -> platform-aware: the Pallas kernels on real TPU (the production
# hot path, DESIGN.md §12), the XLA ref mirror everywhere else (identical
# numerics, no per-grid-step interpret overhead on CPU).
# ``FORKKV_KERNEL_BACKEND`` is the CI-facing alias; its extra value
# "pallas-interpret" forces the Pallas kernels in interpret mode even off
# TPU (the backend-matrix CI job runs the parity suite under it).
_FORCE_INTERPRET = False


def _normalize(name: str) -> str:
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = name == "pallas-interpret"
    return "pallas" if _FORCE_INTERPRET else name


_BACKEND = _normalize(os.environ.get("REPRO_ATTN_BACKEND", "")
                      or os.environ.get("FORKKV_KERNEL_BACKEND", ""))


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("pallas", "pallas-interpret", "ref"), name
    _BACKEND = _normalize(name)


def get_backend() -> str:
    if _BACKEND:
        return _BACKEND
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve_interpret(interpret):
    if interpret is not None:
        return interpret
    if _FORCE_INTERPRET:
        return True
    import jax
    return jax.default_backend() != "tpu"


def residual_attention(q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
                       *, qpos, kv_len, window: int = 0, causal: bool = True,
                       scale: Optional[float] = None,
                       backend: Optional[str] = None,
                       interpret: bool = True) -> jnp.ndarray:
    """Attention over a disaggregated KV cache.  Shapes as in ref.py."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    be = backend or get_backend()
    if be == "ref":
        return ref_mod.residual_attention_ref(
            q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
            qpos=qpos, kv_len=kv_len, window=window, causal=causal,
            scale=scale)
    if q.shape[1] == 1:   # decode fast path
        out = ra.residual_attention_decode(
            q[:, 0], k_base, v_base, k_res, v_res, b_k, b_v, sin, cos,
            kv_len, scale=scale, window=window, interpret=interpret)
        return out[:, None]
    return ra.residual_attention_prefill(
        q, k_base, v_base, k_res, v_res, b_k, b_v, sin, cos, qpos, kv_len,
        scale=scale, causal=causal, window=window, interpret=interpret)


def paged_residual_attention(q, kb_pool, vb_pool, kr_pool, vr_pool, b_k,
                             b_v, bt_b, bt_r, kv_len, *,
                             scale: Optional[float] = None,
                             window: int = 0,
                             rope_theta: float = 10_000.0,
                             use_rope: bool = True,
                             kb_scale=None, vb_scale=None,
                             backend: Optional[str] = None,
                             interpret: Optional[bool] = None) -> jnp.ndarray:
    """Decode attention over paged pools + block tables (DESIGN.md §12).

    The serving hot path: the executor hands the pools and per-request
    block tables straight in — no gather-to-contiguous staging.  Dispatches
    like :func:`residual_attention`:

    * ``pallas`` — the paged kernels with scalar-prefetch block tables,
      per-request page skipping and (disaggregated variant) in-kernel
      deferred RoPE.  Compiled on TPU; ``interpret=True`` runs the same
      kernel code on CPU.
    * ``ref`` — the XLA gather mirror (:func:`repro.kernels.ref.
      paged_residual_attention_ref`); identical numerics-by-construction,
      runs anywhere, and still only touches ``bt_b.shape[1]`` pages.

    Pass ``kr_pool=None`` (with ``vr_pool``/``b_k``/``b_v``/``bt_r`` also
    None) for the base-only variant — unified caches or no-LoRA requests.
    ``kv_len`` counts ALL valid tokens incl. the one just written; the
    query row sits at position ``kv_len - 1``.  ``window > 0`` restricts
    attention to the trailing ``window`` positions (SWA) and skips the
    DMAs of out-of-window pages (DESIGN.md §13).  Returns (B, Hq, D).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    be = backend or get_backend()
    if be == "ref":
        return ref_mod.paged_residual_attention_ref(
            q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt_b, bt_r,
            kv_len, scale=scale, window=window, rope_theta=rope_theta,
            use_rope=use_rope, kb_scale=kb_scale, vb_scale=vb_scale)
    interpret = _resolve_interpret(interpret)
    if kr_pool is None:
        return pra.paged_attention_decode_base(
            q, kb_pool, vb_pool, bt_b, kv_len, scale=scale, window=window,
            kb_scale=kb_scale, vb_scale=vb_scale, interpret=interpret)
    return pra.paged_residual_attention_decode(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt_b, bt_r,
        kv_len, scale=scale, window=window, rope_theta=rope_theta,
        use_rope=use_rope, kb_scale=kb_scale, vb_scale=vb_scale,
        interpret=interpret)


def paged_residual_attention_prefill(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                     b_k, b_v, bt_b, bt_r, start, kv_len, *,
                                     scale: Optional[float] = None,
                                     window: int = 0,
                                     rope_theta: float = 10_000.0,
                                     use_rope: bool = True,
                                     kb_scale=None, vb_scale=None,
                                     backend: Optional[str] = None,
                                     interpret: Optional[bool] = None
                                     ) -> jnp.ndarray:
    """Chunked-prefill attention over paged pools + block tables
    (DESIGN.md §13) — the page-native half of the prefill hot path.

    q is a (B, chunk, Hq, D) tile whose K/V the executor has ALREADY
    written into the pools; KV streams page by page from base+residual
    pools via the block tables with a causal mask inside the chunk and a
    running softmax across page steps.  ``start`` (B,) is the absolute
    position of each chunk's first query row; ``kv_len`` (B,) counts valid
    tokens including the chunk's writes.  Backends exactly as
    :func:`paged_residual_attention`; pass ``kr_pool=None`` for the
    base-only variant.  Returns (B, chunk, Hq, D).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    be = backend or get_backend()
    if be == "ref":
        return ref_mod.paged_residual_attention_prefill_ref(
            q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt_b, bt_r,
            start, kv_len, scale=scale, window=window,
            rope_theta=rope_theta, use_rope=use_rope, kb_scale=kb_scale,
            vb_scale=vb_scale)
    interpret = _resolve_interpret(interpret)
    if kr_pool is None:
        return pra.paged_attention_prefill_base(
            q, kb_pool, vb_pool, bt_b, start, kv_len, scale=scale,
            window=window, kb_scale=kb_scale, vb_scale=vb_scale,
            interpret=interpret)
    return pra.paged_residual_attention_prefill(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt_b, bt_r,
        start, kv_len, scale=scale, window=window, rope_theta=rope_theta,
        use_rope=use_rope, kb_scale=kb_scale, vb_scale=vb_scale,
        interpret=interpret)


def paged_residual_attention_mixed(q, kb_pool, vb_pool, kr_pool, vr_pool,
                                   b_k, b_v, bt_b, bt_r, start, q_len,
                                   kv_len, *, scale: Optional[float] = None,
                                   window: int = 0,
                                   rope_theta: float = 10_000.0,
                                   use_rope: bool = True,
                                   kb_scale=None, vb_scale=None,
                                   backend: Optional[str] = None,
                                   interpret: Optional[bool] = None
                                   ) -> jnp.ndarray:
    """Unified mixed prefill/decode attention (DESIGN.md §14): one launch
    over rows of different q-lengths — decode rows (``q_len=1``) and
    chunked-prefill rows (``q_len=chunk``) in the same batch, each row's
    q-length a scalar-prefetch operand.  Rows past ``q_len`` come back as
    exact zeros on EVERY backend.  ``kv_len`` must equal
    ``start + q_len`` per row.  Backends exactly as
    :func:`paged_residual_attention`; pass ``kr_pool=None`` for the
    base-only variant.  Returns (B, chunk, Hq, D).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    be = backend or get_backend()
    if be == "ref":
        return ref_mod.paged_residual_attention_mixed_ref(
            q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt_b, bt_r,
            start, q_len, kv_len, scale=scale, window=window,
            rope_theta=rope_theta, use_rope=use_rope, kb_scale=kb_scale,
            vb_scale=vb_scale)
    interpret = _resolve_interpret(interpret)
    if kr_pool is None:
        return pra.paged_attention_mixed_base(
            q, kb_pool, vb_pool, bt_b, start, q_len, kv_len, scale=scale,
            window=window, kb_scale=kb_scale, vb_scale=vb_scale,
            interpret=interpret)
    return pra.paged_residual_attention_mixed(
        q, kb_pool, vb_pool, kr_pool, vr_pool, b_k, b_v, bt_b, bt_r,
        start, q_len, kv_len, scale=scale, window=window,
        rope_theta=rope_theta, use_rope=use_rope, kb_scale=kb_scale,
        vb_scale=vb_scale, interpret=interpret)
