"""llama3-405b [dense]: 126L GQA kv=8, 128k vocab. [arXiv:2407.21783]

Uses Adafactor + two-level scan remat: AdamW fp32 moments do not fit
512 x 16GB v5e at our sharding (see EXPERIMENTS.md)."""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense", num_layers=126, d_model=16384,
    num_heads=128, num_kv_heads=8, d_ff=53248, vocab_size=128256,
    lora=LoRAConfig(rank=16), scan_layers=True, scan_groups=14,
    optimizer="adafactor", citation="arXiv:2407.21783")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama3-tiny", num_layers=2, d_model=128, num_heads=8,
        num_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32",
        scan_groups=0, optimizer="adamw", remat=False)
