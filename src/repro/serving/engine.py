"""ForkKV serving engine: scheduler + fork/CoW lifecycle + metrics.

Three cache-sharing policies (paper §7.1):
  * ``forkkv``     — DualRadixTree, shared bCache + per-agent rCache,
                     disaggregated attention (the paper's system)
  * ``prefix``     — per-adapter unified caches (lossless baseline; cache
                     shared only between requests with the SAME adapter)
  * ``full_reuse`` — one unified cache shared across adapters (lossy
                     baseline; first computer wins)

Iteration-level continuous batching (DESIGN.md §14, the default): each
step asks :class:`~repro.serving.scheduler.IterationScheduler` for ONE
token-budget batch plan — every runnable decode row first (q=1 each),
then chunked-prefill rows filling the remaining
``ServeConfig.iteration_token_budget`` — and runs the whole plan as a
single mixed executor call through the unified kernel grid, so a long
prompt can never head-of-line-block in-flight token streams.
``ServeConfig.mixed_batching=False`` keeps the legacy phase-separated
loop (one batched prefill call + one decode call per step, DESIGN.md
§12) for parity testing.  Pools are refcounted; under pressure the
decoupled LRU eviction frees tree leaves; requests that cannot allocate
are queued (admission control) or preempted.

With ``ServeConfig.host_tier_bytes > 0`` both device pools are wrapped in
:class:`~repro.serving.tiers.TieredPagePool` (DESIGN.md §10): eviction
demotes unlocked leaves to a numpy-backed host tier instead of destroying
them, and prefix matching during admission promotes tier-hit pages back
into free device pages — turning the seed's eviction cliff into a copy.

Clients should not drive this class directly: the session/fork API
(:mod:`repro.serving.api`, DESIGN.md §11) wraps it with ``AgentSession``
context pinning, streaming ``GenerationHandle`` s and the ``poll()`` pump.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.config import ModelConfig, ServeConfig
from repro.serving import faults as faults_mod
from repro.serving.executor import PagedExecutor, pool_bytes
from repro.serving.fairshare import make_policy
from repro.serving.pool import PagePool
from repro.serving.radix import DualRadixTree, RadixTree, ResidualForest
from repro.serving.sampling import GREEDY, SamplingParams
from repro.serving.scheduler import BatchPlan, IterationScheduler
from repro.serving.speculate import AdaptiveK, make_proposer
from repro.serving.tiers import (DiskTier, HostTier, TieredPagePool,
                                 blob_bytes, get_codec, read_blob_file,
                                 write_blob_file)


def percentile(vals: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile over SORTED ``vals`` (numpy's
    default 'linear' method, asserted against ``np.percentile`` in
    tests).  The previous nearest-rank rounding returned the window MAX
    as "p99" for any window under ~50 samples — e.g. the bounded
    admission-wait window early in a run — overstating tail latency."""
    if not vals:
        return 0.0
    rank = q * (len(vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return vals[lo] + (vals[hi] - vals[lo]) * frac


@dataclasses.dataclass
class Request:
    rid: int
    adapter_id: int
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0
    # token-selection policy; None -> greedy argmax (the seed behaviour)
    sampling: Optional[SamplingParams] = None
    # multi-tenant admission (DESIGN.md §15): the tenant this request
    # bills against, and an optional queueing deadline — a request still
    # WAITING deadline_s after arrival finishes with
    # ``finish_reason="timeout"`` instead of queueing forever.
    tenant: str = "default"
    deadline_s: float = 0.0
    admitted_at: float = 0.0      # when admission moved it to running
    retry_after_s: float = 0.0    # backoff hint set when shed (HTTP 429)
    # context-only request (AgentSession prefill): generates nothing, its
    # product is the cache; excluded from tasks_done
    is_context: bool = False
    # runtime state
    state: str = "waiting"        # waiting | prefill | decode | done
    output: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0          # next prompt position to compute
    kv_len: int = 0               # tokens with cache present
    base_pages: List[int] = dataclasses.field(default_factory=list)
    res_pages: List[int] = dataclasses.field(default_factory=list)
    owned_base: List[int] = dataclasses.field(default_factory=list)
    owned_res: List[int] = dataclasses.field(default_factory=list)
    coowned_base: List[int] = dataclasses.field(default_factory=list)
    fork: Optional[Any] = dataclasses.field(default=None)
    finished_at: float = 0.0
    # latency timestamps (satellite, DESIGN.md §14): the scheduler stamps
    # first_scheduled_at when a plan first includes the request; the
    # engine stamps first_token_at when the first output token lands —
    # TTFT = first_token_at - arrival, TPOT = the per-token mean after it
    first_scheduled_at: float = 0.0
    first_token_at: float = 0.0
    # per-token wall-clock stamps, one per output token (multi-token-safe
    # TPOT/streaming: a verify step committing k+1 tokens interpolates
    # their stamps across the step instead of piling them on one instant)
    token_times: List[float] = dataclasses.field(default_factory=list)
    # speculative decoding (DESIGN.md §16): per-request draft accounting
    spec_proposed: int = 0        # drafted tokens sent to verification
    spec_accepted: int = 0        # drafted tokens the target model kept
    prefilled_tokens: int = 0     # tokens this request actually computed
                                  # (exact int; broadcast attributes the
                                  # shared pass to its writer)
    prefill_share: float = 0.0    # amortized share of prefill compute —
                                  # broadcast splits the pass across the
                                  # group; feeds metrics()
    # stop | length | rejected | stalled | timeout | error | draining
    finish_reason: str = ""
    error: str = ""               # non-empty on any non-stop/length finish
    # preempt–restore (DESIGN.md §17): kv_len checkpointed at the last
    # preemption (recompute accounting) and the restore-pending flag the
    # next successful admission clears
    preempt_kv: int = 0
    needs_restore: bool = False
    # output length at the last successful admission: a victim must have
    # emitted at least one NEW token since (re)admission to be
    # preemptable, or two requests that cannot coexist would preempt
    # each other's restore prefills forever with zero token progress
    admit_output_len: int = 0

    @property
    def params(self) -> SamplingParams:
        return self.sampling if self.sampling is not None else GREEDY

    @property
    def ptoks(self) -> List[int]:
        """Tokens whose KV must exist before decode can proceed: the
        prompt plus — after a preempt–restore cycle — the already
        generated output, minus its last token (whose KV the decode step
        consuming it writes).  Admission matching and every prefill path
        iterate THIS, so a restored request re-prefills its generated
        suffix exactly like prompt tokens and resumes bit-identically."""
        if not self.output:
            return self.prompt
        return self.prompt + self.output[:-1]


class Engine:
    def __init__(self, cfg: ModelConfig, params, lora, sc: ServeConfig):
        self.cfg = cfg
        self.sc = sc
        self.mode = sc.mode
        disagg = sc.mode == "forkkv"
        # a disk tier or a persist dir implies tiering even without an
        # explicit host budget — restore grafts into the host tier, so one
        # must exist (default 1 GiB when only the deeper tiers asked)
        tiered = (sc.host_tier_bytes > 0 or sc.disk_tier_bytes > 0
                  or bool(sc.persist_dir))
        host_bytes = sc.host_tier_bytes or (1 << 30)
        # ONE host budget shared by both pools: host DRAM is one resource.
        self.host_tier = HostTier(host_bytes) if tiered else None
        # ...and one disk budget below it (DESIGN.md §18).  Blob files live
        # under persist_dir when given (so they survive restarts alongside
        # the manifest), else in a throwaway temp dir.
        self.disk_tier = None
        self.kv_codec = get_codec(sc.kv_codec)
        if tiered and sc.disk_tier_bytes > 0:
            disk_root = (os.path.join(sc.persist_dir, "disk")
                         if sc.persist_dir
                         else tempfile.mkdtemp(prefix="forkkv-disk-"))
            self.disk_tier = DiskTier(
                disk_root, sc.disk_tier_bytes,
                io_hook=lambda: self.faults.io("disk_io"))
        self.base_pool = PagePool(sc.max_pages, sc.page_size, "base")
        if tiered:
            self.base_pool = TieredPagePool(
                self.base_pool, self.host_tier,
                promote_limit=sc.tier_promote_limit,
                codec=self.kv_codec, disk=self.disk_tier)
        # EQUAL BYTE BUDGETS, not equal page counts: an rCache page holds
        # the same tokens in r/kv_dim of the bytes (the paper's asymmetry),
        # so the residual pool gets kv_dim/r x more pages per byte.
        res_factor = max(1, cfg.kv_dim // max(cfg.lora.rank, 1))             if disagg else 1
        n_res_pages = sc.max_pages * res_factor if disagg else sc.max_pages
        self.res_pool = PagePool(n_res_pages, sc.page_size, "residual")
        if tiered and disagg:
            self.res_pool = TieredPagePool(
                self.res_pool, self.host_tier,
                promote_limit=sc.tier_promote_limit,
                codec=self.kv_codec, disk=self.disk_tier)
        # reserve the dump page in both pools
        dump_b = self.base_pool.alloc(1)[0]
        dump_r = self.res_pool.alloc(1)[0]
        self.max_pages_per_req = min(sc.max_pages_per_req,
                                     sc.max_pages - 2)
        self.executor = PagedExecutor(cfg, params, lora, sc, disagg,
                                      self.max_pages_per_req)
        self.executor.dump_page = dump_b
        self.executor.dump_page_r = dump_r
        self.dump_b, self.dump_r = dump_b, dump_r
        if self.mode == "forkkv":
            self.dual = DualRadixTree(self.base_pool, self.res_pool)
        elif self.mode == "prefix":
            # unified cache, keyed per adapter: a forest over the base pool
            self.forest = ResidualForest(self.base_pool)
        else:                      # full_reuse
            self.tree = RadixTree(self.base_pool)
        if tiered:
            # device↔host byte movement + back-pressure (DESIGN.md §10);
            # bound late: the executor/trees must exist first.  The fault
            # sites model IO errors on the transfer path (§17): tiers.py
            # catches them, counts tier_io_errors, and falls back (failed
            # demote → true eviction; failed promote → stay host-tier).
            def _export(kind):
                def fn(p):
                    self.faults.io("tier_demote")
                    return self.executor.export_pages(kind, p)
                return fn

            def _import(kind):
                def fn(p, b):
                    self.faults.io("tier_promote")
                    self.executor.import_pages(kind, p, b)
                return fn

            self.base_pool.bind(
                export_fn=_export("base"), import_fn=_import("base"),
                pressure_fn=lambda n: self._evict(self.base_pool, n))
            if disagg:
                self.res_pool.bind(
                    export_fn=_export("res"), import_fn=_import("res"),
                    pressure_fn=lambda n: self._evict(self.res_pool, n))
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.done: List[Request] = []
        # iteration-level planner (DESIGN.md §14); unused when
        # mixed_batching=False but kept constructed so tests can probe it
        self.scheduler = IterationScheduler(sc)
        self.steps = 0
        self.mixed_steps = 0          # iterations with decode AND prefill
        # bounded window of recent decode batch sizes (diagnostics only);
        # the EXACT running aggregates live in _decode_batch_sum/_steps so
        # avg_decode_batch/decode_steps stay exact while a long-lived
        # server's memory stays O(1) instead of one int per step
        self.decode_batch_hist = collections.deque(maxlen=512)
        self._decode_batch_sum = 0
        self._decode_steps = 0
        self.preemptions = 0          # demote-under-pressure events
        self.rejected = 0             # requests refused at admission
        self.stalled = 0              # requests failed by stall detection
        self.timeouts = 0             # waiting requests past deadline_s
        self.shed = 0                 # requests rejected by overload bounds
        # fault tolerance (DESIGN.md §17): deterministic fault injection
        # (inert when no plan is configured), preempt–restore accounting,
        # quarantine/executor-isolation counters, drain + watchdog state
        self.faults = faults_mod.from_config(sc)
        self.preempted = 0            # requests checkpointed + requeued
        self.restored = 0             # preempted requests re-admitted
        self.recompute_tokens = 0     # checkpointed KV the restore had to
                                      # re-prefill (tier full / evicted)
        self.restored_pages = 0       # pages grafted from a persist
                                      # manifest at startup (§18)
        self.quarantined = 0          # rows failed by the isfinite guard
        self.exec_errors = 0          # executor/step exceptions isolated
        self.watchdog_trips = 0       # stuck-pump detections (frontend)
        self.draining = False         # True: admission stopped, in-flight
                                      # requests run to completion
        self.last_step_at = time.time()   # watchdog heartbeat
        self._no_admit = 0            # consecutive steps admission was
                                      # blocked on memory (preempt trigger)
        # pluggable admission (DESIGN.md §15): FIFO (seed behaviour) or
        # weighted fair share across tenants; the policy probes prefix-hit
        # probability through the radix tree and per-tenant pinned pages
        # through the session-pin accounting below
        self.tenant_pinned_pages: Dict[str, int] = {}
        self.policy = make_policy(
            sc, probe_hit=self.prefix_hit_fraction,
            pinned_pages=lambda t: self.tenant_pinned_pages.get(t, 0))
        # admission-wait distribution (ms): bounded window for p50/p99 —
        # same O(1)-memory pattern as decode_batch_hist
        self._admission_waits = collections.deque(maxlen=2048)
        self._no_progress = 0         # consecutive zero-progress steps
        # speculative decoding (DESIGN.md §16): the proposer is always
        # constructed (cheap, host-only) — per-request SamplingParams can
        # enable speculation even when the engine default is off — and
        # warmed by every completed request so later forks replay their
        # siblings' outputs.  Per-request AdaptiveK controllers back the
        # draft length off when acceptance drops.
        self.proposer = make_proposer(sc)
        self._spec_ctl: Dict[int, AdaptiveK] = {}
        self.spec_steps = 0           # iterations that ran >=1 verify row
        self.spec_proposed = 0        # drafted tokens sent to verification
        self.spec_accepted = 0        # drafted tokens kept
        self.spec_committed = 0       # tokens committed by verify rows
                                      # (accepted + one bonus per row)
        self.peak_base_pages = 0
        self.peak_res_pages = 0
        self.agent_ids_seen = set()
        # step-phase wall-clock totals (ms).  prefill/decode time the
        # executor calls (async dispatch + trace/compile); sync times the
        # blocking device→host reads — ONE per step, not one per chunk —
        # so benchmark deltas are attributable to a phase (DESIGN.md §12)
        self.prefill_ms = 0.0
        self.decode_ms = 0.0
        self.sync_ms = 0.0

    # ------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        req.arrival = time.time() if req.arrival == 0.0 else req.arrival
        self.agent_ids_seen.add(req.adapter_id)
        self.waiting.append(req)

    # -------------------------------------------------------- fork/admit
    def _match(self, req: Request):
        """Prefix-match per policy. Returns (base_pages, res_pages, reuse).

        Matches ``req.ptoks`` (prompt + committed output), not just the
        prompt: a preempted request's checkpointed KV lives in the radix
        tree under exactly that sequence, so restore is an ordinary
        prefix hit — device pages shared directly, host-tier pages
        promoted, evicted spans re-prefilled (DESIGN.md §17)."""
        toks = req.ptoks
        if self.mode == "forkkv":
            fr = self.dual.fork(toks, req.adapter_id, lock=True)
            req.fork = fr
            return list(fr.base_pages), list(fr.res_pages), fr.reuse_len
        if self.mode == "prefix":
            tree = self.forest.tree(req.adapter_id)
            pages, matched, path = tree.match_prefix(toks, lock=True)
            tree.hits_tokens += matched
            tree.miss_tokens += len(toks) - matched
            req.fork = (path, req.adapter_id)
            return list(pages), [], matched
        pages, matched, path = self.tree.match_prefix(toks, lock=True)
        self.tree.hits_tokens += matched
        self.tree.miss_tokens += len(toks) - matched
        req.fork = (path, None)
        return list(pages), [], matched

    def _release_lock(self, req: Request):
        if req.fork is None:
            return
        if self.mode == "forkkv":
            self.dual.release(req.fork, req.adapter_id)
        elif self.mode == "prefix":
            path, aid = req.fork
            self.forest.tree(aid).unlock_path(path)
        else:
            path, _ = req.fork
            self.tree.unlock_path(path)
        req.fork = None

    # ------------------------------------------------------ admission probe
    def prefix_hit_fraction(self, req: Request) -> float:
        """Fraction of ``req.prompt`` the radix cache already covers —
        the admission policy's prefix-hit probability (a request landing
        on warm cache is cheaper; admit it sooner).  Read-only walk: no
        locks taken, no host→device promotion paid (``promote=False``),
        so probing a request never moves bytes."""
        if not req.prompt:
            return 0.0
        if self.mode == "forkkv":
            _, matched, _ = self.dual.base.match_prefix(
                req.prompt, promote=False)
        elif self.mode == "prefix":
            _, matched, _ = self.forest.tree(req.adapter_id).match_prefix(
                req.prompt, promote=False)
        else:
            _, matched, _ = self.tree.match_prefix(req.prompt,
                                                   promote=False)
        return matched / len(req.prompt)

    # ------------------------------------------------------- session pins
    def pin_prefix(self, tokens: Sequence[int], adapter_id: int = 0,
                   tenant: str = "default"):
        """Pin the cached prefix of ``tokens`` against eviction for a
        session's lifetime (DESIGN.md §11).  Distinct from the transient
        per-request locks taken during admission: a pin outlives any one
        request and is released only by :meth:`unpin`.  Returns an opaque
        handle.  ``tenant`` bills the pinned pages against that tenant's
        ``tenant_max_pinned_pages`` admission budget (DESIGN.md §15)."""
        if self.mode == "forkkv":
            inner = self.dual.pin(tokens, adapter_id)
            pages = (sum(len(n.pages) for n in inner[0]) +
                     sum(len(n.pages) for n in inner[1]))
        elif self.mode == "prefix":
            inner = self.forest.pin(adapter_id, tokens)
            pages = sum(len(n.pages) for n in inner[0])
        else:
            inner = self.tree.pin(tokens)
            pages = sum(len(n.pages) for n in inner[0])
        self.tenant_pinned_pages[tenant] = \
            self.tenant_pinned_pages.get(tenant, 0) + pages
        return (self.mode, adapter_id, inner, tenant, pages)

    def unpin(self, handle) -> None:
        mode, adapter_id, inner, tenant, pages = handle
        if mode == "forkkv":
            self.dual.unpin(inner, adapter_id)
        elif mode == "prefix":
            self.forest.unpin(adapter_id, inner[0])
        else:
            self.tree.unpin(inner[0])
        self.tenant_pinned_pages[tenant] = max(
            0, self.tenant_pinned_pages.get(tenant, 0) - pages)

    def _evict(self, pool: PagePool, n: int) -> int:
        tiered = getattr(pool, "is_tiered", False)
        before = pool.demoted_pages if tiered else 0
        if self.mode == "forkkv":
            if pool is self.base_pool:
                freed = self.dual.base.evict(n)
            else:
                freed = self.dual.residual.evict(n)
        elif self.mode == "prefix":
            freed = self.forest.evict(n)
        else:
            freed = self.tree.evict(n)
        if tiered and pool.demoted_pages > before:
            self.preemptions += 1     # cache state pushed out under pressure
        return freed

    def _alloc(self, pool: PagePool, n: int) -> Optional[List[int]]:
        if n == 0:
            return []
        if self.faults.fire("pool_alloc"):
            # injected allocation failure (DESIGN.md §17): indistinguishable
            # from real exhaustion downstream — admission retries, and the
            # preempt trigger fires if the "pressure" persists
            return None
        pages = pool.alloc(n)
        if pages is None:
            self._evict(pool, n - pool.free_pages)
            pages = pool.alloc(n)
        return pages

    def _try_admit(self, req: Request) -> Optional[bool]:
        """Returns True (admitted), False (no memory — retry later) or
        None (rejected outright: the request can never fit)."""
        page = self.sc.page_size
        total_len = len(req.prompt) + req.max_new_tokens
        n_pages = -(-total_len // page)
        if n_pages > self.max_pages_per_req:
            req.state = "done"
            req.finish_reason = "rejected"
            req.error = (f"rejected: request {req.rid} too long "
                         f"({total_len} tokens > "
                         f"{self.max_pages_per_req * page})")
            req.finished_at = time.time()
            return None
        base_pages, res_pages, reuse = self._match(req)
        need_base = n_pages - len(base_pages)
        new_base = self._alloc(self.base_pool, need_base)
        if new_base is None:
            self._release_lock(req)
            return False
        if self.mode == "forkkv":
            # CoW: rCache pages beyond the residual hit are private
            have_res = len(res_pages)
            new_res = self._alloc(self.res_pool, n_pages - have_res)
            if new_res is None:
                self.base_pool.decref(new_base)
                self._release_lock(req)
                return False
            req.owned_res = new_res
            req.res_pages = res_pages + new_res
        req.owned_base = new_base
        req.base_pages = base_pages + new_base
        # resume computing after the usable (both-cache) prefix; for a
        # restored request ptoks extends past the prompt into the
        # generated output, so the uncovered suffix — and ONLY it — is
        # re-prefilled (DESIGN.md §17)
        toks = req.ptoks
        req.prefill_pos = reuse
        # never resume inside a partial page of reused cache
        req.prefill_pos = (req.prefill_pos // page) * page
        req.kv_len = req.prefill_pos
        req.state = "prefill" if req.prefill_pos < len(toks) \
            else "decode"
        if req.state == "decode":
            req.kv_len = len(toks)
        if req.needs_restore:
            req.needs_restore = False
            self.restored += 1
            # checkpointed KV the match did NOT cover must be recomputed
            # (host tier full at preempt time, or evicted since)
            self.recompute_tokens += max(
                0, min(req.preempt_kv, len(toks)) - req.prefill_pos)
        req.admit_output_len = len(req.output)
        return True

    # ------------------------------------------------------------ prefill
    def _page_for(self, req: Request, pos: int, kind: str) -> int:
        pages = req.base_pages if kind == "base" else req.res_pages
        return pages[pos // self.sc.page_size]

    def _write_page_for(self, req: Request, pos: int, kind: str) -> int:
        """CoW: only pages this request owns may be written."""
        page_idx = pos // self.sc.page_size
        pages = req.base_pages if kind == "base" else req.res_pages
        owned = req.owned_base if kind == "base" else req.owned_res
        p = pages[page_idx]
        if p in owned:
            return p
        return self.dump_b if kind == "base" else self.dump_r

    def _prefill_batch(self) -> bool:
        """Batched multi-request prefill: pack co-resident chunks from every
        request in the ``prefill`` state into ONE padded ``(B, chunk)``
        executor call, splitting the ``max_prefill_tokens`` budget across
        the power-of-two-padded batch (B=1 degenerates to the seed's
        single-request chunking, same compiled shape).  One host sync per
        step — and only when some row finished its prompt and needs its
        first token on the host."""
        group = [r for r in self.running if r.state == "prefill"]
        if not group:
            return False
        cap = self.sc.max_prefill_batch or len(group)
        group = group[:max(1, min(cap, self.sc.max_prefill_tokens))]
        # the executor owns the shape policy: one plan drives both the
        # prompt slicing here and the batch padding inside prefill_batch
        _, chunk = self.executor.prefill_plan(len(group))
        chunks, starts, aids, btsb, btsr, wbs, wrs, ends, plens = \
            [], [], [], [], [], [], [], [], []
        temps, tks, tps, seeds, spos = [], [], [], [], []
        for r in group:
            toks = r.ptoks
            plens.append(len(toks))
            start = r.prefill_pos
            end = min(len(toks), start + chunk)
            ends.append(end)
            chunks.append(toks[start:end])
            starts.append(start)
            aids.append(r.adapter_id)
            btsb.append(list(r.base_pages))
            btsr.append(list(r.res_pages) if self.mode == "forkkv" else [])
            wbs.append([self._write_page_for(r, p, "base")
                        for p in range(start, end)])
            wrs.append([self._write_page_for(r, p, "res")
                        for p in range(start, end)]
                       if self.mode == "forkkv"
                       else [self.dump_r] * (end - start))
            sp = r.params
            temps.append(sp.temperature)
            tks.append(sp.top_k)
            tps.append(sp.top_p)
            seeds.append(sp.seed)
            spos.append(len(r.output))
        poison = [1 if self.faults.fire("nan_logits", key=r.rid) else 0
                  for r in group] if self.faults.active else None
        t0 = time.perf_counter()
        next_toks, _, row_ok = self.executor.prefill_batch(
            chunks, starts, aids, btsb, btsr, wbs, wrs, chunk,
            temps=temps, top_ks=tks, top_ps=tps, seeds=seeds, spos=spos,
            poison=poison)
        self.prefill_ms += (time.perf_counter() - t0) * 1e3
        host_toks = host_ok = None
        for i, r in enumerate(group):
            r.prefill_pos = ends[i]
            r.kv_len = ends[i]
            n = len(chunks[i])
            r.prefilled_tokens += n
            r.prefill_share += n
            if ends[i] < plens[i]:
                continue
            if r.max_new_tokens == 0:
                # context-only request (session prefill): the cache is the
                # product — commit it and finish without generating
                self._finish(r, reason="length")
                continue
            if host_toks is None:       # single blocking D2H for the step
                t0 = time.perf_counter()
                host_toks = np.asarray(next_toks)
                host_ok = np.asarray(row_ok)
                self.sync_ms += (time.perf_counter() - t0) * 1e3
            if not bool(host_ok[i]):
                # quarantine (DESIGN.md §17): non-finite logits fail THIS
                # row; co-batched requests proceed untouched
                self._quarantine(r)
                continue
            r.state = "decode"
            if r.output:
                # restored request: its last pre-preemption token was
                # never consumed — the next decode step takes it as
                # input; no new token is emitted here (greedy parity)
                continue
            tok = int(host_toks[i])
            if r.first_token_at == 0.0:
                r.first_token_at = time.time()
            r.output.append(tok)
            r.token_times.append(time.time())
            # the sampled token's KV is not cached yet; it will be written
            # when the decode step consumes it
            if tok in r.params.stop_token_ids:
                self._finish(r, reason="stop")
        return True

    def _bt(self, pages: Sequence[int]) -> List[int]:
        bt = list(pages)[:self.max_pages_per_req]
        dump = self.dump_b
        return bt + [dump] * (self.max_pages_per_req - len(bt))

    def _note_decode_batch(self, n: int) -> None:
        """Record one decode iteration's batch size: bounded window for
        diagnostics + exact running aggregates for the metrics."""
        self.decode_batch_hist.append(n)
        self._decode_batch_sum += n
        self._decode_steps += 1

    # ------------------------------------------- speculative proposals
    def _spec_enabled(self, req: Request) -> bool:
        """Speculate for this request?  Per-request SamplingParams
        override beats the engine default; greedy only (accepted tokens
        must be bit-identical to the sequential stream), and only under
        mixed batching (verify rows ride the unified grid)."""
        sp = req.params
        on = sp.speculate if sp.speculate is not None else self.sc.speculate
        return bool(on) and sp.greedy and self.sc.mixed_batching \
            and not req.is_context

    def _propose(self, req: Request) -> tuple:
        """The scheduler's speculation hook (DESIGN.md §16): up to k
        drafted continuations of the request's tokens, or () for a plain
        decode row.  k is capped by the adaptive controller, the
        remaining generation budget (a verify row commits at most k+1
        tokens) and the request's page allocation (drafted KV must land
        inside its owned pages — the CoW rollback invariant)."""
        if not self._spec_enabled(req):
            return ()
        sp = req.params
        k = sp.spec_k or self.sc.spec_k
        if self.sc.spec_adaptive:
            ctl = self._spec_ctl.get(req.rid)
            if ctl is None:
                ctl = self._spec_ctl[req.rid] = AdaptiveK(k)
            k = min(k, ctl.k)
        k = min(k,
                req.max_new_tokens - len(req.output),
                len(req.base_pages) * self.sc.page_size - req.kv_len - 1)
        if k <= 0:
            return ()
        draft = self.proposer.propose(req.prompt + req.output, k)
        return tuple(draft[:k])

    # ------------------------------------------------------------- decode
    def _decode_all(self) -> bool:
        batch = [r for r in self.running if r.state == "decode"
                 and len(r.output) < r.max_new_tokens + 1]
        batch = batch[:self.sc.max_batch]
        if not batch:
            return False
        self._note_decode_batch(len(batch))
        page = self.sc.page_size
        toks, kvl, ids, btb, btr, wpb, wpr, woff = [], [], [], [], [], [], \
            [], []
        temps, tks, tps, seeds, spos = [], [], [], [], []
        for r in batch:
            last = r.output[-1] if r.output else r.prompt[-1]
            toks.append(last)
            kvl.append(r.kv_len)
            ids.append(r.adapter_id)
            # RAW page lists: the executor owns batch/width bucketing
            btb.append(list(r.base_pages))
            btr.append(list(r.res_pages) if self.mode == "forkkv" else [])
            wpb.append(self._write_page_for(r, r.kv_len, "base"))
            wpr.append(self._write_page_for(r, r.kv_len, "res")
                       if self.mode == "forkkv" else self.dump_r)
            woff.append(r.kv_len % page)
            sp = r.params
            temps.append(sp.temperature)
            tks.append(sp.top_k)
            tps.append(sp.top_p)
            seeds.append(sp.seed)
            spos.append(len(r.output))
        poison = [1 if self.faults.fire("nan_logits", key=r.rid) else 0
                  for r in batch] if self.faults.active else None
        t0 = time.perf_counter()
        next_toks, _, row_ok = self.executor.decode(
            toks, kvl, ids, btb, btr, wpb, wpr, woff, temps=temps,
            top_ks=tks, top_ps=tps, seeds=seeds, spos=spos, poison=poison)
        self.decode_ms += (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        host_toks = np.asarray(next_toks)   # ONE blocking D2H per step
        host_ok = np.asarray(row_ok)        # quarantine guard rides it
        self.sync_ms += (time.perf_counter() - t0) * 1e3
        for i, r in enumerate(batch):
            if not bool(host_ok[i]):
                # quarantine (DESIGN.md §17): this row's logits went
                # non-finite — fail it alone, the batch continues; its
                # kv_len is NOT advanced, so the poisoned write at
                # position kv_len stays uncommitted garbage
                self._quarantine(r)
                continue
            r.kv_len += 1
            tok = int(host_toks[i])
            if r.first_token_at == 0.0:   # fully-cached admission: the
                r.first_token_at = time.time()  # first token is a decode
            r.output.append(tok)
            r.token_times.append(time.time())
            if tok in r.params.stop_token_ids:
                self._finish(r, reason="stop")
            elif len(r.output) >= r.max_new_tokens + 1 or \
                    r.kv_len + 1 >= self.max_pages_per_req * page:
                self._finish(r, reason="length")
        return True

    # ------------------------------------------------------------- finish
    def _commit_cache(self, req: Request) -> None:
        """Insert the request's computed-KV prefix into the radix tree
        (the tree increfs the pages it adopts)."""
        full_seq = req.prompt + req.output[:-1]
        seq = full_seq[:req.kv_len]
        if self.mode == "forkkv":
            self.dual.commit(seq, req.adapter_id,
                             req.base_pages, req.res_pages)
        elif self.mode == "prefix":
            self.forest.insert(req.adapter_id, seq, req.base_pages)
        else:
            self.tree.insert(seq, req.base_pages)

    def _finish(self, req: Request, reason: str = "length",
                commit: bool = True) -> None:
        """``commit=False`` (quarantine / executor-error isolation,
        DESIGN.md §17) skips the tree insert and the proposer warm-up —
        a poisoned request's cache must never be adopted as shared state
        — while still reclaiming every page it owned."""
        req.state = "done"
        req.finish_reason = req.finish_reason or reason
        req.finished_at = time.time()
        if commit:
            self._commit_cache(req)
        # drop this request's ownership; tree holds its own refs now
        self.base_pool.decref(req.owned_base)
        self.base_pool.decref(req.coowned_base)
        if self.mode == "forkkv":
            self.res_pool.decref(req.owned_res)
        self._release_lock(req)
        self.running.remove(req)
        self.done.append(req)
        self._spec_ctl.pop(req.rid, None)
        if commit and req.output and not req.is_context:
            # warm the n-gram cache with the committed sequence so later
            # forks replaying this trajectory get high-acceptance drafts
            self.proposer.observe(req.prompt + req.output[:-1])
        self.policy.on_finish(req, req.finished_at)

    # -------------------------------------------------------- quarantine
    def _quarantine(self, req: Request, why: str = "") -> None:
        """Fail ONE poisoned running request (DESIGN.md §17): terminal
        ``finish_reason="error"``, pages reclaimed, cache NOT committed,
        co-batched requests untouched."""
        self.quarantined += 1
        req.error = why or (
            f"error: request {req.rid} quarantined — non-finite logits "
            f"at step {self.steps}")
        req.finish_reason = "error"
        self._finish(req, reason="error", commit=False)

    def _fail_batch(self, exc: Exception) -> bool:
        """Executor-level exception isolation (DESIGN.md §17): a raising
        step call cannot say which rows' device state survived, so every
        running request fails terminally (``finish_reason="error"``,
        pages reclaimed, nothing committed) and the PUMP SURVIVES —
        waiting requests admit and run on the next step."""
        self.exec_errors += 1
        victims = list(self.running)
        for r in victims:
            r.error = (f"error: request {r.rid} failed — executor error "
                       f"at step {self.steps}: {exc}")
            r.finish_reason = "error"
            self._finish(r, reason="error", commit=False)
        return bool(victims)

    # ---------------------------------------------------- preempt–restore
    def _preempt(self, req: Request) -> None:
        """Checkpoint a running request's computed KV into the radix tree
        and send it back to the waiting queue (DESIGN.md §17).

        The checkpoint IS an ordinary cache commit — the tree adopts the
        full pages covering ``(prompt + output[:-1])[:kv_len]`` — so all
        existing machinery applies unchanged: under continued pressure
        the tree LRU demotes the pages to the host tier (tiered config)
        or destroys them (restore re-prefills = recompute), and
        re-admission restores them via the normal ``_match`` walk.  The
        generated ``output`` is kept: streaming consumers' indices stay
        valid, and ``ptoks`` replays it as prefill on restore."""
        self.preempted += 1
        req.preempt_kv = req.kv_len
        req.needs_restore = True
        if req.kv_len > 0:
            self._commit_cache(req)
        self.base_pool.decref(req.owned_base)
        self.base_pool.decref(req.coowned_base)
        if self.mode == "forkkv":
            self.res_pool.decref(req.owned_res)
        self._release_lock(req)
        self.running.remove(req)
        req.state = "waiting"
        req.prefill_pos = 0
        req.kv_len = 0
        req.base_pages, req.res_pages = [], []
        req.owned_base, req.owned_res, req.coowned_base = [], [], []
        # back of the queue: the blocked request that triggered the
        # preemption gets first claim on the freed pages (front insertion
        # would re-admit the victim immediately — a preempt livelock)
        self.waiting.append(req)
        self.policy.on_preempt(req, time.time())

    def _preempt_for(self, now: float) -> bool:
        """Pick and preempt ONE victim so blocked admission can proceed.

        Candidates: running requests that are not context prefills
        (their session holds pins — evicting them thrashes), not
        broadcast-fork writers (an owned page with refcount > 1 is
        co-owned by the group; preempting the writer would orphan the
        shared pass), and that have emitted at least one NEW token since
        their last admission — without that progress guard, two requests
        that cannot coexist in the pool preempt each other straight out
        of their restore prefills forever (a zero-progress livelock
        ``preempt_after_steps`` only delays).  A protected victim is
        running, so it becomes eligible after its next decode step;
        admission stays blocked at most that long.  Order is the
        admission policy's ``preempt_order`` — worst fair-share score
        first, newest-arrival first under FIFO."""
        cands = [
            r for r in self.running
            if not r.is_context
            and len(r.output) > r.admit_output_len
            and not any(self.base_pool.refcount(p) > 1
                        for p in r.owned_base)]
        for victim in self.policy.preempt_order(cands, now):
            self._preempt(victim)
            return True
        return False

    # --------------------------------------------------------------- drain
    def drain(self) -> None:
        """Graceful drain (DESIGN.md §17): stop admitting, let in-flight
        requests run to completion.  Every queued (never-admitted)
        request is refused with ``finish_reason="draining"`` on the next
        step so callers get a terminal signal (HTTP 503) instead of a
        hang.  Idempotent."""
        self.draining = True

    @property
    def drained(self) -> bool:
        """True once a draining engine holds no in-flight work."""
        return self.draining and not self.running and not self.waiting

    # --------------------------------------------- persist / restore (§18)
    def _persist_trees(self):
        """(executor_kind, adapter, tree) triples covering every radix
        namespace of the current mode."""
        if self.mode == "forkkv":
            out = [("base", None, self.dual.base)]
            out += [("res", aid, t)
                    for aid, t in sorted(self.dual.residual.trees.items())]
            return out
        if self.mode == "prefix":
            return [("base", aid, t)
                    for aid, t in sorted(self.forest.trees.items())]
        return [("base", None, self.tree)]

    def _tree_for_record(self, rec):
        if self.mode == "forkkv":
            return (self.dual.base if rec["kind"] == "base"
                    else self.dual.residual.tree(rec["adapter"]))
        if self.mode == "prefix":
            return self.forest.tree(rec["adapter"])
        return self.tree

    def _node_blobs(self, kind: str, node, pool):
        """Logical (decoded) page blobs of one radix node, whatever tier
        it currently occupies.  Read-only: no refcounts move."""
        if node.tier == "device":
            return self.executor.export_pages(kind, list(node.pages))
        store = pool.disk if node.tier == "disk" else pool.host
        return [pool.codec.decode(store.get(h)) for h in node.pages]

    def persist(self, persist_dir: Optional[str] = None) -> int:
        """Write every cached prefix (all tiers) to ``persist_dir`` as
        blob files + a token-prefix manifest, so a restarted engine can
        :meth:`restore` the shared agent context instead of re-prefilling
        it.  Returns the number of pages persisted.  Blobs are stored
        LOGICAL (decoded), so the restarted server may use a different
        codec.  Best-effort: an unreadable node is skipped, not fatal."""
        d = persist_dir or self.sc.persist_dir
        if not d or self.host_tier is None:
            return 0
        os.makedirs(d, exist_ok=True)
        records = []
        pages_out = 0
        for kind, adapter, tree in self._persist_trees():
            stack = [((), tree.root)]
            while stack:
                prefix, node = stack.pop()
                full = prefix + node.key
                for child in sorted(node.children.values(),
                                    key=lambda c: c.key):
                    stack.append((full, child))
                if node is tree.root or not node.pages:
                    continue
                try:
                    blobs = self._node_blobs(kind, node, tree.pool)
                except Exception:
                    continue        # e.g. injected disk fault: skip node
                merged = {}
                for i, b in enumerate(blobs):
                    for k, v in b.items():
                        merged[f"{i}/{k}"] = v
                fname = f"node_{len(records):06d}.blob"
                write_blob_file(os.path.join(d, fname), merged)
                records.append({"kind": kind, "adapter": adapter,
                                "tokens": [int(t) for t in full],
                                "n_pages": len(blobs), "file": fname})
                pages_out += len(blobs)
        manifest = {"mode": self.mode, "page_size": self.sc.page_size,
                    "records": records}
        tmp = os.path.join(d, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(d, "manifest.json"))
        return pages_out

    def restore(self, persist_dir: Optional[str] = None) -> int:
        """Rehydrate a :meth:`persist` manifest into the radix trees as
        HOST-tier nodes: zero device pages move until a later match
        promotes them (the normal tier-hit path, so restored context
        shows up as ``tier_hits`` instead of re-prefill).  Records come
        parent-first; grafts are best-effort (a full host budget or a
        mode/page-size mismatch skips, never fails the restart).
        Returns the number of pages grafted."""
        d = persist_dir or self.sc.persist_dir
        if not d or self.host_tier is None:
            return 0
        mf = os.path.join(d, "manifest.json")
        if not os.path.exists(mf):
            return 0
        with open(mf) as f:
            doc = json.load(f)
        if doc.get("mode") != self.mode \
                or doc.get("page_size") != self.sc.page_size:
            return 0
        restored = 0
        for rec in doc["records"]:
            try:
                merged = read_blob_file(os.path.join(d, rec["file"]))
            except Exception:
                continue
            blobs = [dict() for _ in range(rec["n_pages"])]
            for k, v in merged.items():
                i, _, key = k.partition("/")
                blobs[int(i)][key] = v
            tree = self._tree_for_record(rec)
            restored += tree.graft_host(rec["tokens"], blobs)
        self.restored_pages += restored
        return restored

    # ------------------------------------------------- broadcast fork
    def _try_broadcast(self) -> bool:
        """Beyond-paper (DESIGN.md §9): when several forkkv agents are at
        the SAME position of an identical upcoming chunk (MapReduce-style
        parallel forks), run ONE base-trajectory prefill emitting all their
        rCaches, and share the writer's new bCache pages (CoW incref)."""
        if self.mode != "forkkv" or not self.sc.broadcast_fork:
            return False
        page = self.sc.page_size
        groups: Dict = {}
        for r in self.running:
            if r.state != "prefill":
                continue
            toks = r.ptoks
            end = min(len(toks),
                      r.prefill_pos + self.sc.max_prefill_tokens)
            end = (end // page) * page
            if end >= len(toks):
                # leave the final tokens to an ordinary per-request prefill:
                # the broadcast pass emits no logits, so the request's first
                # output token must come from a real chunk ending at the
                # prompt's last token — not from an empty follow-up chunk
                end -= page
            if end <= r.prefill_pos:
                continue
            key = (r.prefill_pos, tuple(toks[r.prefill_pos:end]))
            groups.setdefault(key, []).append(r)
        best = max(groups.items(), key=lambda kv: len(kv[1]),
                   default=(None, []))
        key, group = best
        if len(group) < 2:
            return False
        start = key[0]
        chunk = list(key[1])
        end = start + len(chunk)
        writer = group[0]
        p0, p1 = start // page, end // page
        for r in group[1:]:
            for i in range(p0, p1):
                wp = writer.base_pages[i]
                old = r.base_pages[i]
                if old == wp:
                    continue
                if old in r.owned_base:
                    r.owned_base.remove(old)
                    self.base_pool.decref([old])
                r.base_pages[i] = wp
                self.base_pool.incref([wp])
                r.coowned_base.append(wp)
        bt_b = self._bt(writer.base_pages)
        wb = [self._write_page_for(writer, p, "base")
              for p in range(start, end)]
        wr_list = [[self._write_page_for(r, p, "res")
                    for p in range(start, end)] for r in group]
        self.executor.prefill_broadcast(
            chunk, start, [r.adapter_id for r in group], bt_b, wb, wr_list,
            self.sc.max_prefill_tokens)
        for r in group:
            r.prefill_pos = end
            r.kv_len = end
            # amortized share for metrics; the EXACT int counter attributes
            # the single shared pass to its writer (keeps the counter an
            # int — the seed float-crept it via len(chunk)/len(group))
            r.prefill_share += len(chunk) / len(group)
        writer.prefilled_tokens += len(chunk)
        return True

    # -------------------------------------------------- mixed iteration
    def _run_mixed(self, plan: BatchPlan) -> bool:
        """Execute one iteration-level batch plan (DESIGN.md §14) as a
        SINGLE mixed executor call: decode rows carry their last sampled
        token (q=1), prefill rows their next prompt chunk.  Rows that
        will not emit a token this iteration (mid-prompt chunks,
        context-only requests) get neutral sampling params so an
        all-greedy emitting set still compiles the argmax-only body; the
        one host sync happens only when some row emits."""
        rows = plan.rows
        if not rows:
            return False
        page = self.sc.page_size
        chunks, starts, aids, btb, btr, wbs, wrs = [], [], [], [], [], \
            [], []
        temps, tks, tps, seeds, spos = [], [], [], [], []
        emit = []
        for rp in rows:
            r = rp.req
            if rp.kind == "decode":
                chunks.append([r.output[-1] if r.output else r.prompt[-1]])
                emit.append(True)
            elif rp.kind == "verify":
                # speculative row (§16): last sampled token + the drafts;
                # drafted KV lands at [kv_len, kv_len+k) — positions the
                # page-aligned radix invariants place in request-OWNED
                # pages, so a rejected draft is private garbage the next
                # step overwrites (rollback = nothing to do)
                last = r.output[-1] if r.output else r.prompt[-1]
                chunks.append([last] + list(rp.draft))
                emit.append(True)
            else:
                toks = r.ptoks
                chunks.append(toks[rp.start:rp.end])
                # a restored request emits nothing on prefill completion:
                # its last pre-preemption token is the next decode input
                emit.append(rp.end >= len(toks)
                            and r.max_new_tokens > 0 and not r.output)
            starts.append(rp.start)
            aids.append(r.adapter_id)
            btb.append(list(r.base_pages))
            btr.append(list(r.res_pages) if self.mode == "forkkv" else [])
            wbs.append([self._write_page_for(r, p, "base")
                        for p in range(rp.start, rp.end)])
            wrs.append([self._write_page_for(r, p, "res")
                        for p in range(rp.start, rp.end)]
                       if self.mode == "forkkv"
                       else [self.dump_r] * rp.q_len)
            sp = r.params
            if emit[-1]:
                temps.append(sp.temperature)
                tks.append(sp.top_k)
                tps.append(sp.top_p)
                seeds.append(sp.seed)
                spos.append(len(r.output))
            else:                   # non-emitting row: neutral params so
                temps.append(0.0)   # ``sampled`` tracks EMITTING rows only
                tks.append(0)
                tps.append(1.0)
                seeds.append(0)
                spos.append(0)
        verify_rows = plan.verify_rows
        n_decode = len(plan.decode_rows) + len(verify_rows)
        if plan.is_mixed:
            self.mixed_steps += 1
        poison = [1 if self.faults.fire("nan_logits", key=rp.req.rid)
                  else 0 for rp in rows] if self.faults.active else None
        t0 = time.perf_counter()
        if verify_rows:
            self.spec_steps += 1
            # verify-only plans pad the q tile to pow2(k+1), not the
            # 32-wide prefill tile — the verify call must stay close to a
            # decode call's cost for speculation to pay off
            qfloor = plan.q_max if not plan.prefill_rows else 0
            next_toks, _, greedy_all, n_acc, row_ok = \
                self.executor.mixed_step(
                    chunks, starts, aids, btb, btr, wbs, wrs, temps=temps,
                    top_ks=tks, top_ps=tps, seeds=seeds, spos=spos,
                    poison=poison, verify=True, qfloor=qfloor)
        else:
            greedy_all = n_acc = None
            next_toks, _, row_ok = self.executor.mixed_step(
                chunks, starts, aids, btb, btr, wbs, wrs, temps=temps,
                top_ks=tks, top_ps=tps, seeds=seeds, spos=spos,
                poison=poison)
        elapsed = (time.perf_counter() - t0) * 1e3
        # attribute wall clock by token share: a decode-only iteration is
        # pure decode_ms (bench_decode's deltas stay meaningful), a mixed
        # one splits proportionally (verify rows count as decode work)
        dec_toks = sum(rp.q_len for rp in rows if rp.kind != "prefill")
        dec_frac = dec_toks / max(1, plan.total_tokens)
        self.decode_ms += elapsed * dec_frac
        self.prefill_ms += elapsed * (1.0 - dec_frac)
        host_toks = greedy_host = nacc_host = host_ok = None
        if any(emit):               # ONE blocking D2H per iteration
            t0 = time.perf_counter()
            host_toks = np.asarray(next_toks)
            host_ok = np.asarray(row_ok)   # quarantine guard rides the
            if verify_rows:                # step's one sync (§17)
                greedy_host = np.asarray(greedy_all)
                nacc_host = np.asarray(n_acc)
            self.sync_ms += (time.perf_counter() - t0) * 1e3
        if n_decode:
            self._note_decode_batch(n_decode)
        step_end = time.time()
        for i, rp in enumerate(rows):
            r = rp.req
            if emit[i] and host_ok is not None and not bool(host_ok[i]):
                # quarantine (DESIGN.md §17): this row went non-finite —
                # fail it alone (kv_len untouched, nothing committed);
                # every other row of the plan proceeds normally
                self._quarantine(r)
                continue
            if rp.kind == "verify":
                # commit the accepted prefix + the bonus correction token
                # (greedy_all[n_acc] is computed from a fully accepted
                # input prefix, so it is the true greedy continuation);
                # one token at a time, mirroring the decode commit so
                # stop/length semantics stay bit-identical
                k = rp.q_len - 1
                n_ok = int(nacc_host[i])
                committed = [int(t) for t in greedy_host[i, :n_ok + 1]]
                r.spec_proposed += k
                r.spec_accepted += n_ok
                self.spec_proposed += k
                self.spec_accepted += n_ok
                self.spec_committed += len(committed)
                ctl = self._spec_ctl.get(r.rid)
                if ctl is not None:
                    ctl.update(k, n_ok)
                # interpolate per-token stamps across the step's wall
                # clock (multi-token-safe TPOT/streaming)
                dt = (elapsed / 1e3) / len(committed)
                for j, tok in enumerate(committed):
                    r.kv_len += 1
                    ts = step_end - dt * (len(committed) - 1 - j)
                    if r.first_token_at == 0.0:
                        r.first_token_at = ts
                    r.output.append(tok)
                    r.token_times.append(ts)
                    if tok in r.params.stop_token_ids:
                        self._finish(r, reason="stop")
                        break
                    if len(r.output) >= r.max_new_tokens + 1 or \
                            r.kv_len + 1 >= self.max_pages_per_req * page:
                        self._finish(r, reason="length")
                        break
                continue
            if rp.kind == "decode":
                r.kv_len += 1
                tok = int(host_toks[i])
                if r.first_token_at == 0.0:
                    r.first_token_at = step_end
                r.output.append(tok)
                r.token_times.append(step_end)
                if tok in r.params.stop_token_ids:
                    self._finish(r, reason="stop")
                elif len(r.output) >= r.max_new_tokens + 1 or \
                        r.kv_len + 1 >= self.max_pages_per_req * page:
                    self._finish(r, reason="length")
                continue
            # prefill row
            r.prefill_pos = rp.end
            r.kv_len = rp.end
            r.prefilled_tokens += rp.q_len
            r.prefill_share += rp.q_len
            if rp.end < len(r.ptoks):
                continue
            if r.max_new_tokens == 0:
                # context-only request: the cache is the product
                self._finish(r, reason="length")
                continue
            r.state = "decode"
            if r.output:
                # restored request (emit was False): the next decode step
                # consumes its last pre-preemption token — nothing lands
                continue
            tok = int(host_toks[i])
            if r.first_token_at == 0.0:
                r.first_token_at = step_end
            r.output.append(tok)
            r.token_times.append(step_end)
            if tok in r.params.stop_token_ids:
                self._finish(r, reason="stop")
        return True

    # ----------------------------------------------------- refuse helpers
    def _refuse(self, req: Request, reason: str, error: str,
                retry_after: float = 0.0, timeout: bool = False) -> None:
        """Finish a never-admitted waiting request (reject/shed/timeout)."""
        req.state = "done"
        req.finish_reason = reason
        req.error = error
        req.retry_after_s = retry_after
        req.finished_at = time.time()
        self.done.append(req)
        self.policy.on_reject(req, req.finished_at, timeout=timeout)

    def _expire_and_shed(self, now: float) -> bool:
        """Deadline sweep + overload shedding over the waiting queue
        (DESIGN.md §15).  Deadlines apply under EVERY policy: a request
        still waiting ``deadline_s`` after arrival finishes with
        ``finish_reason="timeout"`` instead of queueing forever.  The
        policy then names overload victims (queue depth / wait bounds),
        finished as ``rejected`` with a retry-after hint."""
        progress = False
        for req in [r for r in self.waiting
                    if r.deadline_s > 0 and now - r.arrival > r.deadline_s]:
            self.waiting.remove(req)
            self._refuse(req, "timeout",
                         f"timeout: request {req.rid} waited "
                         f"{now - req.arrival:.3f}s > deadline "
                         f"{req.deadline_s:.3f}s", timeout=True)
            self.timeouts += 1
            progress = True
        for req, retry_after in self.policy.shed(self.waiting, now):
            self.waiting.remove(req)
            self._refuse(req, "rejected",
                         f"rejected: overloaded (queue depth "
                         f"{len(self.waiting) + 1}, tenant {req.tenant}); "
                         f"retry after {retry_after:.1f}s",
                         retry_after=retry_after)
            self.rejected += 1
            self.shed += 1
            progress = True
        return progress

    # --------------------------------------------------------------- step
    def step(self) -> None:
        self.steps += 1
        now = time.time()
        self.faults.maybe_stall()       # pump_stall site (watchdog food)
        progress = False
        if self.draining:
            # drain (§17): stop admission — every queued request gets a
            # terminal refusal (HTTP 503) while in-flight work proceeds
            for req in list(self.waiting):
                self.waiting.remove(req)
                self._refuse(req, "draining",
                             f"draining: request {req.rid} refused — "
                             f"server is shutting down")
                progress = True
        else:
            progress = self._expire_and_shed(now)
        # admit, in policy order (FIFO = the seed behaviour: strict
        # arrival order, stop at the first request that does not fit)
        blocked = False
        while self.waiting and len(self.running) < self.sc.max_batch:
            req = self.policy.select(self.waiting, now)
            if req is None:               # every waiting tenant over budget
                break
            try:
                admitted = self._try_admit(req)
            except Exception as e:        # per-request isolation (§17): a
                self.exec_errors += 1     # blown admission fails ONE
                self.waiting.remove(req)  # request, not the pump
                self._refuse(req, "error",
                             f"error: admission of request {req.rid} "
                             f"failed: {e}")
                progress = True
                continue
            if admitted is None:          # impossible request: reject, keep
                self.waiting.remove(req)  # the engine alive for the rest
                self.done.append(req)     # (_try_admit already finished it)
                self.policy.on_reject(req, now)
                self.rejected += 1
                progress = True
                continue
            if not admitted:
                blocked = True
                break
            self.waiting.remove(req)
            self.running.append(req)
            req.admitted_at = time.time()
            self._admission_waits.append(
                (req.admitted_at - req.arrival) * 1e3)
            self.policy.on_admit(req, req.admitted_at)
            progress = True
            if req.state == "decode" and req.max_new_tokens == 0:
                # fully-cached context-only request: nothing to compute
                self._finish(req, reason="length")
        # preempt–restore trigger (§17): admission blocked on pages for
        # preempt_after_steps consecutive steps → checkpoint one victim
        if blocked and self.sc.preempt:
            self._no_admit += 1
            if self._no_admit >= self.sc.preempt_after_steps and \
                    self._preempt_for(now):
                self._no_admit = 0
                progress = True
        elif not blocked:
            self._no_admit = 0
        try:
            self.faults.io("executor")    # injected step failure (§17)
            if self.sc.mixed_batching:
                # iteration-level continuous batching (§14): broadcast-
                # fork groups still take precedence (ONE shared base-
                # trajectory pass), then one token-budget plan — all
                # runnable decode rows + budget-filling prefill chunks —
                # runs as one call
                if self._try_broadcast():
                    progress = True
                if self._run_mixed(self.scheduler.plan(
                        self.running, propose=self._propose)):
                    progress = True
            else:
                # legacy phase-separated loop: one batched prefill call
                # (broadcast if several agents share an identical upcoming
                # chunk), then one decode call
                if self._try_broadcast():
                    progress = True
                elif self._prefill_batch():
                    progress = True
                if self._decode_all():
                    progress = True
        except Exception as e:
            # executor isolation (§17): the step call died — fail the
            # affected requests terminally, keep the pump alive
            if self._fail_batch(e):
                progress = True
        # stall detection: waiting work + nothing admitted/prefilled/decoded
        # for stall_limit consecutive steps -> fail the head request loudly
        # instead of silently burning the caller's step budget
        if self.waiting and not progress:
            self._no_progress += 1
            if self._no_progress >= self.sc.stall_limit:
                head = self.waiting.pop(0)
                head.state = "done"
                head.finish_reason = "stalled"
                head.error = (
                    f"stalled: request {head.rid} made no progress for "
                    f"{self._no_progress} steps (pool too small or cache "
                    f"pinned beyond its needs: {self.base_pool.free_pages} "
                    f"base pages free)")
                head.finished_at = time.time()
                self.done.append(head)
                self.policy.on_reject(head, head.finished_at)
                self.stalled += 1
                self._no_progress = 0
        else:
            self._no_progress = 0
        self.peak_base_pages = max(self.peak_base_pages,
                                   self.base_pool.used_pages)
        self.peak_res_pages = max(self.peak_res_pages,
                                  self.res_pool.used_pages)
        self.last_step_at = time.time()   # watchdog heartbeat (§17)

    def run(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                break
            self.step()

    # ------------------------------------------------------------ metrics
    def metrics(self) -> Dict:
        pb = pool_bytes(self.executor.pools)
        page = self.sc.page_size
        base_bytes_page = pb["base"] / self.sc.max_pages
        res_bytes_page = (pb["residual"] / self.executor.num_res_pages
                          if pb["residual"] else 0)
        n_agents = max(1, len(self.agent_ids_seen))
        used_bytes = (self.peak_base_pages * base_bytes_page +
                      self.peak_res_pages * res_bytes_page)
        hit = miss = 0
        hit_kinds = {}
        evicted = 0
        if self.mode == "forkkv":
            hit = self.dual.base.hits_tokens
            miss = self.dual.base.miss_tokens
            hit_kinds = dict(self.dual.hit_kinds)
            evicted = (self.dual.base.evicted_pages +
                       self.dual.residual.evicted_pages)
        elif self.mode == "prefix":
            for t in self.forest.trees.values():
                hit += t.hits_tokens
                miss += t.miss_tokens
            evicted = self.forest.evicted_pages
        else:
            hit = self.tree.hits_tokens
            miss = self.tree.miss_tokens
            evicted = self.tree.evicted_pages
        # amortized shares (broadcast splits its one pass across the group);
        # the exact per-request int lives in Request.prefilled_tokens
        prefilled = sum(r.prefill_share for r in self.done)
        prompt_tokens = sum(len(r.prompt) for r in self.done
                            if not r.error)
        tier = {"tier_hits": 0, "disk_hits": 0, "demoted_pages": 0,
                "demoted_bytes": 0, "promoted_pages": 0,
                "promoted_bytes": 0, "spilled_pages": 0,
                "host_evicted_pages": 0, "disk_evicted_pages": 0,
                "dropped_device_pages": 0, "tier_io_errors": 0,
                "codec_logical_bytes": 0, "codec_stored_bytes": 0}
        for pool in (self.base_pool, self.res_pool):
            if getattr(pool, "is_tiered", False):
                for k, v in pool.stats().items():
                    if k in tier:
                        tier[k] += v
        # device cache destroyed by host-LRU cascades is real eviction too
        evicted += tier["dropped_device_pages"]
        tier["host_used_bytes"] = (self.host_tier.used_bytes
                                   if self.host_tier else 0)
        # stored (post-codec) host bytes and the achieved ratio (§18):
        # host_used_bytes IS compressed occupancy now that the budget
        # accounts stored sizes — mirrored under the explicit name too
        tier["host_compressed_bytes"] = tier["host_used_bytes"]
        tier["compression_ratio"] = (
            tier["codec_logical_bytes"] / tier["codec_stored_bytes"]
            if tier["codec_stored_bytes"] else 1.0)
        tier["disk_used_bytes"] = (self.disk_tier.used_bytes
                                   if self.disk_tier else 0)
        tier["kv_codec"] = self.kv_codec.name if self.host_tier else "none"
        tier["restored_pages"] = self.restored_pages
        # per-request latency aggregates (satellite, §14): TTFT from
        # arrival to first output token, TPOT the mean gap after it —
        # over finished generating requests only
        lat = [r for r in self.done
               if not r.is_context and r.first_token_at > 0.0]
        ttfts = sorted((r.first_token_at - r.arrival) * 1e3 for r in lat)

        def _tpot_ms(r):
            # per-token stamps (interpolated across multi-token verify
            # commits) give the honest inter-token gap; fall back to the
            # old span/(n-1) estimate for requests without stamps
            if len(r.token_times) >= 2:
                return ((r.token_times[-1] - r.token_times[0]) * 1e3 /
                        (len(r.token_times) - 1))
            return ((r.finished_at - r.first_token_at) * 1e3 /
                    max(1, len(r.output) - 1))

        tpots = sorted(_tpot_ms(r) for r in lat)

        _pct = percentile

        return {
            **tier,
            "mode": self.mode,
            "tasks_done": len([r for r in self.done if not r.is_context]),
            "context_prefills": len([r for r in self.done if r.is_context]),
            "steps": self.steps,
            "mixed_batching": self.sc.mixed_batching,
            "mixed_steps": self.mixed_steps,
            "iteration_token_budget": self.scheduler.budget,
            "ttft_mean_ms": sum(ttfts) / max(1, len(ttfts)),
            "ttft_p50_ms": _pct(ttfts, 0.50),
            "ttft_p99_ms": _pct(ttfts, 0.99),
            "tpot_mean_ms": sum(tpots) / max(1, len(tpots)),
            "tpot_p50_ms": _pct(tpots, 0.50),
            "tpot_p99_ms": _pct(tpots, 0.99),
            "avg_decode_batch": (self._decode_batch_sum /
                                 max(1, self._decode_steps)),
            "peak_base_pages": self.peak_base_pages,
            "peak_res_pages": self.peak_res_pages,
            "peak_cache_bytes": used_bytes,
            "bytes_per_agent": used_bytes / n_agents,
            "prefilled_tokens": prefilled,
            "prompt_tokens": prompt_tokens,
            "prefill_saved_frac": 1 - prefilled / max(1, prompt_tokens),
            "hit_tokens": hit,
            "miss_tokens": miss,
            "hit_rate": hit / max(1, hit + miss),
            "hit_kinds": hit_kinds,
            "evicted_pages": evicted,
            "preemptions": self.preemptions,
            "rejected": self.rejected,
            "stalled": self.stalled,
            # fault tolerance (DESIGN.md §17): preempt–restore accounting,
            # quarantine/isolation counters, drain + watchdog state, and
            # which injected fault sites actually fired (empty plan = {})
            "preempted_requests": self.preempted,
            "restored_requests": self.restored,
            "recompute_tokens": self.recompute_tokens,
            "quarantined": self.quarantined,
            "exec_errors": self.exec_errors,
            "watchdog_trips": self.watchdog_trips,
            "draining": self.draining,
            "drained": self.drained,
            "faults_fired": self.faults.stats(),
            # multi-tenant admission (DESIGN.md §15): live queue state,
            # admission-wait distribution over a bounded recent window,
            # and per-tenant accept/reject/budget accounting
            "admission": self.policy.name,
            "queue_depth": len(self.waiting),
            "admission_wait_p50_ms": _pct(sorted(self._admission_waits),
                                          0.50),
            "admission_wait_p99_ms": _pct(sorted(self._admission_waits),
                                          0.99),
            "timeouts": self.timeouts,
            "shed": self.shed,
            "tenants": self.policy.snapshot(),
            "tenant_pinned_pages": dict(self.tenant_pinned_pages),
            # step-phase wall clock + compiled-variant probe (DESIGN.md §12)
            "prefill_ms": self.prefill_ms,
            "decode_ms": self.decode_ms,
            "sync_ms": self.sync_ms,
            "decode_steps": self._decode_steps,
            "decode_jit_variants": self.executor.decode_cache_size(),
            "use_paged_kernel": self.executor.use_paged,
            # executor calls that took a legacy gather-to-contiguous path
            # (0 whenever use_paged_kernel=True — regression-gated by the
            # parity matrix, DESIGN.md §13)
            "fallback_gather_calls": self.executor.fallback_gather_calls,
            # speculative decoding (DESIGN.md §16): proposer throughput,
            # acceptance, and how many iterations carried verify rows
            "speculate": self.sc.speculate,
            "spec_proposer": self.proposer.name,
            "spec_steps": self.spec_steps,
            "spec_step_share": self.spec_steps / max(1, self.steps),
            "spec_proposed_tokens": self.spec_proposed,
            "spec_accepted_tokens": self.spec_accepted,
            "spec_committed_tokens": self.spec_committed,
            "spec_acceptance_rate": (self.spec_accepted /
                                     max(1, self.spec_proposed)),
        }
