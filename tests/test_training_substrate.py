"""Training substrate: optimizers, data pipeline, checkpointing, LoRA FT."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import LoRAConfig, ModelConfig
from repro.models.registry import get_model
from repro.training import checkpoint, data, train_loop


def tiny_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                dtype="float32", lora=LoRAConfig(rank=8), remat=True)
    base.update(kw)
    return ModelConfig(**base)


def _run_steps(cfg, n=25, accum=1):
    init, step = train_loop.make_train_step(cfg, lr=1e-3, accum_steps=accum)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    opt = init(params)
    jstep = jax.jit(step)
    losses = []
    for _, b in zip(range(n), data.make_stream(cfg.vocab_size, 32, 8)):
        params, opt, m = jstep(params, opt,
                               {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    return losses, params


def test_adamw_loss_decreases():
    losses, _ = _run_steps(tiny_cfg())
    assert losses[-1] < losses[0]


def test_adafactor_loss_decreases():
    losses, _ = _run_steps(tiny_cfg(optimizer="adafactor"))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_grad_accumulation_matches_full_batch():
    """accum=2 over batch 8 must equal accum=1 with the same data/params."""
    cfg = tiny_cfg(remat=False)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = next(iter(data.make_stream(cfg.vocab_size, 32, 8)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    outs = []
    for accum in (1, 2):
        init, step = train_loop.make_train_step(cfg, lr=1e-3,
                                                accum_steps=accum)
        opt = init(params)
        p2, _, m = jax.jit(step)(params, opt, batch)
        outs.append((float(m["loss"]),
                     np.asarray(jax.tree_util.tree_leaves(p2)[0])))
    assert abs(outs[0][0] - outs[1][0]) < 1e-5
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-4, atol=1e-5)


def test_lora_finetune_trains_only_adapters():
    cfg = tiny_cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    lora = api.init_lora_stacks(jax.random.PRNGKey(1), 2)
    init, step = train_loop.make_lora_train_step(cfg, lr=5e-3, adapter_id=1)
    opt = init(lora)
    jstep = jax.jit(step)
    p_before = np.asarray(jax.tree_util.tree_leaves(params)[0]).copy()
    losses = []
    for _, b in zip(range(15), data.make_stream(cfg.vocab_size, 32, 8,
                                                task_id=3)):
        lora, opt, m = jstep(lora, opt, params,
                             {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    np.testing.assert_array_equal(
        p_before, np.asarray(jax.tree_util.tree_leaves(params)[0]))


def test_data_pipeline_deterministic_and_sharded():
    full = data.make_stream(256, 16, 8, seed=7)
    b_full = next(iter(full))
    shards = [next(iter(data.make_stream(256, 16, 8, seed=7, shard_index=i,
                                         num_shards=4)))
              for i in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    again = next(iter(data.make_stream(256, 16, 8, seed=7)))
    np.testing.assert_array_equal(b_full["tokens"], again["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    checkpoint.save(params, str(tmp_path), "m")
    assert checkpoint.exists(str(tmp_path), "m")
    restored = checkpoint.restore(params, str(tmp_path), "m")
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
