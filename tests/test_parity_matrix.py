"""Cross-mode parity test matrix — the canonical tier-1 serving gate.

One parametrized greedy token-parity suite over

    {forkkv, prefix, full_reuse} x {paged, gather} x {dense, GQA, MQA, SWA}
                                 x {mixed, phase-separated}

through the public ``ForkServer`` API, replacing the ad-hoc per-PR parity
tests (PR 2's forkkv-vs-prefix check, PR 3's paged-vs-gather check): for
every serve mode and attention flavour, the page-native kernels
(decode AND chunked prefill, DESIGN.md §12/§13) must produce bit-identical
greedy tokens to the legacy gather-to-contiguous oracle path — and the
paged path must issue ZERO gather-to-contiguous copies, asserted via the
``fallback_gather_calls`` metric (the regression guard that SWA models can
never silently fall back again).

The ``mixed`` axis (DESIGN.md §14) is this matrix's iteration-level
continuous-batching gate: ``mixed_batching=True`` (the default — one
token-budget plan per step, decode + prefill rows through the unified
kernel grid) must produce the same greedy tokens as the legacy
phase-separated step loop, and the workload staggers its forks so at
least one iteration REALLY mixes decode and prefill rows
(``mixed_steps >= 1`` — without the stagger the parity would be vacuous).

Backends: the suite runs under whichever kernel backend
``FORKKV_KERNEL_BACKEND`` / ``REPRO_ATTN_BACKEND`` selects (CI runs it
once with ``ref`` and once with ``pallas-interpret``).
"""
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams

import jax

PAGE = 16

# attention flavours: MHA, grouped-query, multi-query, sliding-window.
# The SWA window (24) deliberately straddles a page boundary and is
# shorter than the 40-token shared context, so out-of-window masking and
# the window-clamped page walk are both exercised.
ARCHS = {
    "dense": dict(num_heads=4, num_kv_heads=4),
    "gqa": dict(num_heads=8, num_kv_heads=2),
    "mqa": dict(num_heads=4, num_kv_heads=1),
    "swa": dict(num_heads=4, num_kv_heads=2, sliding_window=24),
}
MODES = ("forkkv", "prefix", "full_reuse")


@pytest.fixture(scope="module")
def models():
    """Lazily-built (cfg, params, lora) per attention flavour."""
    cache = {}

    def get(arch: str):
        if arch not in cache:
            cfg = tiny_serving_model(rank=8, num_layers=2, d_model=128,
                                     vocab_size=512, **ARCHS[arch])
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1),
                                        n_adapters=4)
            cache[arch] = (cfg, params, lora)
        return cache[arch]

    return get


def run_workload(model, mode: str, paged: bool, mixed: bool = True):
    """The shared workload: one pinned session context, two CoW forks
    under different adapters, greedy decode.  Deterministic in everything
    but the (mode, paged, mixed, arch) cell under test.

    The forks are STAGGERED — the second is submitted only after a few
    polls, while the first is mid-decode — so the iteration scheduler
    must overlap one request's decode rows with the other's prefill
    chunks in the same plan (the mixed-grid case the §14 refactor
    exists for; legacy phase separation serves the exact same schedule
    through its two per-step calls)."""
    cfg, params, lora = model
    sc = ServeConfig(page_size=PAGE, max_pages=96, max_batch=4,
                     max_prefill_tokens=48, max_pages_per_req=8,
                     mode=mode, use_paged_kernel=paged,
                     mixed_batching=mixed)
    server = ForkServer(cfg, params, lora, sc)
    rng = np.random.default_rng(7)
    ctx = list(rng.integers(0, cfg.vocab_size, 40))
    with server.session(ctx, adapter_id=0) as sess:
        handles = [sess.fork(1, list(rng.integers(0, cfg.vocab_size, 5)),
                             SamplingParams(max_new_tokens=5))]
        for _ in range(3):       # first fork reaches decode...
            server.poll()
        handles.append(
            sess.fork(2, list(rng.integers(0, cfg.vocab_size, 6)),
                      SamplingParams(max_new_tokens=5)))
        outs = [o.tokens for o in server.wait(handles)]
    return outs, server.metrics()


# each (arch, mode, paged, mixed) cell is deterministic, and several test
# parametrizations share cells — memoize so the matrix costs one run per
# distinct cell instead of re-serving the workload per assertion
_CELLS = {}


def cell(models, arch: str, mode: str, paged: bool, mixed: bool):
    key = (arch, mode, paged, mixed)
    if key not in _CELLS:
        _CELLS[key] = run_workload(models(arch), mode, paged, mixed)
    return _CELLS[key]


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mode", MODES)
def test_paged_vs_gather_token_parity(models, mode, arch):
    """Greedy outputs must be token-identical between the page-native
    kernels and the legacy gather path — same workload, same session/fork
    calls, only ``ServeConfig.use_paged_kernel`` flipped — and the paged
    run must never gather: ``fallback_gather_calls == 0``.  Runs under
    the mixed-batching default, so the unified grid is what's gated."""
    paged_out, paged_m = cell(models, arch, mode, paged=True, mixed=True)
    gather_out, gather_m = cell(models, arch, mode, paged=False,
                                mixed=True)
    assert all(len(t) == 5 for t in paged_out)
    assert paged_out == gather_out

    # the paged path is fully page-native — SWA included, no silent
    # fallback (the PR-5 regression guard)
    assert paged_m["use_paged_kernel"] is True
    assert paged_m["fallback_gather_calls"] == 0
    # and the gather path is VISIBLE from day one: every prefill/decode
    # executor call shows up in the metric
    assert gather_m["use_paged_kernel"] is False
    assert gather_m["fallback_gather_calls"] > 0


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mode", MODES)
def test_mixed_vs_phase_separated_token_parity(models, mode, arch):
    """The §14 gate: iteration-level continuous batching (the default)
    must generate the same greedy tokens as the legacy phase-separated
    step loop — same staggered workload, only
    ``ServeConfig.mixed_batching`` flipped — while REALLY mixing decode
    and prefill rows in at least one iteration, still without a single
    gather fallback."""
    mixed_out, mixed_m = cell(models, arch, mode, paged=True, mixed=True)
    legacy_out, legacy_m = cell(models, arch, mode, paged=True,
                                mixed=False)
    assert all(len(t) == 5 for t in mixed_out)
    assert mixed_out == legacy_out

    assert mixed_m["mixed_batching"] is True
    # the stagger guarantees overlap: without this the parity above would
    # only ever exercise pure-prefill / pure-decode plans
    assert mixed_m["mixed_steps"] >= 1
    assert mixed_m["fallback_gather_calls"] == 0
    assert legacy_m["mixed_batching"] is False
    assert legacy_m["mixed_steps"] == 0
    assert legacy_m["fallback_gather_calls"] == 0
