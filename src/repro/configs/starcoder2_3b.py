"""starcoder2-3b [dense]: GQA kv=2, RoPE, GELU MLP. [arXiv:2402.19173]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense", num_layers=30, d_model=3072,
    num_heads=24, num_kv_heads=2, d_ff=12288, vocab_size=49152,
    mlp_activation="gelu", lora=LoRAConfig(rank=16), scan_layers=True,
    citation="arXiv:2402.19173")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="starcoder2-tiny", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        dtype="float32", remat=False)
