"""End-to-end driver: serve ReAct and MapReduce agent workflows with the
ForkKV engine and compare the three cache-sharing policies (paper Fig. 11).

Run:  PYTHONPATH=src python examples/multi_agent_serving.py [--fast]
"""
import argparse
import os
import sys

# repo root on the path so ``benchmarks.common`` resolves no matter where
# the script is launched from
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import run_workflow   # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
args = ap.parse_args()

n_wf = 1 if args.fast else 2
print(f"{'policy':12s} {'workflow':10s} {'tasks/s':>8s} {'hit%':>6s} "
      f"{'peakMB':>7s} {'batch':>6s}")
for workflow in ("react", "mapreduce"):
    for mode in ("forkkv", "prefix", "full_reuse"):
        rep = run_workflow(mode, workflow, n_workflows=n_wf, agents=3,
                           context=256, max_new=6, max_pages=192)
        print(f"{mode:12s} {workflow:10s} "
              f"{rep['tasks']/rep['wall_s']:8.3f} "
              f"{100*rep['hit_rate']:6.1f} "
              f"{rep['peak_cache_bytes']/2**20:7.1f} "
              f"{rep['avg_decode_batch']:6.1f}")
print("\nForkKV shares the bCache across agents (high hit%, low peak MB);"
      "\nprefix caching cannot share across adapters; full_reuse shares"
      "\neverything but degrades quality (see benchmarks/bench_quality.py).")
