"""Online serving latency under Poisson arrivals: iteration-level mixed
batching vs the legacy phase-separated step loop (DESIGN.md §14).

The point of the token-budget scheduler: with phase separation, a long
prompt's prefill call head-of-line-blocks every in-flight decode row for
that call's whole wall clock, so tail TPOT (and the TTFT of anything
queued behind the prompt) degrades as soon as arrivals overlap.  Mixed
batching caps each iteration at a token budget, packs every decode row
first and streams prompts in as chunks — same total work, bounded
per-iteration latency.

Method: one seeded Poisson open-loop workload (exponential inter-arrival
gaps, multi-LoRA round-robin adapters, mixed prompt lengths) is replayed
against two otherwise-identical ForkServers — ``mixed_batching=True`` and
``False`` — submitting each request via ``server.generate()`` when its
arrival time comes up while continuously draining ``server.poll()``.
Per-request TTFT/TPOT aggregates come from ``Engine.metrics()`` (the
satellite of the same PR); throughput is generated tokens over the
measured wall clock.

Emits CSV rows (benchmarks.run harness format) AND writes
``BENCH_serving.json`` with a mixed-vs-phase-separated comparison block.

  python -m benchmarks.bench_serving             # full sweep
  python -m benchmarks.bench_serving --smoke     # CI-sized, same JSON
"""
from __future__ import annotations

import argparse
import gc
import json
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import emit, get_tiny_model
from repro.core.config import ServeConfig
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams

# Queue-saturated regime (arrivals outpace the 4-slot batch, so TTFT is
# dominated by queue drain): this is where iteration-level batching's
# FCFS budget allocation beats the legacy loop's processor-sharing
# split — the legacy prefill call divides ``max_prefill_tokens`` across
# every running prefill row, so ALL of them finish late, holding their
# batch slots and starving the queue; mixed gives the head row the whole
# budget, drains it through decode and admits the next request sooner.
# Under light load (no queue) the two are within noise of each other and
# the unified call's padding overhead can put mixed slightly behind on
# this CPU testbed — the kernels skip dead rows, but the XLA layers
# around them still compute the padded (batch, chunk) tile.
FULL = dict(n_requests=24, rate_rps=7.0, prompt_lo=64, prompt_hi=128,
            max_new=8, max_pages=512, max_batch=4, max_prefill_tokens=16,
            n_adapters=4, seed=0)
SMOKE = dict(n_requests=10, rate_rps=7.0, prompt_lo=64, prompt_hi=128,
             max_new=6, max_pages=320, max_batch=4, max_prefill_tokens=16,
             n_adapters=2, seed=0)

# Multi-tenant regime (--tenants N, DESIGN.md §15): N-1 hog tenants
# flood a near-instant Poisson burst while one light tenant trickles
# interactive requests into the backlog.  FIFO makes the light tenant
# queue behind every hog request; weighted fair queuing admits it at the
# next slot (its virtual time is ~zero).  The prefill budget is the full
# prompt here so TTFT measures QUEUEING, not chunking.
TENANT_FULL = dict(n_light=6, light_rate_rps=8.0, n_hog_each=24,
                   hog_rate_rps=200.0, prompt_lo=96, prompt_hi=128,
                   max_new=6, max_pages=512, max_batch=4,
                   max_prefill_tokens=128, n_adapters=4, seed=0)
TENANT_SMOKE = dict(n_light=4, light_rate_rps=8.0, n_hog_each=16,
                    hog_rate_rps=200.0, prompt_lo=96, prompt_hi=128,
                    max_new=4, max_pages=320, max_batch=4,
                    max_prefill_tokens=128, n_adapters=2, seed=0)

# Speculative regime (--speculate, DESIGN.md §16): a repetitive agent-tree
# trace — n_distinct trajectories, each replayed several times under
# Poisson arrivals (sibling forks re-running a shared plan).  The first
# pass over each trajectory warms the ngram cache at finish; every replay
# then proposes the cached continuation and the verify row commits k+1
# tokens per step at ~100% acceptance.  Longer decodes (max_new) than the
# batching regime so per-token latency dominates the measurement.
SPEC_FULL = dict(n_requests=18, n_distinct=3, rate_rps=12.0, prompt_lo=64,
                 prompt_hi=96, max_new=16, max_pages=512, max_batch=4,
                 max_prefill_tokens=128, n_adapters=3, seed=0, spec_k=4)
SPEC_SMOKE = dict(n_requests=9, n_distinct=3, rate_rps=12.0, prompt_lo=64,
                  prompt_hi=96, max_new=12, max_pages=320, max_batch=4,
                  max_prefill_tokens=128, n_adapters=3, seed=0, spec_k=4)


def _workload(knobs: Dict, vocab: int, salt: int = 0):
    """Seeded open-loop trace: (arrival_s, adapter_id, prompt) per
    request.  The arrival/length schedule depends only on ``seed`` —
    identical for both batching modes AND for warmup-vs-measured — while
    ``salt`` varies the token content, so a warmup replay compiles every
    bucket the measured replay will hit without seeding the radix cache
    with the measured prompts."""
    rng = np.random.default_rng(knobs["seed"])
    rng_tok = np.random.default_rng(knobs["seed"] + 7919 * (salt + 1))
    gaps = rng.exponential(1.0 / knobs["rate_rps"], knobs["n_requests"])
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(knobs["n_requests"]):
        plen = int(rng.integers(knobs["prompt_lo"], knobs["prompt_hi"] + 1))
        prompt = list(rng_tok.integers(0, vocab, plen))
        reqs.append((float(arrivals[i]), i % knobs["n_adapters"], prompt))
    return reqs


def _run_side(mixed: bool, knobs: Dict) -> Dict:
    cfg, params, lora = get_tiny_model(rank=8,
                                       n_adapters=knobs["n_adapters"])
    sc = ServeConfig(page_size=16, max_pages=knobs["max_pages"],
                     max_batch=knobs["max_batch"],
                     max_prefill_tokens=knobs["max_prefill_tokens"],
                     mode="forkkv", max_pages_per_req=16,
                     mixed_batching=mixed)
    server = ForkServer(cfg, params, lora, sc)
    sp = SamplingParams(max_new_tokens=knobs["max_new"])

    def _replay(trace):
        t0 = time.perf_counter()
        handles: List = []
        i = 0
        while i < len(trace):
            now = time.perf_counter() - t0
            while i < len(trace) and trace[i][0] <= now:
                _, aid, prompt = trace[i]
                handles.append(server.generate(aid, list(prompt), sp))
                i += 1
            if i < len(trace) and not server.engine.running \
                    and not server.engine.waiting:
                # idle gap before the next arrival: don't spin the engine
                time.sleep(min(0.002, max(0.0, trace[i][0] - now)))
            else:
                server.poll()
        outs = server.wait(handles)
        return outs, time.perf_counter() - t0

    # warmup outside the clock: timed replays of the SAME arrival/length
    # schedule with different token content, repeated until the jit
    # cache stops growing — the measured run must not bill multi-second
    # compile walls (the schedule is timing-sensitive, so one cold
    # replay alone can miss buckets the steady-state schedule hits),
    # and fresh token content keeps the radix cache from handing the
    # measured replay prefix hits the other side didn't get
    prev = -1
    for salt in (1, 2, 3):
        _replay(_workload(knobs, cfg.vocab_size, salt=salt))
        size = (server.engine.executor._prefill._cache_size() +
                server.engine.executor._decode._cache_size())
        if size == prev:
            break
        prev = size
    m0 = server.metrics()

    outs, wall_s = _replay(_workload(knobs, cfg.vocab_size, salt=0))

    assert all(o.finish_reason == "length" for o in outs), \
        [o.finish_reason for o in outs]
    gen_tokens = sum(len(o.tokens) for o in outs)
    # aggregate over the MEASURED requests only (o.metrics carries the
    # per-request TTFT/TPOT) — the engine-level aggregates would fold the
    # compile-heavy warmup requests into the tail
    ttfts = sorted(o.metrics["ttft_ms"] for o in outs)
    tpots = sorted(o.metrics["tpot_ms"] for o in outs)

    def _pct(vals: List[float], q: float) -> float:
        return vals[min(len(vals) - 1, int(round(q * (len(vals) - 1))))]

    m = server.metrics()
    return {
        "batching": "mixed" if mixed else "phase_separated",
        "requests": len(outs),
        "wall_s": round(wall_s, 3),
        "gen_tokens": gen_tokens,
        "throughput_tok_s": round(gen_tokens / max(wall_s, 1e-9), 2),
        "ttft_mean_ms": round(sum(ttfts) / len(ttfts), 3),
        "ttft_p50_ms": round(_pct(ttfts, 0.50), 3),
        "ttft_p99_ms": round(_pct(ttfts, 0.99), 3),
        "tpot_mean_ms": round(sum(tpots) / len(tpots), 3),
        "tpot_p50_ms": round(_pct(tpots, 0.50), 3),
        "tpot_p99_ms": round(_pct(tpots, 0.99), 3),
        "mixed_steps": m["mixed_steps"],
        "iteration_token_budget": m["iteration_token_budget"],
        "decode_jit_variants": m["decode_jit_variants"],
        "fallback_gather_calls": m["fallback_gather_calls"] -
        m0["fallback_gather_calls"],
    }


def _tenant_workload(knobs: Dict, vocab: int, n_tenants: int,
                     light_only: bool, salt: int = 0):
    """Seeded multi-tenant trace: (arrival_s, tenant, adapter, prompt)
    sorted by arrival.  The light tenant's arrival/length schedule is
    IDENTICAL across the solo and combined replays (same seed stream),
    so its solo run is a true baseline."""
    rng = np.random.default_rng(knobs["seed"] + 13)
    rng_tok = np.random.default_rng(knobs["seed"] + 7919 * (salt + 1) + 13)
    reqs = []

    def _mk(tenant, rate, count, offset):
        arrivals = np.cumsum(rng.exponential(1.0 / rate, count))
        for i in range(count):
            plen = int(rng.integers(knobs["prompt_lo"],
                                    knobs["prompt_hi"] + 1))
            prompt = list(rng_tok.integers(0, vocab, plen))
            reqs.append((float(arrivals[i]) + offset, tenant,
                         (len(reqs)) % knobs["n_adapters"], prompt))

    # consume the SAME rng stream in the same order regardless of
    # light_only, so the light tenant's schedule never shifts
    _mk("light", knobs["light_rate_rps"], knobs["n_light"], 0.0)
    for h in range(max(0, n_tenants - 1)):
        hogs_offset = 0.0
        before = len(reqs)
        _mk(f"hog{h}", knobs["hog_rate_rps"], knobs["n_hog_each"],
            hogs_offset)
        if light_only:
            del reqs[before:]
    reqs.sort(key=lambda r: r[0])
    return reqs


def _run_tenant_side(admission: str, knobs: Dict, n_tenants: int,
                     light_only: bool) -> Dict:
    cfg, params, lora = get_tiny_model(rank=8,
                                       n_adapters=knobs["n_adapters"])
    sc = ServeConfig(page_size=16, max_pages=knobs["max_pages"],
                     max_batch=knobs["max_batch"],
                     max_prefill_tokens=knobs["max_prefill_tokens"],
                     mode="forkkv", max_pages_per_req=16,
                     mixed_batching=True, admission=admission)
    server = ForkServer(cfg, params, lora, sc)
    sp = SamplingParams(max_new_tokens=knobs["max_new"])

    def _replay(trace):
        t0 = time.perf_counter()
        handles: List = []
        i = 0
        while i < len(trace):
            now = time.perf_counter() - t0
            while i < len(trace) and trace[i][0] <= now:
                _, tenant, aid, prompt = trace[i]
                handles.append(server.generate(aid, list(prompt), sp,
                                               tenant=tenant))
                i += 1
            if i < len(trace) and not server.engine.running \
                    and not server.engine.waiting:
                time.sleep(min(0.002, max(0.0, trace[i][0] - now)))
            else:
                server.poll()
        outs = server.wait(handles)
        return outs, time.perf_counter() - t0

    prev = -1
    for salt in (1, 2, 3):
        _replay(_tenant_workload(knobs, cfg.vocab_size, n_tenants,
                                 light_only, salt=salt))
        size = (server.engine.executor._prefill._cache_size() +
                server.engine.executor._decode._cache_size())
        if size == prev:
            break
        prev = size

    # two measured replays of the same schedule with fresh token content
    # (no radix cross-hits); keep the higher-throughput one — single
    # replays on a shared CPU testbed are noisy enough to flip the
    # 5%-throughput criterion on scheduler jitter alone
    best = None
    for salt in (0, 4):
        server.engine._admission_waits.clear()   # drop warmup waits
        outs, wall_s = _replay(_tenant_workload(knobs, cfg.vocab_size,
                                                n_tenants, light_only,
                                                salt=salt))
        assert all(o.finish_reason == "length" for o in outs), \
            [o.finish_reason for o in outs]
        if best is None or wall_s < best[1]:
            best = (outs, wall_s)
    outs, wall_s = best

    def _pct(vals: List[float], q: float) -> float:
        return vals[min(len(vals) - 1, int(round(q * (len(vals) - 1))))]

    by_tenant: Dict[str, Dict] = {}
    for tenant in sorted({o.tenant for o in outs}):
        t_outs = [o for o in outs if o.tenant == tenant]
        ttfts = sorted(o.metrics["ttft_ms"] for o in t_outs)
        tpots = sorted(o.metrics["tpot_ms"] for o in t_outs)
        by_tenant[tenant] = {
            "requests": len(t_outs),
            "ttft_p50_ms": round(_pct(ttfts, 0.50), 3),
            "ttft_p99_ms": round(_pct(ttfts, 0.99), 3),
            "tpot_p50_ms": round(_pct(tpots, 0.50), 3),
            "tpot_p99_ms": round(_pct(tpots, 0.99), 3),
        }
    gen_tokens = sum(len(o.tokens) for o in outs)
    em = server.metrics()
    return {"admission": admission, "requests": len(outs),
            "wall_s": round(wall_s, 3), "gen_tokens": gen_tokens,
            "throughput_tok_s": round(gen_tokens / max(wall_s, 1e-9), 2),
            "admission_wait_p99_ms": em["admission_wait_p99_ms"],
            "tenants": by_tenant}


def run_tenants(smoke: bool, n_tenants: int) -> Dict:
    """Fairness experiment (acceptance, DESIGN.md §15): light tenant's
    TTFT p99 under fair share must stay within 2x of its SOLO run while
    FIFO blows past it, at <=5% aggregate throughput cost."""
    knobs = TENANT_SMOKE if smoke else TENANT_FULL
    sides = {}
    for name, admission, light_only in (
            ("light_solo", "fifo", True),
            ("fifo", "fifo", False),
            ("fairshare", "fairshare", False)):
        sides[name] = _run_tenant_side(admission, knobs, n_tenants,
                                       light_only)
        gc.collect()
        jax.clear_caches()
        light = sides[name]["tenants"]["light"]
        emit(f"serving.tenants.{name}.light_ttft_p99_ms",
             light["ttft_p99_ms"] * 1e3,
             f"reqs={sides[name]['requests']};tok_s="
             f"{sides[name]['throughput_tok_s']}")
    solo_p99 = sides["light_solo"]["tenants"]["light"]["ttft_p99_ms"]
    fifo_p99 = sides["fifo"]["tenants"]["light"]["ttft_p99_ms"]
    fair_p99 = sides["fairshare"]["tenants"]["light"]["ttft_p99_ms"]
    comparison = {
        "light_ttft_p99_solo_ms": solo_p99,
        "fifo_vs_solo_ratio": round(fifo_p99 / max(solo_p99, 1e-9), 3),
        "fairshare_vs_solo_ratio": round(fair_p99 / max(solo_p99, 1e-9),
                                         3),
        "throughput_ratio_fair_vs_fifo": round(
            sides["fairshare"]["throughput_tok_s"] /
            max(sides["fifo"]["throughput_tok_s"], 1e-9), 4),
    }
    protected = (comparison["fairshare_vs_solo_ratio"] <= 2.0 and
                 comparison["fifo_vs_solo_ratio"] > 2.0)
    verdict = ("fairshare_protects_light" if protected and
               comparison["throughput_ratio_fair_vs_fifo"] >= 0.95
               else "light_not_protected" if
               comparison["throughput_ratio_fair_vs_fifo"] >= 0.95
               else "throughput_regression")
    emit("serving.tenants.throughput_ratio", 0,
         f"{comparison['throughput_ratio_fair_vs_fifo']:.3f};"
         f"verdict={verdict}")
    return {"n_tenants": n_tenants, "knobs": dict(knobs),
            "sides": sides, "comparison": comparison, "verdict": verdict}


def _spec_workload(knobs: Dict, vocab: int, salt: int = 0):
    """Seeded repetitive trace: ``n_distinct`` trajectory prompts, each
    request replaying trajectory ``i % n_distinct`` (Poisson arrivals).
    Same salt discipline as :func:`_workload` — warmup replays use fresh
    token content so neither the radix cache nor the ngram cache leaks
    warmup state into the measured run."""
    rng = np.random.default_rng(knobs["seed"] + 29)
    rng_tok = np.random.default_rng(knobs["seed"] + 7919 * (salt + 1) + 29)
    gaps = rng.exponential(1.0 / knobs["rate_rps"], knobs["n_requests"])
    arrivals = np.cumsum(gaps)
    protos = []
    for _ in range(knobs["n_distinct"]):
        plen = int(rng.integers(knobs["prompt_lo"], knobs["prompt_hi"] + 1))
        protos.append(list(rng_tok.integers(0, vocab, plen)))
    return [(float(arrivals[i]), i % knobs["n_adapters"],
             protos[i % knobs["n_distinct"]])
            for i in range(knobs["n_requests"])]


def _run_spec_side(speculate: bool, knobs: Dict) -> Dict:
    cfg, params, lora = get_tiny_model(rank=8,
                                       n_adapters=knobs["n_adapters"])
    sc = ServeConfig(page_size=16, max_pages=knobs["max_pages"],
                     max_batch=knobs["max_batch"],
                     max_prefill_tokens=knobs["max_prefill_tokens"],
                     mode="forkkv", max_pages_per_req=16,
                     mixed_batching=True, speculate=speculate,
                     spec_k=knobs["spec_k"], spec_proposer="ngram_cache")
    server = ForkServer(cfg, params, lora, sc)
    sp = SamplingParams(max_new_tokens=knobs["max_new"])

    def _replay(trace):
        t0 = time.perf_counter()
        handles: List = []
        i = 0
        while i < len(trace):
            now = time.perf_counter() - t0
            while i < len(trace) and trace[i][0] <= now:
                _, aid, prompt = trace[i]
                handles.append(server.generate(aid, list(prompt), sp))
                i += 1
            if i < len(trace) and not server.engine.running \
                    and not server.engine.waiting:
                time.sleep(min(0.002, max(0.0, trace[i][0] - now)))
            else:
                server.poll()
        outs = server.wait(handles)
        return outs, time.perf_counter() - t0

    prev = -1
    for salt in (1, 2, 3):
        _replay(_spec_workload(knobs, cfg.vocab_size, salt=salt))
        size = (server.engine.executor._prefill._cache_size() +
                server.engine.executor._decode._cache_size())
        if size == prev:
            break
        prev = size
    m0 = server.metrics()

    # two measured replays with fresh token content, keep the faster —
    # same CPU-noise discipline as the tenant experiment (the arrival
    # schedule bounds the wall clock, so single replays sit within
    # scheduler jitter of each other)
    best = None
    for salt in (0, 4):
        outs, wall_s = _replay(_spec_workload(knobs, cfg.vocab_size,
                                              salt=salt))
        if best is None or wall_s < best[1]:
            best = (outs, wall_s)
    outs, wall_s = best

    assert all(o.finish_reason == "length" for o in outs), \
        [o.finish_reason for o in outs]
    gen_tokens = sum(len(o.tokens) for o in outs)
    ttfts = sorted(o.metrics["ttft_ms"] for o in outs)
    tpots = sorted(o.metrics["tpot_ms"] for o in outs)
    proposed = sum(o.metrics["spec_proposed"] for o in outs)
    accepted = sum(o.metrics["spec_accepted"] for o in outs)

    def _pct(vals: List[float], q: float) -> float:
        return vals[min(len(vals) - 1, int(round(q * (len(vals) - 1))))]

    m = server.metrics()
    return {
        "speculate": speculate,
        "requests": len(outs),
        "wall_s": round(wall_s, 3),
        "gen_tokens": gen_tokens,
        "throughput_tok_s": round(gen_tokens / max(wall_s, 1e-9), 2),
        "ttft_p50_ms": round(_pct(ttfts, 0.50), 3),
        "ttft_p99_ms": round(_pct(ttfts, 0.99), 3),
        "tpot_mean_ms": round(sum(tpots) / len(tpots), 3),
        "tpot_p50_ms": round(_pct(tpots, 0.50), 3),
        "tpot_p99_ms": round(_pct(tpots, 0.99), 3),
        # measured-replay speculation counters (per-request, so warmup
        # steps never pollute them)
        "spec_proposed_tokens": proposed,
        "spec_accepted_tokens": accepted,
        "acceptance_rate": round(accepted / max(1, proposed), 4),
        "spec_steps": m["spec_steps"] - m0["spec_steps"],
        "fallback_gather_calls": m["fallback_gather_calls"] -
        m0["fallback_gather_calls"],
    }


def run_speculate(smoke: bool) -> Dict:
    """Speculation experiment (acceptance, DESIGN.md §16): on the
    repetitive agent-tree trace, speculative decoding must cut TPOT p50
    (multi-token commits on replayed trajectories) at >= 1.0x throughput
    — rejected drafts cost nothing but the wider verify call."""
    knobs = SPEC_SMOKE if smoke else SPEC_FULL
    sides = {}
    for spec in (True, False):
        side = _run_spec_side(spec, knobs)
        sides["speculate" if spec else "baseline"] = side
        gc.collect()
        jax.clear_caches()
        name = "speculate" if spec else "baseline"
        emit(f"serving.spec.{name}.tpot_p50_ms", side["tpot_p50_ms"] * 1e3,
             f"reqs={side['requests']};tok_s={side['throughput_tok_s']};"
             f"acceptance={side['acceptance_rate']}")
    on, off = sides["speculate"], sides["baseline"]

    def _impr(key: str) -> float:
        return round(100.0 * (off[key] - on[key]) / max(off[key], 1e-9), 2)

    comparison = {
        "acceptance_rate": on["acceptance_rate"],
        "tpot_p50_improvement_pct": _impr("tpot_p50_ms"),
        "tpot_p99_improvement_pct": _impr("tpot_p99_ms"),
        "tpot_mean_improvement_pct": _impr("tpot_mean_ms"),
        "throughput_ratio": round(on["throughput_tok_s"] /
                                  max(off["throughput_tok_s"], 1e-9), 4),
    }
    faster = comparison["tpot_p50_improvement_pct"] > 0
    verdict = ("speculation_cuts_tpot" if faster and
               comparison["throughput_ratio"] >= 1.0
               else "no_tpot_improvement" if
               comparison["throughput_ratio"] >= 1.0
               else "throughput_regression")
    emit("serving.spec.comparison.throughput_ratio", 0,
         f"{comparison['throughput_ratio']:.3f};verdict={verdict}")
    return {"knobs": dict(knobs), "baseline": off, "speculate": on,
            "comparison": comparison, "verdict": verdict}


def run(smoke: bool) -> Dict:
    knobs = SMOKE if smoke else FULL
    sides = {}
    for mixed in (True, False):
        side = _run_side(mixed, knobs)
        sides[side["batching"]] = side
        # each side owns its pools + jit cache; start the other clean
        gc.collect()
        jax.clear_caches()
        for metric in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms",
                       "tpot_p99_ms"):
            emit(f"serving.{side['batching']}.{metric}",
                 side[metric] * 1e3,
                 f"reqs={side['requests']};tok_s="
                 f"{side['throughput_tok_s']}")
    mx, ps = sides["mixed"], sides["phase_separated"]

    def _impr(key: str) -> float:
        """% improvement of mixed over phase-separated (positive = mixed
        better)."""
        return round(100.0 * (ps[key] - mx[key]) / max(ps[key], 1e-9), 2)

    comparison = {
        "ttft_p99_improvement_pct": _impr("ttft_p99_ms"),
        "tpot_p99_improvement_pct": _impr("tpot_p99_ms"),
        "ttft_p50_improvement_pct": _impr("ttft_p50_ms"),
        "tpot_p50_improvement_pct": _impr("tpot_p50_ms"),
        "throughput_ratio": round(mx["throughput_tok_s"] /
                                  max(ps["throughput_tok_s"], 1e-9), 4),
    }
    p99_better = (comparison["ttft_p99_improvement_pct"] > 0 or
                  comparison["tpot_p99_improvement_pct"] > 0)
    verdict = ("mixed_improves_p99" if p99_better and
               comparison["throughput_ratio"] >= 0.95
               else "no_p99_improvement" if comparison["throughput_ratio"]
               >= 0.95 else "throughput_regression")
    emit("serving.comparison.throughput_ratio", 0,
         f"{comparison['throughput_ratio']:.3f};verdict={verdict}")
    return {"smoke": smoke, "knobs": dict(knobs), "mixed": mx,
            "phase_separated": ps, "comparison": comparison,
            "verdict": verdict}


def main(argv=None) -> None:
    # benchmarks.run calls main() with no args while holding its own CLI
    # flags in sys.argv — parse only what we are explicitly handed
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same JSON output)")
    ap.add_argument("--tenants", type=int, default=0, metavar="N",
                    help="also run the N-tenant fairness experiment "
                         "(1 light + N-1 hog tenants): solo vs FIFO vs "
                         "fair share, per-tenant TTFT/TPOT percentiles")
    ap.add_argument("--speculate", action="store_true",
                    help="also run the speculative-decoding experiment "
                         "(repetitive agent-tree trace, spec-on vs "
                         "spec-off TPOT + acceptance rate, DESIGN.md §16)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args([] if argv is None else argv)
    report = run(args.smoke)
    if args.tenants > 1:
        report["multi_tenant"] = run_tenants(args.smoke, args.tenants)
    if args.speculate:
        report["speculative"] = run_speculate(args.smoke)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
