"""Serving launcher: run the ForkKV engine on a workload.

  PYTHONPATH=src python -m repro.launch.serve --mode forkkv \
      --workflow react --workflows 2 --agents 3

Runs entirely through the session/fork API (``repro.serving.api``): the
launcher builds a :class:`ForkServer`, the workflow driver pins the shared
context in an :class:`AgentSession` and forks agents off it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams
from repro.serving.workflows import WorkflowConfig, WorkflowDriver


def build_server(mode: str, *, rank: int = 8, max_pages: int = 512,
                 max_batch: int = 8, n_adapters: int = 32,
                 max_pages_per_req: int = 24, seed: int = 0,
                 host_tier_bytes: int = 0, tier_promote_limit: int = 0,
                 broadcast_fork: bool = False,
                 adaptive_fallback: bool = False,
                 use_paged_kernel: bool = True,
                 mixed_batching: bool = True,
                 iteration_token_budget: int = 0,
                 admission: str = "fifo",
                 tenant_weights: tuple = (),
                 tenant_max_concurrent: int = 0,
                 max_queue_depth: int = 0,
                 max_queue_wait_s: float = 0.0,
                 speculate: bool = False,
                 spec_k: int = 4,
                 spec_proposer: str = "prompt_lookup",
                 preempt: bool = True,
                 preempt_after_steps: int = 4,
                 fault_plan: str = "",
                 fault_seed: int = 0,
                 watchdog_s: float = 10.0,
                 kv_quant: str = "none",
                 kv_codec: str = "identity",
                 disk_tier_bytes: int = 0,
                 persist_dir: str = ""):
    cfg = tiny_serving_model(rank=rank)
    if kv_quant != "none":
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(seed + 1),
                                n_adapters=n_adapters)
    sc = ServeConfig(page_size=16, max_pages=max_pages, max_batch=max_batch,
                     max_prefill_tokens=128, mode=mode,
                     max_pages_per_req=max_pages_per_req,
                     host_tier_bytes=host_tier_bytes,
                     tier_promote_limit=tier_promote_limit,
                     broadcast_fork=broadcast_fork,
                     adaptive_fallback=adaptive_fallback,
                     use_paged_kernel=use_paged_kernel,
                     mixed_batching=mixed_batching,
                     iteration_token_budget=iteration_token_budget,
                     admission=admission,
                     tenant_weights=tuple(tenant_weights),
                     tenant_max_concurrent=tenant_max_concurrent,
                     max_queue_depth=max_queue_depth,
                     max_queue_wait_s=max_queue_wait_s,
                     speculate=speculate, spec_k=spec_k,
                     spec_proposer=spec_proposer,
                     preempt=preempt,
                     preempt_after_steps=preempt_after_steps,
                     fault_plan=fault_plan, fault_seed=fault_seed,
                     watchdog_s=watchdog_s,
                     kv_codec=kv_codec, disk_tier_bytes=disk_tier_bytes,
                     persist_dir=persist_dir)
    server = ForkServer(cfg, params, lora, sc)
    # restart rehydration (DESIGN.md §18): a manifest left by a previous
    # run's persist() grafts its shared prefixes into the radix tree as
    # host-tier nodes — matched requests promote instead of re-prefilling
    if persist_dir and os.path.exists(os.path.join(persist_dir,
                                                   "manifest.json")):
        n = server.engine.restore(persist_dir)
        print(f"restore: rehydrated {n} page(s) from {persist_dir}",
              flush=True)
    return server, cfg


def build_engine(mode: str, **kw):
    """Back-compat shim: returns the wrapped Engine."""
    server, cfg = build_server(mode, **kw)
    return server.engine, cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="forkkv",
                    choices=["forkkv", "prefix", "full_reuse"])
    ap.add_argument("--workflow", default="react",
                    choices=["react", "mapreduce"])
    ap.add_argument("--workflows", type=int, default=2)
    ap.add_argument("--agents", type=int, default=3)
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-pages", type=int, default=512)
    ap.add_argument("--broadcast-fork", action="store_true",
                    help="amortize identical simultaneous prefills into one "
                         "base-trajectory pass (DESIGN.md §9)")
    ap.add_argument("--adaptive-fallback", action="store_true",
                    help="enable the adaptive unified-cache fallback knob "
                         "(ServeConfig.adaptive_fallback)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k sampling cutoff (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling cutoff (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG seed")
    ap.add_argument("--host-tier-mb", type=int, default=0,
                    help="host KV offload budget in MiB (0 = disabled, "
                         "DESIGN.md §10)")
    ap.add_argument("--tier-promote-limit", type=int, default=0,
                    help="max pages promoted host→device per match "
                         "(0 = unlimited)")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="bCache page quantization inside the paged "
                         "kernels (DESIGN.md §18)")
    ap.add_argument("--kv-codec", default="identity",
                    choices=["identity", "int8", "zstd"],
                    help="blob codec applied on demote to host/disk and "
                         "reversed on promote (DESIGN.md §18)")
    ap.add_argument("--disk-tier-mb", type=int, default=0,
                    help="disk KV tier budget in MiB below the host tier "
                         "(0 = disabled, DESIGN.md §18)")
    ap.add_argument("--persist-dir", default="",
                    help="directory for the disk tier + persist manifest; "
                         "a restarted server rehydrates cached prefixes "
                         "from it instead of re-prefilling (DESIGN.md §18)")
    ap.add_argument("--phase-separated", action="store_true",
                    help="disable iteration-level continuous batching and "
                         "run the legacy phase-separated step loop "
                         "(ServeConfig.mixed_batching=False, DESIGN.md §14)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="iteration token budget for mixed batching "
                         "(0 = derive max_prefill_tokens + max_batch)")
    ap.add_argument("--gather-decode", action="store_true",
                    help="disable the page-native decode kernel and use "
                         "the legacy gather-to-contiguous path "
                         "(bit-parity testing, DESIGN.md §12)")
    ap.add_argument("--http", action="store_true",
                    help="serve HTTP instead of running a canned workflow: "
                         "SSE streaming completions, session/fork routes "
                         "and /v1/metrics (DESIGN.md §15)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="HTTP bind address (with --http)")
    ap.add_argument("--port", type=int, default=8080,
                    help="HTTP port (with --http; 0 = ephemeral)")
    ap.add_argument("--admission", default="fifo",
                    choices=["fifo", "fairshare"],
                    help="admission policy: FIFO or weighted-fair-queue "
                         "multi-tenant scheduling (DESIGN.md §15)")
    ap.add_argument("--tenant-weight", action="append", default=[],
                    metavar="TENANT=W",
                    help="fair-share weight for a tenant (repeatable), "
                         "e.g. --tenant-weight interactive=4")
    ap.add_argument("--tenant-max-concurrent", type=int, default=0,
                    help="per-tenant cap on concurrently admitted "
                         "requests (0 = unlimited)")
    ap.add_argument("--max-queue-depth", type=int, default=0,
                    help="shed waiting requests beyond this queue depth "
                         "(0 = never shed on depth)")
    ap.add_argument("--max-queue-wait-s", type=float, default=0.0,
                    help="shed waiting requests older than this many "
                         "seconds (0 = never shed on wait)")
    ap.add_argument("--speculate", action="store_true",
                    help="enable draft-free speculative decoding for "
                         "greedy requests (DESIGN.md §16)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max drafted tokens per verify row (with "
                         "--speculate; adaptive controller may lower it)")
    ap.add_argument("--proposer", default="prompt_lookup",
                    choices=["prompt_lookup", "ngram_cache"],
                    help="draft proposer: prompt self-match or the "
                         "completed-request n-gram cache")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable preempt-restore under pool pressure "
                         "(DESIGN.md §17); blocked admission then waits "
                         "for natural completions only")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault-injection plan, e.g. "
                         "'pool_alloc:c3;nan_logits:p0.1' (DESIGN.md §17; "
                         "FORKKV_FAULT_PLAN env is the fallback)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for probabilistic fault triggers")
    ap.add_argument("--watchdog-s", type=float, default=10.0,
                    help="stuck-pump watchdog threshold in seconds for "
                         "--http (0 = disabled)")
    ap.add_argument("--stats", action="store_true",
                    help="print step-phase wall-clock totals "
                         "(prefill/decode/sync ms), compiled decode "
                         "variant count and per-request latency "
                         "aggregates (TTFT/TPOT p50/p99)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    weights = []
    for spec in args.tenant_weight:
        name, _, w = spec.partition("=")
        weights.append((name, float(w or 1.0)))
    server, cfg = build_server(
        args.mode, max_pages=args.max_pages,
        host_tier_bytes=args.host_tier_mb << 20,
        tier_promote_limit=args.tier_promote_limit,
        kv_quant=args.kv_quant, kv_codec=args.kv_codec,
        disk_tier_bytes=args.disk_tier_mb << 20,
        persist_dir=args.persist_dir,
        broadcast_fork=args.broadcast_fork,
        adaptive_fallback=args.adaptive_fallback,
        use_paged_kernel=not args.gather_decode,
        mixed_batching=not args.phase_separated,
        iteration_token_budget=args.token_budget,
        admission=args.admission, tenant_weights=tuple(weights),
        tenant_max_concurrent=args.tenant_max_concurrent,
        max_queue_depth=args.max_queue_depth,
        max_queue_wait_s=args.max_queue_wait_s,
        speculate=args.speculate, spec_k=args.spec_k,
        spec_proposer=args.proposer,
        preempt=not args.no_preempt,
        fault_plan=args.fault_plan, fault_seed=args.fault_seed,
        watchdog_s=args.watchdog_s)
    if args.http:
        import signal

        from repro.serving.frontend import HttpFrontend
        # start_background so the bound port (possibly ephemeral) can be
        # printed for callers that parse it (scripts/smoke.sh)
        fe = HttpFrontend(server, host=args.host,
                          port=args.port).start_background()
        print(f"serving mode={args.mode} admission={args.admission} "
              f"on http://{args.host}:{fe.port}", flush=True)

        # graceful drain (DESIGN.md §17): SIGTERM stops admission (new
        # requests get 503 + Retry-After), in-flight requests finish,
        # then the process exits 0.  begin_drain is signal-safe (flag
        # flip + queue.put); the wait happens back on the main thread.
        def _on_term(signum, frame):
            print("drain: signal received, finishing in-flight "
                  "requests", flush=True)
            fe.begin_drain()

        signal.signal(signal.SIGTERM, _on_term)
        try:
            while fe._thread.is_alive():
                fe._thread.join(timeout=0.2)
                if fe.drained:
                    print("drain: complete, exiting", flush=True)
                    break
        except KeyboardInterrupt:
            fe.begin_drain()
            while not fe.drained and fe._thread.is_alive():
                fe._thread.join(timeout=0.2)
        if args.persist_dir:
            n = server.engine.persist(args.persist_dir)
            print(f"persist: wrote {n} page(s) to {args.persist_dir}",
                  flush=True)
        fe.shutdown()
        return
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed, max_new_tokens=args.max_new)
    wf = WorkflowConfig(n_workflows=args.workflows,
                        agents_per_workflow=args.agents,
                        shared_context_len=args.context,
                        max_new_tokens=args.max_new, vocab=cfg.vocab_size,
                        sampling=sampling)
    driver = WorkflowDriver(server, wf)
    rep = driver.run_react() if args.workflow == "react" \
        else driver.run_mapreduce()
    if args.persist_dir:
        n = server.engine.persist(args.persist_dir)
        print(f"persist: wrote {n} page(s) to {args.persist_dir}",
              flush=True)
    if args.json:
        print(json.dumps(rep, default=str, indent=1))
    else:
        print(f"mode={rep['mode']} workflow={rep['workflow']} "
              f"tasks={rep['tasks']} wall={rep['wall_s']:.1f}s "
              f"throughput={rep['throughput_tasks_per_s']:.3f} tasks/s")
        print(f"hit_rate={rep['hit_rate']:.2f} "
              f"peak_base_pages={rep['peak_base_pages']} "
              f"peak_res_pages={rep['peak_res_pages']} "
              f"avg_decode_batch={rep['avg_decode_batch']:.1f} "
              f"hit_kinds={rep['hit_kinds']}")
        if args.host_tier_mb:
            print(f"tier_hits={rep['tier_hits']} "
                  f"demoted_pages={rep['demoted_pages']} "
                  f"promoted_bytes={rep['promoted_bytes']} "
                  f"host_used_bytes={rep['host_used_bytes']} "
                  f"preemptions={rep['preemptions']}")
        if args.stats:
            per_step = rep["decode_ms"] / max(1, rep["decode_steps"])
            print(f"kernels={'paged' if rep['use_paged_kernel'] else 'gather'}"
                  f" prefill_ms={rep['prefill_ms']:.1f} "
                  f"decode_ms={rep['decode_ms']:.1f} "
                  f"sync_ms={rep['sync_ms']:.1f} "
                  f"decode_steps={rep['decode_steps']} "
                  f"decode_ms_per_step={per_step:.2f} "
                  f"decode_jit_variants={rep['decode_jit_variants']} "
                  f"fallback_gather_calls={rep['fallback_gather_calls']}")
            batching = ("mixed" if rep["mixed_batching"]
                        else "phase-separated")
            print(f"batching={batching} "
                  f"mixed_steps={rep['mixed_steps']} "
                  f"token_budget={rep['iteration_token_budget']} "
                  f"ttft_p50_ms={rep['ttft_p50_ms']:.1f} "
                  f"ttft_p99_ms={rep['ttft_p99_ms']:.1f} "
                  f"tpot_p50_ms={rep['tpot_p50_ms']:.1f} "
                  f"tpot_p99_ms={rep['tpot_p99_ms']:.1f}")
            em = server.metrics()
            if em["speculate"]:
                print(f"speculate=on proposer={em['spec_proposer']} "
                      f"spec_steps={em['spec_steps']} "
                      f"spec_step_share={em['spec_step_share']:.2f} "
                      f"proposed={em['spec_proposed_tokens']} "
                      f"accepted={em['spec_accepted_tokens']} "
                      f"acceptance={em['spec_acceptance_rate']:.2f}")
            print(f"admission={em['admission']} "
                  f"queue_depth={em['queue_depth']} "
                  f"admission_wait_p50_ms={em['admission_wait_p50_ms']:.2f} "
                  f"admission_wait_p99_ms={em['admission_wait_p99_ms']:.2f} "
                  f"timeouts={em['timeouts']} shed={em['shed']} "
                  f"tenants={em['tenants']}")
            print(f"preempted={em['preempted_requests']} "
                  f"restored={em['restored_requests']} "
                  f"recompute_tokens={em['recompute_tokens']} "
                  f"quarantined={em['quarantined']} "
                  f"exec_errors={em['exec_errors']} "
                  f"watchdog_trips={em['watchdog_trips']} "
                  f"draining={em['draining']} "
                  f"faults_fired={em['faults_fired']}")


if __name__ == "__main__":
    main()
