"""Codec, disk-tier and persist/restore gates (DESIGN.md §18).

  * codec ROUND-TRIP matrix — identity/zstd are bit-identical for every
    cached dtype (fp32 AND bf16, which np.savez cannot even hold); int8
    is lossy within its documented per-row bound |x - deq| <= amax/254;
  * disk SPILL — host-LRU pressure moves whole nodes to disk files
    instead of destroying them, and a later match promotes them back to
    device bit-identical (counted as ``disk_hits``);
  * disk-IO FAULTS — an injected ``disk_io`` fault degrades (spill
    failure drops the node, promote failure truncates the match) and
    never crashes;
  * PERSIST/RESTORE — the acceptance gate: a persisted engine's manifest
    rehydrates into a brand-new engine whose greedy continuation of the
    same context is token-identical, served from tier hits rather than a
    full re-prefill;
  * percentile — the engine's linear-interpolated percentile matches
    ``np.percentile`` (the old nearest-rank version returned the window
    max as "p99" for small windows).
"""
import math

import jax
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine, Request, percentile
from repro.serving.pool import PagePool
from repro.serving.radix import RadixTree
from repro.serving.tiers import (DiskTier, HostTier, TieredPagePool,
                                 blob_bytes, get_codec, read_blob_file,
                                 write_blob_file)

PAGE = 4


# ----------------------------------------------------------------- codecs
def _blob(rng, dtype):
    x = rng.standard_normal((2, 8, 4)).astype(np.float32)
    y = rng.standard_normal((2, 8, 4)).astype(np.float32)
    if dtype == "bfloat16":
        import jax.numpy as jnp
        return {"k": np.asarray(jnp.asarray(x, jnp.bfloat16)),
                "v": np.asarray(jnp.asarray(y, jnp.bfloat16))}
    return {"k": x, "v": y}


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("name", ["identity", "zstd", "int8"])
def test_codec_roundtrip_matrix(name, dtype):
    codec = get_codec(name)
    rng = np.random.default_rng(0)
    blob = _blob(rng, dtype)
    dec = codec.decode(codec.encode(blob))
    assert set(dec) == set(blob)
    for key in blob:
        assert dec[key].dtype == blob[key].dtype
        assert dec[key].shape == blob[key].shape
        if codec.lossless:
            np.testing.assert_array_equal(
                dec[key].view(np.uint8), blob[key].view(np.uint8))
        else:   # int8: |x - deq| <= scale/2 = amax(|row|)/254 per row,
            # plus the half-ulp of casting the dequantized value back to
            # a narrow storage dtype (bf16 half-ulp <= |x| * 2^-8)
            x = np.asarray(blob[key], np.float32)
            bound = np.abs(x).max(axis=-1, keepdims=True) / 254.0 + 1e-6
            if dtype == "bfloat16":
                bound = bound + np.abs(x) * 2.0 ** -8
            err = np.abs(np.asarray(dec[key], np.float32) - x)
            assert (err <= bound).all(), err.max()


def test_int8_codec_passes_through_integer_arrays():
    """Already-quantized pool pages (kv_quant="int8" blobs carry int8
    "k"/"v" plus f32 "ks"/"vs") must not be double-quantized."""
    codec = get_codec("int8")
    q = np.arange(-64, 64, dtype=np.int8).reshape(8, 16)
    dec = codec.decode(codec.encode({"k": q}))
    assert dec["k"].dtype == np.int8
    np.testing.assert_array_equal(dec["k"], q)


def test_zstd_codec_compresses_redundant_data():
    codec = get_codec("zstd")
    blob = {"k": np.zeros((64, 64), np.float32)}
    enc = codec.encode(blob)
    assert blob_bytes(enc) < blob_bytes(blob) // 10
    assert codec.backend in ("zstandard", "zlib")


def test_blob_file_roundtrips_bfloat16(tmp_path):
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    blob = {"k": np.asarray(jnp.asarray(
        rng.standard_normal((4, 8)), jnp.bfloat16)),
        "meta": np.arange(3, dtype=np.int32)}
    path = str(tmp_path / "page.blob")
    nbytes = write_blob_file(path, blob)
    assert nbytes > 0
    back = read_blob_file(path)
    assert set(back) == set(blob)
    for key in blob:
        assert back[key].dtype == blob[key].dtype
        np.testing.assert_array_equal(
            back[key].view(np.uint8), blob[key].view(np.uint8))


# --------------------------------------------------------------- disk tier
class FakeDeviceStore:
    def __init__(self, num_pages, elems=8):
        self.data = np.zeros((num_pages, elems), np.float32)

    def export(self, pages):
        return [{"x": self.data[p].copy()} for p in pages]

    def import_(self, pages, blobs):
        for p, b in zip(pages, blobs):
            self.data[p] = b["x"]


def make_tiered3(tmp_path, host_budget, disk_budget=1 << 20,
                 num_pages=16, io_hook=None):
    store = FakeDeviceStore(num_pages)
    host = HostTier(host_budget)
    disk = DiskTier(str(tmp_path / "disk"), disk_budget, io_hook=io_hook)
    pool = TieredPagePool(PagePool(num_pages, PAGE), host,
                          export_fn=store.export, import_fn=store.import_,
                          disk=disk)
    tree = RadixTree(pool)
    pool.pressure_fn = tree.evict
    return tree, pool, store, host, disk


def insert_seq(tree, pool, store, toks, fill):
    pages = pool.alloc(len(toks) // PAGE)
    for i, p in enumerate(pages):
        store.data[p] = fill * 100 + i
    tree.insert(toks, pages)
    pool.decref(pages)
    return pages


def test_host_pressure_spills_to_disk_and_promotes_back(tmp_path):
    # host fits exactly ONE 2-page node (2 x 32B blobs)
    tree, pool, store, host, disk = make_tiered3(tmp_path, host_budget=64)
    a, b = list(range(8)), list(range(100, 108))
    pa = insert_seq(tree, pool, store, a, fill=1)
    snapshot = {p: store.data[p].copy() for p in pa}
    insert_seq(tree, pool, store, b, fill=2)
    assert tree.evict(2) == 2                   # a -> host
    assert tree.evict(2) == 2                   # b -> host, a SPILLS to disk
    assert pool.spilled_pages == 2
    assert disk.num_entries == 2 and host.num_entries == 2
    assert pool.dropped_device_pages == 0       # nothing was destroyed
    store.data[:] = -1
    got, matched, _ = tree.match_prefix(a)      # promote straight from disk
    assert matched == 8
    assert pool.disk_hits == 1 and pool.tier_hits == 1
    for old, new in zip(pa, got):
        np.testing.assert_array_equal(store.data[new], snapshot[old])
    assert disk.num_entries == 0                # disk copy consumed
    _, mb, _ = tree.match_prefix(b)             # b still on host
    assert mb == 8


def test_disk_put_fault_degrades_to_drop(tmp_path):
    """A failing spill write rolls back and drops the node — the pre-disk
    behaviour — instead of crashing the host-LRU eviction path."""
    def boom():
        raise OSError("injected disk fault")
    tree, pool, store, host, disk = make_tiered3(tmp_path, host_budget=64,
                                                 io_hook=boom)
    a, b = list(range(8)), list(range(100, 108))
    insert_seq(tree, pool, store, a, fill=1)
    insert_seq(tree, pool, store, b, fill=2)
    assert tree.evict(2) == 2
    assert tree.evict(2) == 2                   # spill of a fails -> dropped
    assert pool.io_errors >= 1 and pool.spilled_pages == 0
    assert disk.num_entries == 0
    _, ma, _ = tree.match_prefix(a)
    assert ma == 0                              # a is gone, not corrupt
    _, mb, _ = tree.match_prefix(b)
    assert mb == 8                              # b unharmed on host


def test_disk_get_fault_truncates_promote(tmp_path):
    """A failing disk read during promotion truncates the match (the
    request recomputes the suffix); the on-disk node stays intact and a
    later healthy read still promotes it."""
    fail = []

    def flaky():
        if fail:
            raise OSError("injected disk fault")
    tree, pool, store, host, disk = make_tiered3(tmp_path, host_budget=64,
                                                 io_hook=flaky)
    a, b = list(range(8)), list(range(100, 108))
    pa = insert_seq(tree, pool, store, a, fill=1)
    snapshot = {p: store.data[p].copy() for p in pa}
    insert_seq(tree, pool, store, b, fill=2)
    tree.evict(2)
    tree.evict(2)                               # a on disk (healthy writes)
    fail.append(True)
    _, matched, _ = tree.match_prefix(a)
    assert matched == 0                         # truncated, not crashed
    assert pool.promote_failures == 1 and pool.io_errors == 1
    assert disk.num_entries == 2                # node survived the fault
    fail.clear()
    store.data[:] = -1
    got, matched, _ = tree.match_prefix(a)
    assert matched == 8 and pool.disk_hits == 1
    for old, new in zip(pa, got):
        np.testing.assert_array_equal(store.data[new], snapshot[old])


# --------------------------------------------------------- persist/restore
@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def run_one(engine, adapter, prompt, max_new=6):
    req = Request(rid=0, adapter_id=adapter, prompt=list(prompt),
                  max_new_tokens=max_new)
    engine.submit(req)
    while req.state != "done":
        engine.step()
    return req


def _sc(persist_dir, **kw):
    base = dict(page_size=16, max_pages=256, max_batch=4,
                max_prefill_tokens=64, mode="forkkv",
                max_pages_per_req=12, host_tier_bytes=64 << 20,
                persist_dir=str(persist_dir))
    base.update(kw)
    return ServeConfig(**base)


@pytest.mark.parametrize("codec", ["identity", "zstd", "int8"])
def test_persist_restore_token_parity(model, tmp_path, codec):
    """Acceptance: a new engine restoring a persisted manifest continues
    the same agent context with IDENTICAL greedy tokens, served from the
    tier (tier_hits > 0) instead of a full re-prefill — under every
    codec, since persisted blobs are stored logical (decoded)."""
    cfg, params, lora = model
    rng = np.random.default_rng(0)
    ctx = list(rng.integers(0, cfg.vocab_size, 64))
    probe = ctx + list(rng.integers(0, cfg.vocab_size, 8))

    eng1 = Engine(cfg, params, lora, _sc(tmp_path, kv_codec=codec))
    run_one(eng1, adapter=3, prompt=ctx)         # populate the radix tree
    ref = run_one(eng1, adapter=3, prompt=probe)  # unbroken-run continuation
    n = eng1.persist()
    assert n > 0

    eng2 = Engine(cfg, params, lora, _sc(tmp_path, kv_codec=codec))
    assert eng2.restore() == n                   # every page rehydrated
    req = run_one(eng2, adapter=3, prompt=probe)
    assert req.output == ref.output, "restored context diverged"
    m = eng2.metrics()
    assert m["restored_pages"] == n
    assert m["tier_hits"] > 0
    # the shared 64-token context came from the tier, not recompute
    assert req.prefilled_tokens < len(probe)


def test_restore_rejects_mismatched_geometry(model, tmp_path):
    cfg, params, lora = model
    eng1 = Engine(cfg, params, lora, _sc(tmp_path))
    rng = np.random.default_rng(1)
    run_one(eng1, 2, list(rng.integers(0, cfg.vocab_size, 48)))
    assert eng1.persist() > 0
    eng2 = Engine(cfg, params, lora, _sc(tmp_path, mode="prefix"))
    assert eng2.restore() == 0                   # mode mismatch: skip, no crash


def test_engine_survives_disk_io_fault_plan(model, tmp_path):
    """Engine-level ``disk_io`` fault injection: spills/promotes degrade
    (drop or truncate) and the run still completes every request."""
    cfg, params, lora = model
    sc = _sc(tmp_path, host_tier_bytes=1 << 20, disk_tier_bytes=32 << 20,
             fault_plan="disk_io:p0.5", fault_seed=7)
    eng = Engine(cfg, params, lora, sc)
    rng = np.random.default_rng(2)
    for i in range(4):
        req = run_one(eng, adapter=i + 1,
                      prompt=list(rng.integers(0, cfg.vocab_size, 64)))
        assert req.output and req.finish_reason == "length"


# -------------------------------------------------------------- percentile
def test_percentile_matches_numpy_linear():
    rng = np.random.default_rng(3)
    vals = sorted(rng.standard_normal(37).tolist())
    for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        assert percentile(vals, q) == pytest.approx(
            np.percentile(vals, q * 100), abs=1e-12)
    assert percentile([], 0.99) == 0.0
    assert percentile([4.2], 0.99) == 4.2
    # the regression: p99 of a small window must NOT be the window max
    small = sorted(rng.standard_normal(20).tolist())
    assert percentile(small, 0.99) < max(small)
    assert percentile(small, 0.99) == pytest.approx(
        np.percentile(small, 99))
