"""ResidualAttention kernel benchmark (paper §5.3).

On this CPU container the Pallas kernel runs in interpret mode (a Python
loop), so wall time is NOT indicative of TPU performance — correctness and
the XLA-path (flash) timing are.  We report:
  * interpret-mode kernel vs jnp oracle max error across a shape sweep,
  * XLA flash-disagg timing vs naive HBM reconstruction timing (the
    paper's §3.3 comparison at the XLA level): fused streaming vs full
    materialization.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import attention as attn_lib
from repro.core import rope as rope_lib
from repro.kernels import ref as ref_mod
from repro.kernels import residual_attention as ra


def kernel_error_sweep() -> None:
    for (sq, sk, hq, hkv, d, r) in [(128, 128, 4, 2, 64, 16),
                                    (64, 256, 8, 1, 128, 8)]:
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 8)
        B = 1
        q = jax.random.normal(ks[0], (B, sq, hq, d))
        kb = jax.random.normal(ks[1], (B, sk, hkv, d))
        vb = jax.random.normal(ks[2], (B, sk, hkv, d))
        kr = jax.random.normal(ks[3], (B, sk, r)) * 0.3
        vr = jax.random.normal(ks[4], (B, sk, r)) * 0.3
        bk = jax.random.normal(ks[5], (B, r, hkv * d)) * 0.3
        bv = jax.random.normal(ks[6], (B, r, hkv * d)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(sk), (B, sk))
        sin, cos = rope_lib.rope_sincos(pos, d)
        qpos = jnp.broadcast_to(jnp.arange(sq), (B, sq))
        kvl = jnp.full((B,), sk, jnp.int32)
        t0 = time.time()
        got = ra.residual_attention_prefill(
            q, kb, vb, kr, vr, bk, bv, sin, cos, qpos, kvl, scale=d**-0.5,
            block_q=64, block_k=64, interpret=True)
        us = (time.time() - t0) * 1e6
        want = ref_mod.residual_attention_ref(
            q, kb, vb, kr, vr, bk, bv, sin, cos, qpos=qpos, kv_len=kvl,
            scale=d**-0.5)
        err = float(jnp.max(jnp.abs(got - want)))
        emit(f"kernel.prefill.s{sq}x{sk}_h{hq}g{hkv}_d{d}_r{r}", us,
             f"max_err={err:.2e};interpret=True")


def fused_vs_materialized() -> None:
    """Flash-fused disagg attention vs naive HBM reconstruction (XLA)."""
    B, S, hq, hkv, d, r = 2, 2048, 8, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    q = jax.random.normal(ks[0], (B, S, hq, d))
    kb = jax.random.normal(ks[1], (B, S, hkv, d))
    vb = jax.random.normal(ks[2], (B, S, hkv, d))
    kr = jax.random.normal(ks[3], (B, S, r)) * 0.3
    vr = jax.random.normal(ks[4], (B, S, r)) * 0.3
    bk = jax.random.normal(ks[5], (B, r, hkv * d)) * 0.3
    bv = jax.random.normal(ks[6], (B, r, hkv * d)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    sin, cos = rope_lib.rope_sincos(pos, d)

    @jax.jit
    def fused(q, kb, vb, kr, vr, bk, bv):
        return attn_lib.flash_attention(q, kb, vb, qpos=pos, kpos=pos,
                                        causal=True, k_res=kr, v_res=vr,
                                        b_k=bk, b_v=bv)

    @jax.jit
    def materialized(q, kb, vb, kr, vr, bk, bv):
        k, v = ref_mod.reconstruct(kb, vb, kr, vr, bk, bv, sin, cos)
        return attn_lib.flash_attention(q, k, v, qpos=pos, kpos=pos,
                                        causal=True)

    for name, fn in (("fused", fused), ("materialized", materialized)):
        out = fn(q, kb, vb, kr, vr, bk, bv)
        out.block_until_ready()
        t0 = time.time()
        for _ in range(3):
            out = fn(q, kb, vb, kr, vr, bk, bv)
            out.block_until_ready()
        us = (time.time() - t0) / 3 * 1e6
        emit(f"kernel.xla.{name}", us, f"S={S};B={B}")


def main() -> None:
    kernel_error_sweep()
    fused_vs_materialized()


if __name__ == "__main__":
    main()
