"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun_*.json (produced by `python -m repro.launch.dryrun`)
and prints per (arch × shape × mesh): the three analytic roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO ratio and the raw cost_analysis
numbers (with the loops-once caveat, see launch/analytic.py).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def load(paths=("experiments/dryrun_single.json",
                "experiments/dryrun_multi.json")):
    recs = []
    for p in paths:
        if os.path.exists(p):
            recs.extend(json.load(open(p)))
    return recs


def main() -> None:
    recs = load()
    if not recs:
        emit("roofline.missing", 0,
             "run `python -m repro.launch.dryrun --arch all --shape all"
             " --mesh both --out ...` first")
        return
    for r in recs:
        name = f"roofline.{r.get('mesh','single')}.{r['arch']}.{r['shape']}"
        if r["status"] == "skipped":
            emit(name, 0, "skipped=long_500k-needs-subquadratic")
            continue
        if r["status"] != "ok":
            emit(name, 0, f"error={r.get('error','?')[:80]}")
            continue
        a = r["analytic"]
        t = a["terms"]
        emit(name, r.get("compile_s", 0) * 1e6,
             f"dominant={t['dominant'].replace('_s','')};"
             f"compute_s={t['compute_s']:.3e};"
             f"memory_s={t['memory_s']:.3e};"
             f"collective_s={t['collective_s']:.3e};"
             f"useful_frac={a.get('useful_fraction',0):.2f};"
             f"hlo_flops_raw={r['flops']:.2e};"
             f"hlo_coll_raw={r['collectives']['total']:.2e}")


if __name__ == "__main__":
    main()
