"""Paged-native serving decode (DESIGN.md §12): executor/engine behaviour.

Covers the shape-policy and phasing properties of the paged hot path:
  * compiled decode variants stay O(log max_batch) under a
    fluctuating-batch workload (power-of-two bucketing, no per-batch-size
    retraces);
  * batched prefill produces the same results as the seed's one-request-
    per-step chunking (implicitly: every test in the suite runs on it);
  * step-phase wall-clock metrics are populated.

Paged-vs-gather token parity lives in tests/test_parity_matrix.py — the
canonical cross-mode gate over {mode} x {paged, gather} x {attention
flavour} (DESIGN.md §13) that replaced this file's ad-hoc parity test.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams


@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def make_server(model, mode, *, paged=True, max_batch=4, max_pages=192,
                max_pages_per_req=12):
    cfg, params, lora = model
    sc = ServeConfig(page_size=16, max_pages=max_pages, max_batch=max_batch,
                     max_prefill_tokens=64, mode=mode,
                     max_pages_per_req=max_pages_per_req,
                     use_paged_kernel=paged)
    return ForkServer(cfg, params, lora, sc), cfg


def test_decode_jit_variants_logarithmic(model):
    """Fluctuating decode batch: requests with staggered generation
    lengths shrink the live batch 5 -> 1, but the executor buckets the
    compiled batch to powers of two (<= max_batch), so the number of
    compiled decode variants is bounded by log2(max_batch) + 1 — not by
    the number of distinct batch sizes seen."""
    max_batch = 8
    server, cfg = make_server(model, "forkkv", max_batch=max_batch)
    rng = np.random.default_rng(1)
    handles = []
    for i in range(5):
        prompt = list(rng.integers(0, cfg.vocab_size, 20 + i))
        handles.append(server.generate(
            i, prompt, SamplingParams(max_new_tokens=2 * i + 2)))
    outs = [o.tokens for o in server.wait(handles)]
    for i, toks in enumerate(outs):
        assert len(toks) == 2 * i + 2
    m = server.metrics()
    if m["decode_jit_variants"] < 0:
        pytest.skip("jit cache-size probe unavailable on this jax version")
    # batch sizes 5,4,3,2,1 were live; buckets {8,4,2,1} at most
    bound = int(math.log2(max_batch)) + 1
    assert 1 <= m["decode_jit_variants"] <= bound, m["decode_jit_variants"]
    # steady state: a second identical workload adds NO new variants
    before = m["decode_jit_variants"]
    hs = [server.generate(9, list(rng.integers(0, cfg.vocab_size, 24)),
                          SamplingParams(max_new_tokens=4))]
    server.wait(hs)
    assert server.metrics()["decode_jit_variants"] == before


def test_phase_metrics_populated(model):
    """Step-phase wall-clock metrics: prefill/decode both ran, and the
    per-chunk host sync is gone — sync happens once per step, so sync_ms
    exists but the counters are all finite and non-negative."""
    server, cfg = make_server(model, "forkkv")
    rng = np.random.default_rng(2)
    h = server.generate(1, list(rng.integers(0, cfg.vocab_size, 40)),
                        SamplingParams(max_new_tokens=4))
    out = server.wait([h])[0]
    assert len(out.tokens) == 4
    m = server.metrics()
    assert m["prefill_ms"] > 0
    assert m["decode_ms"] > 0
    assert m["sync_ms"] >= 0
    assert m["decode_steps"] >= 4


def test_batched_prefill_matches_sequential(model):
    """Batched multi-request prefill must not change outputs: N concurrent
    requests (co-scheduled chunks, one padded executor call) produce the
    same greedy tokens as the same prompts submitted one at a time."""
    cfg = model[0]
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, 30 + 7 * i))
               for i in range(3)]
    # concurrent: all three prefill together
    server, _ = make_server(model, "forkkv")
    hs = [server.generate(i + 1, p, SamplingParams(max_new_tokens=5))
          for i, p in enumerate(prompts)]
    concurrent = [o.tokens for o in server.wait(hs)]
    # sequential: fresh server, one request at a time (prefill batch = 1)
    server2, _ = make_server(model, "forkkv")
    sequential = []
    for i, p in enumerate(prompts):
        h = server2.generate(i + 1, p, SamplingParams(max_new_tokens=5))
        sequential.append(server2.wait([h])[0].tokens)
    assert concurrent == sequential
