"""internlm2-1.8b [dense]: GQA kv=8. [arXiv:2403.17297]"""
import dataclasses
from repro.core.config import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=8, d_ff=8192, vocab_size=92544,
    lora=LoRAConfig(rank=16), scan_layers=True,
    citation="arXiv:2403.17297")


def tiny() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internlm2-tiny", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        dtype="float32", remat=False)
