"""Paper Fig. 15 — sensitivity to LoRA rank and output length.

Rank linearly scales the rCache footprint; output length accumulates fresh
KV.  Both stress ForkKV's per-agent memory; we report throughput + peak
memory for ForkKV vs prefix caching.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, run_workflow


def main() -> None:
    for rank in (4, 8, 16):
        t0 = time.time()
        f = run_workflow("forkkv", "react", rank=rank, n_workflows=2,
                         agents=3, context=256, max_new=6, max_pages=192)
        p = run_workflow("prefix", "react", rank=rank, n_workflows=2,
                         agents=3, context=256, max_new=6, max_pages=192)
        emit(f"sensitivity.rank{rank}", (time.time() - t0) * 1e6,
             f"forkkv_tps={f['tasks']/f['wall_s']:.3f};"
             f"prefix_tps={p['tasks']/p['wall_s']:.3f};"
             f"forkkv_peak_MB={f['peak_cache_bytes']/2**20:.1f};"
             f"prefix_peak_MB={p['peak_cache_bytes']/2**20:.1f}")
    for max_new in (4, 8, 16):
        t0 = time.time()
        f = run_workflow("forkkv", "react", n_workflows=2, agents=3,
                         context=256, max_new=max_new, max_pages=192)
        p = run_workflow("prefix", "react", n_workflows=2, agents=3,
                         context=256, max_new=max_new, max_pages=192)
        emit(f"sensitivity.outlen{max_new}", (time.time() - t0) * 1e6,
             f"forkkv_tps={f['tasks']/f['wall_s']:.3f};"
             f"prefix_tps={p['tasks']/p['wall_s']:.3f}")


if __name__ == "__main__":
    main()
