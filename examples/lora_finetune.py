"""Train the substrate end-to-end: pretrain a ~small base model for a few
hundred steps, then fine-tune two LoRA agents on distinct synthetic tasks —
the adapters ForkKV serves.  Saves checkpoints.

Run:  PYTHONPATH=src python examples/lora_finetune.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.config import LoRAConfig, ModelConfig
from repro.models.registry import get_model
from repro.training import checkpoint, data, train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--lora-steps", type=int, default=100)
ap.add_argument("--ckpt-dir", default="/tmp/forkkv_ckpt")
args = ap.parse_args()

cfg = ModelConfig(name="base-demo", family="dense", num_layers=4,
                  d_model=128, num_heads=8, num_kv_heads=4, d_ff=256,
                  vocab_size=512, dtype="float32", lora=LoRAConfig(rank=8),
                  remat=False)
api = get_model(cfg)
init, step = train_loop.make_train_step(cfg, lr=2e-3)
params = api.init_params(jax.random.PRNGKey(0))
opt = init(params)
jstep = jax.jit(step)
t0 = time.time()
for i, b in zip(range(args.steps), data.make_stream(512, 64, 8)):
    params, opt, m = jstep(params, opt,
                           {k: jnp.asarray(v) for k, v in b.items()})
    if i % 50 == 0 or i == args.steps - 1:
        print(f"[base] step {i:4d} loss={float(m['loss']):.4f} "
              f"({(time.time()-t0)/(i+1):.3f}s/step)")
checkpoint.save(params, args.ckpt_dir, "base")

lora = api.init_lora_stacks(jax.random.PRNGKey(1), 2, nonzero=False)
for aid in (0, 1):
    linit, lstep = train_loop.make_lora_train_step(cfg, lr=5e-3,
                                                   adapter_id=aid)
    lopt = linit(lora)
    jl = jax.jit(lstep)
    for i, b in zip(range(args.lora_steps),
                    data.make_stream(512, 64, 8, task_id=3 + 5 * aid)):
        lora, lopt, m = jl(lora, lopt, params,
                           {k: jnp.asarray(v) for k, v in b.items()})
        if i % 50 == 0 or i == args.lora_steps - 1:
            print(f"[agent {aid}] step {i:4d} loss={float(m['loss']):.4f}")
checkpoint.save(lora, args.ckpt_dir, "lora_agents")
print(f"checkpoints in {args.ckpt_dir}: base.npz, lora_agents.npz")
