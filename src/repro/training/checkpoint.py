"""Minimal sharding-aware checkpointing (no orbax offline).

Saves a pytree of arrays to ``<dir>/<name>.npz`` with flattened key paths;
restores into the same treedef.  Device shardings are re-applied by the
caller via ``jax.device_put`` with the step's shardings.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree, directory: str, name: str) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{name}.npz")
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    return path


def restore(tree_like, directory: str, name: str):
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    path = os.path.join(directory, f"{name}.npz")
    data = np.load(path)
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for p, leaf in leaves_paths[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)


def exists(directory: str, name: str) -> bool:
    return os.path.exists(os.path.join(directory, f"{name}.npz"))
