"""Per-architecture smoke tests: reduced variants of all 10 assigned archs.

Each test instantiates the tiny() family variant, runs one forward pass and
one train step on CPU, and asserts output shapes + finiteness.  Decode-shape
smoke (one serve step) runs for every arch that has a decode path.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs as cfg_lib
from repro.models.registry import get_model
from repro.training import train_loop

ARCHS = list(cfg_lib.ARCH_IDS)


def _extra(cfg, batch):
    if cfg.frontend == "vision_stub":
        return jnp.zeros((batch, cfg.num_patches, cfg.d_model),
                         cfg.activation_dtype)
    if cfg.frontend == "audio_stub":
        return jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                         cfg.activation_dtype)
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = cfg_lib.get_tiny_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 4
    assert (cfg.num_experts or 0) <= 4
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    kwargs = {}
    extra = _extra(cfg, B)
    if extra is not None:
        kwargs["extra_embeds"] = extra
    logits = api.forward(params, tokens, **kwargs)
    exp_seq = S + (cfg.num_patches if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, exp_seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    init_opt, step = train_loop.make_train_step(cfg, lr=1e-3)
    opt = init_opt(params)
    batch = {"tokens": tokens, "labels": tokens}
    if extra is not None:
        batch["extra_embeds"] = extra
    params2, opt2, m = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert float(m["loss"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve_step(arch):
    """One decode step against a small cache (disagg where supported)."""
    cfg = cfg_lib.get_tiny_config(arch)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    B, S, P = 2, 16, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    disagg = api.supports_forkkv
    lora = api.init_lora_stacks(jax.random.PRNGKey(2), 4) \
        if api.init_lora_stacks else None
    ids = jnp.array([0, 3])
    cache = api.init_cache(B, P, disagg=disagg)
    kwargs = dict(lora=lora, adapter_ids=ids, disagg=disagg) \
        if lora is not None else {}
    pk = {}
    extra = _extra(cfg, B)
    if extra is not None and cfg.family == "audio":
        pk["extra_embeds"] = extra
    logits, cache = api.prefill(params, tokens, cache, **kwargs, **pk)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    kv_len = jnp.full((B,), S, jnp.int32)
    step_logits, cache = api.decode_step(
        params, tokens[:, -1], cache, kv_len, **kwargs)
    assert step_logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(step_logits.astype(jnp.float32)).all())


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = cfg_lib.get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v), arch
    assert cfg_lib.get_config("dbrx-132b").num_experts == 16
    assert cfg_lib.get_config("dbrx-132b").num_experts_per_tok == 4
    assert cfg_lib.get_config("llama4-maverick-400b-a17b").num_experts == 128
    assert cfg_lib.get_config(
        "llama4-maverick-400b-a17b").num_experts_per_tok == 1
    assert cfg_lib.get_config("mamba2-130m").ssm_state == 128
