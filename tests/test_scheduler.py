"""Iteration-level scheduler invariants + unified-grid oracle checks
(DESIGN.md §14).

The planner is pure (no model, no device), so its contract is locked
down directly on :class:`IterationScheduler`:

  * the token budget is never exceeded (except by decode rows, which are
    NEVER starved no matter how small the budget),
  * decode rows come first and are capped at ``max_batch``,
  * prefill chunks fill FCFS, bounded by prompt remainder, remaining
    budget and ``max_prefill_tokens``,
  * ``first_scheduled_at`` is stamped exactly once.

Plus: a direct numerics check of the unified mixed kernels against their
ref oracle (per-row q-lengths, exact-zero padding rows), and an
engine-level check that stall detection still fires under the
mixed-batching default.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import IterationScheduler


def mk_req(rid, state, prompt_len=100, pos=0, kv=0, out=0, max_new=8):
    r = Request(rid=rid, adapter_id=0, prompt=list(range(prompt_len)),
                max_new_tokens=max_new)
    r.state = state
    r.prefill_pos = pos
    r.kv_len = kv
    r.output = list(range(out))
    return r


# ---------------------------------------------------- planning invariants
def test_budget_never_exceeded_and_decode_priority():
    sc = ServeConfig(max_batch=4, max_prefill_tokens=32,
                     max_prefill_batch=8, iteration_token_budget=40)
    sched = IterationScheduler(sc)
    running = [mk_req(i, "decode", kv=50, out=2) for i in range(3)] + \
              [mk_req(10 + i, "prefill", prompt_len=200)
               for i in range(4)]
    plan = sched.plan(running)
    assert plan.total_tokens <= max(plan.budget, len(plan.decode_rows))
    assert plan.total_tokens <= 40
    # decode rows first, all of them, q=1 at the request's kv_len
    assert [rp.kind for rp in plan.rows[:3]] == ["decode"] * 3
    assert all(rp.q_len == 1 and rp.start == 50
               for rp in plan.decode_rows)
    assert all(rp.q_len <= sc.max_prefill_tokens
               for rp in plan.prefill_rows)


def test_decode_never_starved_by_tiny_budget():
    sc = ServeConfig(max_batch=8, iteration_token_budget=2)
    running = [mk_req(i, "decode", kv=50, out=1) for i in range(6)] + \
              [mk_req(10, "prefill", prompt_len=100)]
    plan = IterationScheduler(sc).plan(running)
    # every decode row runs even though the budget (2) can't cover them;
    # prefill gets nothing this iteration
    assert len(plan.decode_rows) == 6
    assert len(plan.prefill_rows) == 0


def test_decode_capped_at_max_batch_and_exhausted_rows_skipped():
    sc = ServeConfig(max_batch=2, iteration_token_budget=100)
    running = [mk_req(i, "decode", kv=50, out=1) for i in range(4)]
    running.append(mk_req(9, "decode", kv=50, out=9, max_new=8))
    plan = IterationScheduler(sc).plan(running)
    assert len(plan.decode_rows) == 2
    # a request that already has max_new+1 tokens is not schedulable
    assert all(rp.req.rid != 9 for rp in plan.rows)


def test_prefill_chunks_fcfs_with_prompt_and_budget_bounds():
    sc = ServeConfig(max_batch=4, max_prefill_tokens=16,
                     iteration_token_budget=24)
    running = [mk_req(1, "prefill", prompt_len=100, pos=90),  # 10 left
               mk_req(2, "prefill", prompt_len=100),
               mk_req(3, "prefill", prompt_len=100)]
    plan = IterationScheduler(sc).plan(running)
    q = {rp.req.rid: rp.q_len for rp in plan.prefill_rows}
    # final chunk: the exact 10-token remainder (tail pad paid once)
    assert q[1] == 10
    # mid-prompt chunks: budget remainder (24-10=14, then 24-18=6)
    # clamped DOWN to a power of two so the padded q tile stays tight
    assert q[2] == 8
    assert q[3] == 4
    assert plan.total_tokens == 22
    assert plan.rows[0].end == 100


def test_budget_exhaustion_stops_prefill_packing():
    sc = ServeConfig(max_batch=4, max_prefill_tokens=16,
                     iteration_token_budget=16)
    running = [mk_req(1, "prefill", prompt_len=16),
               mk_req(2, "prefill", prompt_len=100)]
    plan = IterationScheduler(sc).plan(running)
    q = {rp.req.rid: rp.q_len for rp in plan.prefill_rows}
    assert q == {1: 16}          # head takes the whole budget, FCFS
    assert plan.total_tokens == 16


def test_first_scheduled_stamped_once():
    sched = IterationScheduler(ServeConfig(iteration_token_budget=64))
    r = mk_req(1, "prefill", prompt_len=100)
    sched.plan([r], now=123.0)
    assert r.first_scheduled_at == 123.0
    sched.plan([r], now=456.0)
    assert r.first_scheduled_at == 123.0


def test_default_budget_covers_legacy_throughput():
    """budget=0 derives max_prefill_tokens + max_batch: a full decode
    batch ON TOP of the legacy prefill budget, so enabling mixed
    batching can never shrink per-step throughput."""
    sc = ServeConfig(max_batch=8, max_prefill_tokens=64)
    assert IterationScheduler(sc).budget == 64 + 8


def test_mixed_plan_flag():
    sched = IterationScheduler(ServeConfig(iteration_token_budget=64))
    both = sched.plan([mk_req(1, "decode", kv=10, out=1),
                       mk_req(2, "prefill", prompt_len=50)])
    assert both.is_mixed and both.q_max > 1
    assert not sched.plan([mk_req(1, "decode", kv=10, out=1)]).is_mixed


# ------------------------------------------- unified-grid kernel oracle
def _rand_mixed_inputs(key, *, window):
    """Random pools + a 3-row batch mixing a decode row (q_len=1), a full
    prefill chunk and a q_len=0 padding row."""
    page, hkv, g, d, r, npages = 8, 2, 2, 16, 4, 8
    sq = 4
    hq = hkv * g
    ks = jax.random.split(key, 8)
    kb = jax.random.normal(ks[0], (npages, page, hkv, d), jnp.float32)
    vb = jax.random.normal(ks[1], (npages, page, hkv, d), jnp.float32)
    kr = 0.1 * jax.random.normal(ks[2], (npages, page, r), jnp.float32)
    vr = 0.1 * jax.random.normal(ks[3], (npages, page, r), jnp.float32)
    q = jax.random.normal(ks[4], (3, sq, hq, d), jnp.float32)
    b_k = 0.1 * jax.random.normal(ks[5], (3, r, hkv * d), jnp.float32)
    b_v = 0.1 * jax.random.normal(ks[6], (3, r, hkv * d), jnp.float32)
    bt_b = jnp.asarray([[0, 1, 2], [3, 4, 5], [0, 0, 0]], jnp.int32)
    bt_r = jnp.asarray([[5, 6, 7], [1, 2, 3], [0, 0, 0]], jnp.int32)
    q_len = jnp.asarray([1, 4, 0], jnp.int32)       # decode | prefill | pad
    start = jnp.asarray([17, 4, 0], jnp.int32)
    kv_len = start + q_len
    kw = dict(scale=d ** -0.5, window=window, rope_theta=10_000.0,
              use_rope=True)
    return (q, kb, vb, kr, vr, b_k, b_v, bt_b, bt_r, start, q_len,
            kv_len), kw, q_len


@pytest.mark.parametrize("window", [0, 12])
def test_mixed_kernel_matches_ref_oracle(window):
    """The Pallas unified grid (interpret mode) must match the XLA mixed
    oracle row for row — including EXACT zeros past each row's q_len,
    the cross-backend determinism the prefill grid never promised."""
    from repro.kernels import paged_residual_attention as pra
    from repro.kernels import ref as ref_mod
    args, kw, q_len = _rand_mixed_inputs(jax.random.PRNGKey(0),
                                         window=window)
    got = pra.paged_residual_attention_mixed(*args, **kw, interpret=True)
    want = ref_mod.paged_residual_attention_mixed_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    for i, ql in enumerate(np.asarray(q_len)):
        np.testing.assert_array_equal(np.asarray(got)[i, ql:], 0.0)


@pytest.mark.parametrize("window", [0, 12])
def test_mixed_base_kernel_matches_ref_oracle(window):
    from repro.kernels import paged_residual_attention as pra
    from repro.kernels import ref as ref_mod
    args, kw, q_len = _rand_mixed_inputs(jax.random.PRNGKey(1),
                                         window=window)
    q, kb, vb = args[0], args[1], args[2]
    bt_b, start, q_len_, kv_len = args[7], args[9], args[10], args[11]
    base_kw = dict(scale=kw["scale"], window=window)
    got = pra.paged_attention_mixed_base(q, kb, vb, bt_b, start, q_len_,
                                         kv_len, **base_kw,
                                         interpret=True)
    want = ref_mod.paged_residual_attention_mixed_ref(
        q, kb, vb, None, None, None, None, bt_b, None, start, q_len_,
        kv_len, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    for i, ql in enumerate(np.asarray(q_len)):
        np.testing.assert_array_equal(np.asarray(got)[i, ql:], 0.0)


# --------------------------------------------- stall detection (engine)
@pytest.fixture(scope="module")
def small_model():
    cfg = tiny_serving_model(rank=8, num_layers=2, d_model=128,
                             vocab_size=512, num_heads=4, num_kv_heads=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=4)
    return cfg, params, lora


def test_stall_detection_fires_under_mixed_batching(small_model):
    """The §14 step restructure must keep the no-progress accounting: a
    request that can never allocate (pool pinned beyond its needs) still
    fails with ``stalled`` after ``stall_limit`` empty plans."""
    cfg, params, lora = small_model
    sc = ServeConfig(page_size=16, max_pages=12, max_batch=4,
                     max_prefill_tokens=48, max_pages_per_req=10,
                     stall_limit=6, mode="forkkv")
    assert sc.mixed_batching is True     # the default under test
    eng = Engine(cfg, params, lora, sc)
    rng = np.random.default_rng(0)
    ctx = Request(rid=1, adapter_id=0, max_new_tokens=0, is_context=True,
                  prompt=list(rng.integers(0, cfg.vocab_size, 96)))
    eng.submit(ctx)
    while ctx.state != "done":
        eng.step()
    pin = eng.pin_prefix(ctx.prompt, 0)          # 6 of 11 pages pinned
    big = Request(rid=2, adapter_id=1, max_new_tokens=4,
                  prompt=list(rng.integers(0, cfg.vocab_size, 120)))
    eng.submit(big)
    for _ in range(sc.stall_limit + 20):
        if big.state == "done":
            break
        eng.step()
    assert big.finish_reason == "stalled"
    assert "stalled" in big.error and big.output == []
    assert eng.metrics()["stalled"] == 1
    eng.unpin(pin)
