"""Paper Fig. 11 / Fig. 12 / Fig. 13 — end-to-end throughput.

ReAct and MapReduce workflows, ForkKV vs prefix caching vs full reuse, on
the tiny CPU serving model.  The sweep over concurrent workflows mirrors
Fig. 12 (memory pressure grows with workflow count, where ForkKV's smaller
per-agent footprint pays off); the paper's arrival-rate sweep (Fig. 13)
stresses the same mechanism and is represented by the high-workflow points.

Two throughput columns:
  * wall tasks/s — real CPU wall-clock (at toy scale this is dominated by
    per-step Python/dispatch overhead, which the disaggregated executor
    pays more of; NOT representative of GPU/TPU serving),
  * work-normalized tasks/ktok — tasks per thousand prefill-computed
    tokens, the scale-free measure of the recomputation ForkKV avoids
    (compute ∝ prefilled tokens dominates at the paper's 32K contexts).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, run_workflow

MODES = ("forkkv", "prefix", "full_reuse")


def sweep(workflow: str, n_workflows: int, max_pages: int,
          rounds: int = 1, context: int = 256) -> None:
    for mode in MODES:
        t0 = time.time()
        rep = run_workflow(mode, workflow, n_workflows=n_workflows,
                           agents=3, context=context, max_new=4,
                           max_pages=max_pages, max_batch=8, rounds=rounds)
        thr = rep["tasks"] / rep["wall_s"]
        work = rep["tasks"] / max(rep["prefilled_tokens"], 1) * 1000
        emit(f"throughput.{workflow}.wf{n_workflows}.r{rounds}.{mode}",
             (time.time() - t0) * 1e6,
             f"wall_tasks_per_s={thr:.3f};"
             f"work_tasks_per_ktok={work:.3f};"
             f"prefilled={rep['prefilled_tokens']:.0f};"
             f"hit_rate={rep['hit_rate']:.2f};"
             f"avg_batch={rep['avg_decode_batch']:.1f};"
             f"evicted={rep['evicted_pages']}")


def main() -> None:
    # Fig 11-style: medium pressure, single round
    for workflow in ("react", "mapreduce"):
        sweep(workflow, n_workflows=2, max_pages=192)
    # Fig 12/13-style: sustained multi-round load under a small pool —
    # prefix caching thrashes (evictions -> re-prefill); ForkKV's per-agent
    # footprint keeps everything resident
    sweep("react", n_workflows=3, max_pages=120, rounds=2)
    sweep("react", n_workflows=4, max_pages=110, rounds=2, context=448)


if __name__ == "__main__":
    main()
