"""RadixTree / DualRadixTree / PagePool — unit + hypothesis property tests.

The deterministic tests run everywhere; the property tests need
``hypothesis`` and are skipped in minimal environments.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # minimal env: keep deterministic tests running
    HAVE_HYPOTHESIS = False

from repro.serving.pool import PagePool
from repro.serving.radix import DualRadixTree, RadixTree

PAGE = 4


def make_tree(pages=256):
    pool = PagePool(pages, PAGE)
    return RadixTree(pool), pool


def insert_seq(tree, pool, toks):
    n = len(toks) // PAGE
    pages = pool.alloc(max(n, 0)) or []
    tree.insert(toks, pages)
    return pages


def test_match_after_insert_exact():
    t, pool = make_tree()
    toks = list(range(16))
    pages = insert_seq(t, pool, toks)
    got, matched, _ = t.match_prefix(toks)
    assert matched == 16 and got == pages


def test_partial_match_splits_node():
    t, pool = make_tree()
    toks = list(range(20))
    insert_seq(t, pool, toks)
    _, matched, _ = t.match_prefix(toks[:10])
    assert matched == 8              # page-aligned prefix of the split node
    # diverging branch shares the common prefix pages
    toks2 = toks[:12] + [99] * 8
    got, matched2, _ = t.match_prefix(toks2)
    assert matched2 == 12


def test_shared_pages_refcounted():
    t, pool = make_tree()
    toks = list(range(16))
    pages = insert_seq(t, pool, toks)
    for p in pages:
        assert pool.refcount(p) == 2     # caller + tree
    pool.decref(pages)                   # caller drops its refs
    for p in pages:
        assert pool.refcount(p) == 1     # tree keeps them alive
    t.evict(len(pages))
    for p in pages:
        assert pool.refcount(p) == 0


def test_eviction_respects_locks():
    t, pool = make_tree(pages=8)
    toks = list(range(16))
    pages = insert_seq(t, pool, toks)
    pool.decref(pages)
    _, _, path = t.match_prefix(toks, lock=True)
    assert t.evict(4) == 0               # locked: nothing evictable
    t.unlock_path(path)
    assert t.evict(4) >= 4


def test_unlock_after_foreign_split_releases_head():
    """Regression: splitting a LOCKED node copies the lock onto the new
    head; the locker's unlock must release the head too (walking the
    current parent chain), or the head stays pinned forever."""
    t, pool = make_tree()
    toks = list(range(16))
    pages = insert_seq(t, pool, toks)
    pool.decref(pages)
    _, _, path = t.match_prefix(toks, lock=True)
    t.match_prefix(toks[:8])             # second request splits locked node
    t.unlock_path(path)
    assert t.evict(4) >= 4               # nothing left pinned

    def walk(n):
        assert n.lock_ref == 0
        for c in n.children.values():
            walk(c)

    walk(t.root)


def test_lru_order():
    t, pool = make_tree()
    a = [1] * 8
    b = [2] * 8
    pa = insert_seq(t, pool, a)
    pb = insert_seq(t, pool, b)
    pool.decref(pa)
    pool.decref(pb)
    t.match_prefix(a)                    # touch a -> b becomes LRU
    t.evict(2)
    _, ma, _ = t.match_prefix(a)
    _, mb, _ = t.match_prefix(b)
    assert ma == 8 and mb == 0


def test_dual_fork_kinds():
    bp, rp = PagePool(64, PAGE), PagePool(64, PAGE)
    dual = DualRadixTree(bp, rp)
    toks = list(range(16))
    bpages = bp.alloc(4)
    rpages = rp.alloc(4)
    fr = dual.fork(toks, adapter_id=0, lock=False)
    assert fr.hit_kind == "miss"
    dual.commit(toks, 0, bpages, rpages)
    fr = dual.fork(toks, adapter_id=0, lock=False)
    assert fr.hit_kind == "full" and fr.reuse_len == 16
    # different adapter: base hits, residual misses -> partial_res (CoW)
    fr = dual.fork(toks, adapter_id=1, lock=False)
    assert fr.hit_kind == "partial_res"
    assert fr.base_len == 16 and fr.res_len == 0
    # decoupled eviction: evict base only -> partial_base (recompute xW only)
    dual.base.evict(4)
    fr = dual.fork(toks, adapter_id=0, lock=False)
    assert fr.hit_kind == "partial_base"
    assert fr.res_len == 16 and fr.base_len == 0


# ---------------------------------------------------------------- property
if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=40),
                    min_size=1, max_size=12))
    def test_property_match_is_prefix_and_refcounts_consistent(seqs):
        """For any insert sequence set: (1) every match is a true
        page-aligned prefix; (2) pool refcounts equal 1 (owner) + #tree
        nodes referencing."""
        pool = PagePool(1024, PAGE)
        tree = RadixTree(pool)
        owned = []
        for toks in seqs:
            n = len(toks) // PAGE
            pages = pool.alloc(n) if n else []
            assert pages is not None
            owned.append(pages)
            tree.insert(toks, pages)
            got, matched, _ = tree.match_prefix(toks)
            assert matched % PAGE == 0
            assert matched <= len(toks)
            assert len(got) == matched // PAGE
        # count tree references by walking
        refs = {}

        def walk(n):
            for p in n.pages:
                refs[p] = refs.get(p, 0) + 1
            for c in n.children.values():
                walk(c)

        walk(tree.root)
        for pages in owned:
            for p in pages:
                assert pool.refcount(p) == 1 + refs.get(p, 0)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3),
                              st.lists(st.integers(0, 2), min_size=4,
                                       max_size=32)),
                    min_size=1, max_size=10),
           st.integers(0, 30))
    def test_property_dual_fork_reuse_bounded(inserts, evictions):
        """fork() invariants: reuse <= min(base_len, res_len) <= prompt
        length, all page-aligned, under arbitrary inserts/evictions."""
        bp, rp = PagePool(512, PAGE), PagePool(512, PAGE)
        dual = DualRadixTree(bp, rp)
        for aid, toks in inserts:
            n = len(toks) // PAGE
            bpages = bp.alloc(n) or []
            rpages = rp.alloc(n) or []
            dual.commit(toks, aid, bpages, rpages)
        dual.base.evict(evictions)
        for aid, toks in inserts:
            fr = dual.fork(toks, aid, lock=False)
            assert fr.reuse_len == min(fr.base_len, fr.res_len)
            assert fr.base_len % PAGE == 0 and fr.res_len % PAGE == 0
            assert fr.base_len <= len(toks) and fr.res_len <= len(toks)
            assert len(fr.base_pages) == fr.base_len // PAGE
            assert len(fr.res_pages) == fr.res_len // PAGE
else:
    def test_property_radix_skipped_without_hypothesis():
        pytest.importorskip("hypothesis")


def test_warm_context_outranks_cold_cache_in_eviction():
    """Session-aware eviction (DESIGN.md §15): an unpinned-but-warm
    session context is evicted only after cold cache, even when the cold
    entry is more recently used."""
    t, pool = make_tree(pages=4)
    warm_toks = [1] * PAGE
    cold_toks = [2] * PAGE
    pw = insert_seq(t, pool, warm_toks)
    pc = insert_seq(t, pool, cold_toks)
    pool.decref(pw)
    pool.decref(pc)
    path, matched = t.pin(warm_toks)     # session pins its context...
    assert matched == PAGE
    t.unpin(path)                        # ...and closes: warm, unpinned
    t.match_prefix(cold_toks)            # cold entry is now MRU
    t.evict(1)
    _, mw, _ = t.match_prefix(warm_toks)
    _, mc, _ = t.match_prefix(cold_toks)
    # pure LRU would have evicted the warm context; warmth outranks it
    assert mw == PAGE and mc == 0
    # warmth is a rank, not a lock: under continued pressure the warm
    # context still goes (and a re-inserted entry starts cold again)
    t.evict(1)
    _, mw2, _ = t.match_prefix(warm_toks)
    assert mw2 == 0
