"""Tiered KV offload: CoW-aware HBM→host demotion/promotion (DESIGN.md §10).

The seed engine destroyed KV pages on LRU eviction, forcing a full
re-prefill of the shared bCache whenever device pages ran out.  This module
adds a second storage tier so eviction becomes *demotion*:

  * :class:`HostTier` — a numpy-backed page store with its own byte budget
    and LRU.  Entries hold the exact bytes of one KV page (all layers, K and
    V), so a later promotion restores the device cache bit-identically.
  * :class:`TieredPagePool` — a façade wrapping the existing
    :class:`~repro.serving.pool.PagePool`.  It keeps the whole refcounted
    device-page API (``alloc``/``incref``/``decref``/…) and adds the tier
    transitions used by the radix trees:

      - ``demote_node(node)``   device pages → host blobs; the radix node
        stays alive with ``tier == "host"`` and its ``pages`` list holding
        host *handles* instead of device page ids.
      - ``promote_node(node)``  host blobs → freshly allocated device pages
        (applying back-pressure through ``pressure_fn`` when the device
        pool is full); the node returns to ``tier == "device"``.

CoW invariants across tiers (DESIGN.md §10):
  * only pages whose sole reference is the radix tree (refcount == 1) are
    demoted — pages shared with in-flight requests never leave the device;
  * a demoted page is immutable in host memory; one demoted bCache page
    serves every agent that later re-forks it (the promotion re-creates a
    shared, refcounted device page);
  * nodes on a locked radix path (``lock_ref > 0``) are pinned in whichever
    tier they occupy: device eviction skips them and the host LRU refuses
    to drop their entries.

Below the host sits an optional third tier (DESIGN.md §18):

  * blob *codecs* — pluggable transforms applied on demote and reversed
    on promote (``identity`` / ``int8`` per-row-scale quantization /
    ``zstd`` lossless compression), so the host budget holds *stored*
    bytes, not logical bytes;
  * :class:`DiskTier` — a file-backed page store with the same
    handle/owner/LRU contract as :class:`HostTier`.  Host-LRU pressure
    *spills* whole nodes to disk (``tier == "disk"``) instead of
    destroying them; disk-LRU pressure is the true end of the line.

When the host budget is also exhausted the tier degrades to the seed
behaviour: true eviction (the node and its bytes are destroyed).
"""
from __future__ import annotations

import itertools
import json
import os
import zlib
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

# A blob is one page's worth of cache bytes: a dict of numpy arrays
# (e.g. {"k": (L, page, Hkv, hd), "v": ...}) produced by the executor's
# export_pages and consumed by import_pages.
Blob = Dict[str, np.ndarray]


def blob_bytes(blob: Blob) -> int:
    return sum(int(a.nbytes) for a in blob.values())


# --------------------------------------------------------------------------
# Blob codecs (DESIGN.md §18): encode on demote, decode on promote.
# Encoded blobs are still Dict[str, np.ndarray], so HostTier/DiskTier store
# and account them unchanged — the budget naturally tracks STORED bytes.
# --------------------------------------------------------------------------
def _dtype_name(dt) -> str:
    return np.dtype(dt).name


def _dtype_from_name(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                      # registered by jax anyway
        return np.dtype(getattr(ml_dtypes, name))


def _meta_arr(doc: dict) -> np.ndarray:
    return np.frombuffer(json.dumps(doc).encode(), np.uint8)


def _meta_doc(arr: np.ndarray) -> dict:
    return json.loads(bytes(arr).decode())


class IdentityCodec:
    """Pass-through: stored bytes == logical bytes, bit-identical."""

    name = "identity"
    lossless = True
    deterministic_size = True

    def encode(self, blob: Blob) -> Blob:
        return blob

    def decode(self, blob: Blob) -> Blob:
        return blob


class Int8Codec:
    """Symmetric per-row int8: ``scale = amax(|x|, axis=-1) / 127``.

    Mirrors the dense-cache ``ModelConfig.kv_quant`` math
    (transformer.quantize_kv): one float32 scale per trailing-axis row,
    so a (L, page, Hkv, hd) K blob quantizes per (layer, token, head).
    Lossy with bounded error: |x - deq(q)| <= scale/2 = amax/254 per row.
    Non-float arrays (e.g. already-int8 pool pages) pass through.
    """

    name = "int8"
    lossless = False
    deterministic_size = True

    def encode(self, blob: Blob) -> Blob:
        enc: Blob = {}
        for key, a in blob.items():
            if not np.issubdtype(np.dtype(a.dtype), np.floating) \
                    and _dtype_name(a.dtype) != "bfloat16":
                enc[key] = a
                continue
            x = np.asarray(a, np.float32)
            scale = np.abs(x).max(axis=-1) / 127.0
            scale = np.maximum(scale, 1e-8)
            q = np.clip(np.round(x / scale[..., None]), -127, 127)
            enc[key + ".q"] = q.astype(np.int8)
            enc[key + ".s"] = scale.astype(np.float32)
            enc[key + ".meta"] = _meta_arr({"dtype": _dtype_name(a.dtype)})
        return enc

    def decode(self, blob: Blob) -> Blob:
        dec: Blob = {}
        for key, a in blob.items():
            if key.endswith(".q"):
                base = key[:-2]
                scale = blob[base + ".s"]
                dt = _dtype_from_name(_meta_doc(blob[base + ".meta"])["dtype"])
                dec[base] = (a.astype(np.float32)
                             * scale[..., None]).astype(dt)
            elif key.endswith(".s") or key.endswith(".meta"):
                continue
            else:
                dec[key] = a
        return dec


class ZstdCodec:
    """Lossless byte compression per array.

    Uses the ``zstandard`` module when importable; this environment ships
    without it, so the codec gates on the import and falls back to stdlib
    ``zlib`` — same lossless bit-identical contract, different ratio/speed.
    ``backend`` records which one is active (surfaced in stats).
    """

    name = "zstd"
    lossless = True
    deterministic_size = False     # stored size is content-dependent

    def __init__(self):
        try:
            import zstandard
            self._c = zstandard.ZstdCompressor()
            self._d = zstandard.ZstdDecompressor()
            self.backend = "zstandard"
        except ImportError:
            self._c = self._d = None
            self.backend = "zlib"

    def _compress(self, raw: bytes) -> bytes:
        if self._c is not None:
            return self._c.compress(raw)
        return zlib.compress(raw, 6)

    def _decompress(self, data: bytes) -> bytes:
        if self._d is not None:
            return self._d.decompress(data)
        return zlib.decompress(data)

    def encode(self, blob: Blob) -> Blob:
        enc: Blob = {}
        for key, a in blob.items():
            raw = np.ascontiguousarray(a).tobytes()
            enc[key + ".z"] = np.frombuffer(self._compress(raw), np.uint8)
            enc[key + ".meta"] = _meta_arr({"dtype": _dtype_name(a.dtype),
                                            "shape": list(a.shape)})
        return enc

    def decode(self, blob: Blob) -> Blob:
        dec: Blob = {}
        for key, a in blob.items():
            if not key.endswith(".z"):
                continue
            base = key[:-2]
            meta = _meta_doc(blob[base + ".meta"])
            raw = self._decompress(bytes(a))
            dec[base] = np.frombuffer(
                raw, _dtype_from_name(meta["dtype"])).reshape(meta["shape"])
        return dec


_CODECS = {"identity": IdentityCodec, "int8": Int8Codec, "zstd": ZstdCodec}


def get_codec(name: str):
    if name not in _CODECS:
        raise ValueError(f"unknown KV codec {name!r} "
                         f"(choose from {sorted(_CODECS)})")
    return _CODECS[name]()


# --------------------------------------------------------------------------
# Blob file container: explicit dtype-name + shape header, so bfloat16
# arrays round-trip without pickling (np.savez chokes on extension dtypes).
# Shared by DiskTier entries and the persist()/restore() manifest.
# --------------------------------------------------------------------------
def write_blob_file(path: str, blob: Blob) -> int:
    meta = []
    payload = []
    for key, a in blob.items():
        raw = np.ascontiguousarray(a).tobytes()
        meta.append({"key": key, "dtype": _dtype_name(a.dtype),
                     "shape": list(a.shape), "nbytes": len(raw)})
        payload.append(raw)
    hdr = json.dumps(meta).encode()
    with open(path, "wb") as f:
        f.write(len(hdr).to_bytes(8, "little"))
        f.write(hdr)
        for raw in payload:
            f.write(raw)
    return 8 + len(hdr) + sum(len(r) for r in payload)


def read_blob_file(path: str) -> Blob:
    with open(path, "rb") as f:
        hlen = int.from_bytes(f.read(8), "little")
        meta = json.loads(f.read(hlen).decode())
        blob: Blob = {}
        for m in meta:
            raw = f.read(m["nbytes"])
            blob[m["key"]] = np.frombuffer(
                raw, _dtype_from_name(m["dtype"])).reshape(m["shape"])
    return blob


class HostTier:
    """Numpy-backed second-tier page store: byte budget + LRU.

    Handles are opaque ints.  Entries carry their *owner* (the
    :class:`TieredPagePool` that demoted them) so a shared HostTier can
    serve several device pools (bCache + rCache) under ONE host budget —
    host DRAM is a single resource.  When the budget overflows, the least
    recently used evictable entry is dropped and the owner is notified via
    ``owner._on_host_evict(handle)`` so it can unlink the radix node.
    """

    def __init__(self, budget_bytes: int):
        self.budget_bytes = int(budget_bytes)
        self.used_bytes = 0
        self._entries: Dict[int, tuple] = {}   # handle -> (blob, nbytes, owner)
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._handles = itertools.count(1)
        # counters
        self.put_count = 0
        self.get_count = 0
        self.evicted_entries = 0
        self.evicted_bytes = 0

    def __contains__(self, handle: int) -> bool:
        return handle in self._entries

    @property
    def num_entries(self) -> int:
        return len(self._entries)

    def put(self, blob: Blob, owner=None) -> Optional[int]:
        """Store one page blob; LRU-evict unpinned entries to make room.

        Returns a handle, or None when the blob cannot fit even after
        evicting everything evictable (budget exhausted → caller falls
        back to true eviction).
        """
        nbytes = blob_bytes(blob)
        if nbytes > self.budget_bytes:
            return None
        if self.used_bytes + nbytes > self.budget_bytes:
            # one forward pass over an LRU snapshot — never rescan pinned
            # entries; eviction hooks may drop collateral handles, so
            # skip any that vanished under us
            for h in list(self._lru):
                if self.used_bytes + nbytes <= self.budget_bytes:
                    break
                if h not in self._entries:
                    continue
                _, _, own = self._entries[h]
                if own is None or own.host_can_evict(h):
                    self._evict(h)
            if self.used_bytes + nbytes > self.budget_bytes:
                return None
        handle = next(self._handles)
        self._entries[handle] = (blob, nbytes, owner)
        self._lru[handle] = None
        self.used_bytes += nbytes
        self.put_count += 1
        return handle

    def _evict(self, handle: int) -> None:
        blob, nbytes, owner = self._entries.pop(handle)
        self._lru.pop(handle, None)
        self.used_bytes -= nbytes
        self.evicted_entries += 1
        self.evicted_bytes += nbytes
        if owner is not None:
            # the popped blob rides along so the owner can spill it to the
            # disk tier instead of losing the bytes (DESIGN.md §18)
            owner._on_host_evict(handle, blob)

    def get(self, handle: int) -> Blob:
        blob, _, _ = self._entries[handle]
        self._lru.move_to_end(handle)
        self.get_count += 1
        return blob

    def touch(self, handle: int) -> None:
        if handle in self._lru:
            self._lru.move_to_end(handle)

    def can_admit(self, nbytes: int) -> bool:
        """Could ``nbytes`` fit after evicting every unpinned entry?

        Demotion reserves its FULL blob total through this before storing
        anything: pinned (locked-node) entries don't count as evictable,
        so a demote that cannot complete never destroys other nodes'
        entries as collateral on the way to failing.
        """
        free = self.budget_bytes - self.used_bytes
        if nbytes <= free:
            return True
        evictable = sum(nb for h, (_, nb, own) in self._entries.items()
                        if own is None or own.host_can_evict(h))
        return nbytes <= free + evictable

    def free(self, handle: int) -> None:
        """Idempotent: freeing an already-evicted handle is a no-op."""
        if handle not in self._entries:
            return
        _, nbytes, _ = self._entries.pop(handle)
        self._lru.pop(handle, None)
        self.used_bytes -= nbytes


class DiskTier:
    """File-backed third-tier page store: byte budget + LRU, same
    handle/owner contract as :class:`HostTier`.

    Entries are blob files under ``root``; ``used_bytes`` counts the
    on-disk (stored, post-codec) sizes.  ``io_hook`` is an injectable
    pre-IO callable (the engine wires the ``disk_io`` fault site through
    it): a raising hook or a failing filesystem surfaces as an exception
    from ``put``/``get``, which the owning :class:`TieredPagePool`
    degrades — spill failure drops the node, promote failure truncates
    the match — never crashing the pump (DESIGN.md §17/§18).
    """

    def __init__(self, root: str, budget_bytes: int,
                 io_hook: Optional[Callable[[], None]] = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.budget_bytes = int(budget_bytes)
        self.io_hook = io_hook
        self.used_bytes = 0
        self._entries: Dict[int, tuple] = {}  # handle -> (path, nbytes, owner)
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._handles = itertools.count(1)
        self.put_count = 0
        self.get_count = 0
        self.evicted_entries = 0
        self.evicted_bytes = 0

    def __contains__(self, handle: int) -> bool:
        return handle in self._entries

    @property
    def num_entries(self) -> int:
        return len(self._entries)

    def put(self, blob: Blob, owner=None) -> Optional[int]:
        """Write one blob file; LRU-evict to make room.  Returns None when
        the blob cannot fit; raises on IO failure (caller degrades)."""
        est = blob_bytes(blob)
        if est > self.budget_bytes:
            return None
        if self.used_bytes + est > self.budget_bytes:
            for h in list(self._lru):
                if self.used_bytes + est <= self.budget_bytes:
                    break
                if h not in self._entries:
                    continue
                _, _, own = self._entries[h]
                if own is None or own.disk_can_evict(h):
                    self._evict(h)
            if self.used_bytes + est > self.budget_bytes:
                return None
        handle = next(self._handles)
        path = os.path.join(self.root, f"page_{handle:08d}.blob")
        if self.io_hook is not None:
            self.io_hook()
        nbytes = write_blob_file(path, blob)
        self._entries[handle] = (path, nbytes, owner)
        self._lru[handle] = None
        self.used_bytes += nbytes
        self.put_count += 1
        return handle

    def _evict(self, handle: int) -> None:
        path, nbytes, owner = self._entries.pop(handle)
        self._lru.pop(handle, None)
        self.used_bytes -= nbytes
        self.evicted_entries += 1
        self.evicted_bytes += nbytes
        try:
            os.unlink(path)
        except OSError:
            pass
        if owner is not None:
            owner._on_disk_evict(handle)

    def get(self, handle: int) -> Blob:
        path, _, _ = self._entries[handle]
        self._lru.move_to_end(handle)
        self.get_count += 1
        if self.io_hook is not None:
            self.io_hook()
        return read_blob_file(path)

    def touch(self, handle: int) -> None:
        if handle in self._lru:
            self._lru.move_to_end(handle)

    def can_admit(self, nbytes: int) -> bool:
        free = self.budget_bytes - self.used_bytes
        if nbytes <= free:
            return True
        evictable = sum(nb for h, (_, nb, own) in self._entries.items()
                        if own is None or own.disk_can_evict(h))
        return nbytes <= free + evictable

    def free(self, handle: int) -> None:
        if handle not in self._entries:
            return
        path, nbytes, _ = self._entries.pop(handle)
        self._lru.pop(handle, None)
        self.used_bytes -= nbytes
        try:
            os.unlink(path)
        except OSError:
            pass


class TieredPagePool:
    """Façade over a device :class:`PagePool` adding a host demotion tier.

    Exposes the full PagePool API (the radix trees and the engine keep
    using it unchanged) plus the demote/promote transitions.  Device↔host
    byte movement is delegated to callbacks bound by the engine:

      export_fn(pages)        -> [blob, ...]   device → host copies
      import_fn(pages, blobs)                  host → device copies
      pressure_fn(n)                           free ≥ n device pages
                                               (tree LRU evict/demote)

    ``codec`` transforms blobs on the way in/out of the host tier
    (identity/int8/zstd — DESIGN.md §18); ``disk`` adds the third tier:
    host-LRU pressure spills whole nodes to it instead of destroying
    them, and promotion reads disk-tier nodes straight back to device.
    """

    is_tiered = True

    def __init__(self, pool, host: HostTier,
                 export_fn: Optional[Callable] = None,
                 import_fn: Optional[Callable] = None,
                 pressure_fn: Optional[Callable[[int], int]] = None,
                 promote_limit: int = 0,
                 codec=None, disk: Optional[DiskTier] = None):
        self.pool = pool
        self.host = host
        self.disk = disk
        self.codec = codec if codec is not None else IdentityCodec()
        self.export_fn = export_fn
        self.import_fn = import_fn
        self.pressure_fn = pressure_fn
        self.promote_limit = promote_limit   # max pages promoted per match
        self._node_of: Dict[int, object] = {}  # host handle -> radix Node
        self._node_of_disk: Dict[int, object] = {}  # disk handle -> Node
        self._match_promoted = 0
        self._page_nbytes: Optional[int] = None  # stored size, learned once
        # counters
        self.tier_hits = 0            # promote events (one per node)
        self.disk_hits = 0            # promote events served from disk
        self.demoted_pages = 0
        self.demoted_bytes = 0        # logical bytes demoted
        self.promoted_pages = 0
        self.promoted_bytes = 0       # logical bytes promoted
        self.spilled_pages = 0        # host → disk spills
        self.host_evicted_pages = 0   # pages truly lost from the host tier
        self.disk_evicted_pages = 0   # pages truly lost from the disk tier
        self.dropped_device_pages = 0  # device pages lost to host-LRU cascade
        self.demote_failures = 0
        self.promote_failures = 0
        self.io_errors = 0            # export/import raised (DESIGN.md §17)
        self.codec_logical_bytes = 0  # pre-codec bytes entering the host
        self.codec_stored_bytes = 0   # post-codec bytes actually stored

    def bind(self, export_fn: Callable, import_fn: Callable,
             pressure_fn: Optional[Callable[[int], int]] = None) -> None:
        self.export_fn = export_fn
        self.import_fn = import_fn
        self.pressure_fn = pressure_fn

    # -------------------------------------------------- PagePool façade
    def can_alloc(self, n: int) -> bool:
        return self.pool.can_alloc(n)

    def alloc(self, n: int) -> Optional[List[int]]:
        return self.pool.alloc(n)

    def incref(self, pages: Sequence[int]) -> None:
        self.pool.incref(pages)

    def decref(self, pages: Sequence[int]) -> List[int]:
        return self.pool.decref(pages)

    def refcount(self, page: int) -> int:
        return self.pool.refcount(page)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return self.pool.pages_for_tokens(n_tokens)

    @property
    def num_pages(self) -> int:
        return self.pool.num_pages

    @property
    def page_size(self) -> int:
        return self.pool.page_size

    @property
    def name(self) -> str:
        return self.pool.name

    @property
    def used_pages(self) -> int:
        return self.pool.used_pages

    @property
    def free_pages(self) -> int:
        return self.pool.free_pages

    @property
    def utilization(self) -> float:
        return self.pool.utilization

    @property
    def alloc_count(self) -> int:
        return self.pool.alloc_count

    @property
    def oom_count(self) -> int:
        return self.pool.oom_count

    # ---------------------------------------------------- tier bridging
    def begin_match(self) -> None:
        """Reset the per-match promotion budget (``tier_promote_limit``)."""
        self._match_promoted = 0

    def promote_room(self) -> Optional[int]:
        """Pages the current match may still promote (None = unlimited).
        The matcher splits oversized host nodes at this boundary so a node
        larger than the whole limit still promotes incrementally."""
        if not self.promote_limit:
            return None
        return max(0, self.promote_limit - self._match_promoted)

    def host_can_evict(self, handle: int) -> bool:
        """Host LRU guard: entries of locked (in-use) or session-pinned
        nodes are untouchable."""
        node = self._node_of.get(handle)
        return node is None or (node.lock_ref == 0 and node.pin_ref == 0)

    def disk_can_evict(self, handle: int) -> bool:
        """Disk LRU guard — same lock/pin contract as the host tier."""
        node = self._node_of_disk.get(handle)
        return node is None or (node.lock_ref == 0 and node.pin_ref == 0)

    def demote_node(self, node) -> bool:
        """Copy a node's device pages to the host tier and free them.

        CoW guard: only applies when the tree is the sole owner of every
        page (refcount == 1).  On success the node survives with
        ``tier == "host"`` and ``pages`` holding host handles.  Returns
        False (caller falls back to true eviction) when the export path is
        unbound, a page is still shared, or the host budget is exhausted.
        """
        pages = list(node.pages)
        if not pages or self.export_fn is None:
            return False
        if node.pin_ref > 0:
            # session-pinned context: immune to demotion too — a live
            # session's whole point is keeping its prefix hot on device
            return False
        if any(self.pool.refcount(p) != 1 for p in pages):
            return False
        # Pin the WHOLE ancestor chain, not just the victim: host.put may
        # LRU-evict a host-tier ancestor, whose _drop_subtree would reach
        # down and free this node's device pages mid-demote (double free).
        # Locks cover the whole path — same convention as match_prefix.
        chain = []
        n = node
        while n is not None:
            n.lock_ref += 1
            chain.append(n)
            n = n.parent
        try:
            # STORED blob size per page is deterministic for size-stable
            # codecs (identity/int8): once learned, a doomed demote is
            # rejected BEFORE paying the device→host export + encode it
            # would only throw away.  zstd sizes are content-dependent, so
            # the authoritative post-encode check below decides alone.
            if self._page_nbytes is not None and not self.host.can_admit(
                    len(pages) * self._page_nbytes):
                self.demote_failures += 1
                return False
            try:
                blobs = self.export_fn(pages)
            except Exception:
                # IO fault (DESIGN.md §17): nothing was moved — the node
                # keeps its device pages and the caller falls back to
                # true eviction, so a flaky export degrades to the seed's
                # destroy-on-evict instead of crashing the pump
                self.io_errors += 1
                self.demote_failures += 1
                return False
            logical = sum(blob_bytes(b) for b in blobs)
            blobs = [self.codec.encode(b) for b in blobs]
            stored = sum(blob_bytes(b) for b in blobs)
            if self.codec.deterministic_size:
                self._page_nbytes = blob_bytes(blobs[0])
            # admission reserves what will actually be STORED — reserving
            # logical (pre-codec) sizes would over-evict peers and
            # under-fill the budget (the accounting bug this PR fixes)
            if not self.host.can_admit(stored):
                # the node cannot fit (budget too small, or the remainder
                # is pinned): fail before the put loop evicts other nodes'
                # entries as collateral for a doomed demote
                self.demote_failures += 1
                return False
            handles: List[int] = []
            for blob in blobs:
                h = self.host.put(blob, self)
                if h is None:
                    for hh in handles:
                        self._node_of.pop(hh, None)
                        self.host.free(hh)
                    self.demote_failures += 1
                    return False
                self._node_of[h] = node
                handles.append(h)
            self.pool.decref(pages)              # device pages become free
            node.pages = handles
            node.tier = "host"
            self.demoted_pages += len(pages)
            self.demoted_bytes += logical
            self.codec_logical_bytes += logical
            self.codec_stored_bytes += stored
            return True
        finally:
            for n in chain:
                n.lock_ref -= 1

    def promote_node(self, node) -> bool:
        """Copy a host-tier node back into freshly allocated device pages.

        The caller must hold a lock on the node (match does), which pins
        its host entries while ``pressure_fn`` makes room on the device.
        On success the node is a normal device node again, its pages owned
        by the tree (refcount 1).  Returns False when the promote budget
        for this match is spent or the device pool stays full — the match
        then truncates (partial hit), never corrupts.
        """
        handles = list(node.pages)
        n = len(handles)
        if n == 0 or self.import_fn is None:
            return False
        if self.promote_limit and self._match_promoted + n > self.promote_limit:
            self.promote_failures += 1
            return False
        from_disk = node.tier == "disk"
        store = self.disk if from_disk else self.host
        node_of = self._node_of_disk if from_disk else self._node_of
        for h in handles:
            store.touch(h)
        pages = self.pool.alloc(n)
        if pages is None and self.pressure_fn is not None:
            self.pressure_fn(n - self.pool.free_pages)
            pages = self.pool.alloc(n)
        if pages is None:
            self.promote_failures += 1
            return False
        try:
            blobs = [self.codec.decode(store.get(h)) for h in handles]
            self.import_fn(pages, blobs)
        except Exception:
            # IO fault (disk read or device import): give back the device
            # pages just allocated; the stored entries are untouched, so
            # the node stays a valid host/disk-tier node and the match
            # truncates (partial hit) — the request recomputes the suffix
            # instead of dying
            self.pool.decref(pages)
            self.io_errors += 1
            self.promote_failures += 1
            return False
        for h in handles:
            node_of.pop(h, None)
            store.free(h)
        node.pages = pages
        node.tier = "device"
        self.tier_hits += 1
        if from_disk:
            self.disk_hits += 1
        self.promoted_pages += n
        self._match_promoted += n
        self.promoted_bytes += sum(blob_bytes(b) for b in blobs)
        return True

    def host_put_blobs(self, blobs: Sequence[Blob]) -> Optional[List[int]]:
        """Encode and store logical blobs in the host tier (restore path).
        All-or-nothing: on any failure the already-stored entries are
        freed and None is returned."""
        enc = [self.codec.encode(b) for b in blobs]
        stored = sum(blob_bytes(b) for b in enc)
        if not self.host.can_admit(stored):
            return None
        handles: List[int] = []
        for b in enc:
            h = self.host.put(b, self)
            if h is None:
                for hh in handles:
                    self._node_of.pop(hh, None)
                    self.host.free(hh)
                return None
            handles.append(h)
        logical = sum(blob_bytes(b) for b in blobs)
        self.codec_logical_bytes += logical
        self.codec_stored_bytes += stored
        return handles

    def adopt_host_handles(self, handles: Sequence[int], node) -> None:
        """Register restored host handles as owned by ``node`` (so host-LRU
        eviction and spill find their radix node)."""
        for h in handles:
            self._node_of[h] = node

    def retarget(self, handles: Sequence[int], node) -> None:
        """Re-own handles after a radix node split moved them to a new node.
        Splits happen in whichever tier the node occupies, so both handle
        namespaces are checked."""
        for h in handles:
            if node.tier == "disk":
                if h in self._node_of_disk:
                    self._node_of_disk[h] = node
            elif h in self._node_of:
                self._node_of[h] = node

    def _on_host_evict(self, handle: int, blob: Optional[Blob] = None) -> None:
        """Host LRU dropped one of our entries.  With a disk tier bound,
        the owning node SPILLS — its whole blob set moves to disk files and
        the node survives with ``tier == "disk"``.  Without one (or when
        the spill fails), the node and any children go with it — the
        pre-§18 behaviour."""
        node = self._node_of.pop(handle, None)
        if node is None:
            return
        if self.disk is not None and node.tier == "host" \
                and self._spill_node_to_disk(node, handle, blob):
            return
        self._drop_subtree(node)

    def _spill_node_to_disk(self, node, handle: int,
                            blob: Optional[Blob]) -> bool:
        """Move one host-tier node's blobs to the disk tier.  ``handle``
        was already popped from the host store; its blob rides in by
        value.  Children stay attached whatever their tier."""
        blobs = []
        for h in node.pages:
            if h == handle:
                if blob is None:
                    return False
                blobs.append(blob)
            elif h in self.host:
                blobs.append(self.host.get(h))
            else:
                return False       # partially-gone node: cannot spill
        if not self.disk.can_admit(sum(blob_bytes(b) for b in blobs)):
            return False
        dhandles: List[int] = []
        try:
            for b in blobs:
                dh = self.disk.put(b, self)
                if dh is None:
                    raise OSError("disk tier full")
                self._node_of_disk[dh] = node
                dhandles.append(dh)
        except Exception:
            # disk write failed (IO fault or budget): roll back and let the
            # caller drop the node — degrade, don't crash
            for dh in dhandles:
                self._node_of_disk.pop(dh, None)
                self.disk.free(dh)
            self.io_errors += 1
            return False
        for h in node.pages:
            if h != handle:
                self._node_of.pop(h, None)
                self.host.free(h)
        self.spilled_pages += len(dhandles)
        node.pages = dhandles
        node.tier = "disk"
        return True

    def _on_disk_evict(self, handle: int) -> None:
        """Disk LRU dropped an entry: the end of the line — the owning
        node (and any children) is destroyed."""
        node = self._node_of_disk.pop(handle, None)
        if node is None:
            return
        self._drop_subtree(node)

    def _drop_subtree(self, node) -> None:
        """Destroy a radix subtree whose bytes are gone (true eviction of
        host-tier state).  Safe on mixed subtrees: device descendants give
        their pages back to the device pool.

        Never reachable for in-use state: a locked node implies a locked
        ancestor chain (match and demote both pin root→node), so
        ``host_can_evict`` refuses every entry above it — asserted here
        so a future violation fails loudly instead of double-freeing."""
        assert node.lock_ref == 0, "dropping a locked (in-use) radix node"
        assert node.pin_ref == 0, "dropping a session-pinned radix node"
        for child in list(node.children.values()):
            self._drop_subtree(child)
        if node.tier == "host":
            self.host_evicted_pages += len(node.pages)
            for h in node.pages:
                self._node_of.pop(h, None)
                self.host.free(h)       # idempotent: triggering handle gone
        elif node.tier == "disk":
            self.disk_evicted_pages += len(node.pages)
            for h in node.pages:
                self._node_of_disk.pop(h, None)
                self.disk.free(h)       # idempotent: triggering handle gone
        elif node.pages:
            self.dropped_device_pages += len(node.pages)
            self.pool.decref(node.pages)
        if node.parent is not None:
            node.parent.children.pop(node.key[0], None)
        node.pages = []
        node.children = {}

    def stats(self) -> Dict[str, int]:
        return {
            "tier_hits": self.tier_hits,
            "disk_hits": self.disk_hits,
            "demoted_pages": self.demoted_pages,
            "demoted_bytes": self.demoted_bytes,
            "promoted_pages": self.promoted_pages,
            "promoted_bytes": self.promoted_bytes,
            "spilled_pages": self.spilled_pages,
            "host_evicted_pages": self.host_evicted_pages,
            "disk_evicted_pages": self.disk_evicted_pages,
            "dropped_device_pages": self.dropped_device_pages,
            "demote_failures": self.demote_failures,
            "promote_failures": self.promote_failures,
            "tier_io_errors": self.io_errors,
            "codec_logical_bytes": self.codec_logical_bytes,
            "codec_stored_bytes": self.codec_stored_bytes,
        }
