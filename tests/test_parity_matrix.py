"""Cross-mode parity test matrix — the canonical tier-1 serving gate.

One parametrized greedy token-parity suite over

    {forkkv, prefix, full_reuse} x {paged, gather} x {dense, GQA, MQA, SWA}

through the public ``ForkServer`` API, replacing the ad-hoc per-PR parity
tests (PR 2's forkkv-vs-prefix check, PR 3's paged-vs-gather check): for
every serve mode and attention flavour, the page-native kernels
(decode AND chunked prefill, DESIGN.md §12/§13) must produce bit-identical
greedy tokens to the legacy gather-to-contiguous oracle path — and the
paged path must issue ZERO gather-to-contiguous copies, asserted via the
``fallback_gather_calls`` metric (the regression guard that SWA models can
never silently fall back again).

Backends: the suite runs under whichever kernel backend
``FORKKV_KERNEL_BACKEND`` / ``REPRO_ATTN_BACKEND`` selects (CI runs it
once with ``ref`` and once with ``pallas-interpret``).
"""
import numpy as np
import pytest

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams

import jax

PAGE = 16

# attention flavours: MHA, grouped-query, multi-query, sliding-window.
# The SWA window (24) deliberately straddles a page boundary and is
# shorter than the 40-token shared context, so out-of-window masking and
# the window-clamped page walk are both exercised.
ARCHS = {
    "dense": dict(num_heads=4, num_kv_heads=4),
    "gqa": dict(num_heads=8, num_kv_heads=2),
    "mqa": dict(num_heads=4, num_kv_heads=1),
    "swa": dict(num_heads=4, num_kv_heads=2, sliding_window=24),
}
MODES = ("forkkv", "prefix", "full_reuse")


@pytest.fixture(scope="module")
def models():
    """Lazily-built (cfg, params, lora) per attention flavour."""
    cache = {}

    def get(arch: str):
        if arch not in cache:
            cfg = tiny_serving_model(rank=8, num_layers=2, d_model=128,
                                     vocab_size=512, **ARCHS[arch])
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1),
                                        n_adapters=4)
            cache[arch] = (cfg, params, lora)
        return cache[arch]

    return get


def run_workload(model, mode: str, paged: bool):
    """The shared workload: one pinned session context, two CoW forks
    under different adapters, greedy decode.  Deterministic in everything
    but the (mode, paged, arch) cell under test."""
    cfg, params, lora = model
    sc = ServeConfig(page_size=PAGE, max_pages=96, max_batch=4,
                     max_prefill_tokens=48, max_pages_per_req=8,
                     mode=mode, use_paged_kernel=paged)
    server = ForkServer(cfg, params, lora, sc)
    rng = np.random.default_rng(7)
    ctx = list(rng.integers(0, cfg.vocab_size, 40))
    with server.session(ctx, adapter_id=0) as sess:
        handles = [sess.fork(a, list(rng.integers(0, cfg.vocab_size, 4 + a)),
                             SamplingParams(max_new_tokens=5))
                   for a in (1, 2)]
        outs = [o.tokens for o in server.wait(handles)]
    return outs, server.metrics()


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mode", MODES)
def test_paged_vs_gather_token_parity(models, mode, arch):
    """Greedy outputs must be token-identical between the page-native
    kernels and the legacy gather path — same workload, same session/fork
    calls, only ``ServeConfig.use_paged_kernel`` flipped — and the paged
    run must never gather: ``fallback_gather_calls == 0``."""
    model = models(arch)
    paged_out, paged_m = run_workload(model, mode, paged=True)
    gather_out, gather_m = run_workload(model, mode, paged=False)
    assert all(len(t) == 5 for t in paged_out)
    assert paged_out == gather_out

    # the paged path is fully page-native — SWA included, no silent
    # fallback (the PR-5 regression guard)
    assert paged_m["use_paged_kernel"] is True
    assert paged_m["fallback_gather_calls"] == 0
    # and the gather path is VISIBLE from day one: every prefill/decode
    # executor call shows up in the metric
    assert gather_m["use_paged_kernel"] is False
    assert gather_m["fallback_gather_calls"] > 0
