"""Long-context decode with sub-quadratic architectures.

Demonstrates why long_500k runs only for SSM/hybrid/SWA archs: their decode
state is O(1) or window-bounded, so a 500k-token context costs the same
per step as a 1k one.  Uses the tiny mamba2 + recurrentgemma variants.

Run:  PYTHONPATH=src python examples/long_context_ssm.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.models.registry import get_model

for arch in ("mamba2-130m", "recurrentgemma-9b", "h2o-danube-3-4b"):
    cfg = cfg_lib.get_tiny_config(arch)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    B = 2
    cache = api.init_cache(B, 4096, disagg=False)
    # simulate a long prefix: prefill in chunks, then time decode steps
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 256), 0,
                              cfg.vocab_size)
    _, cache = api.prefill(params, toks, cache)
    kv_len = jnp.full((B,), 256, jnp.int32)
    tok = toks[:, -1]
    # warmup + timed decode
    lg, cache = api.decode_step(params, tok, cache, kv_len)
    t0 = time.time()
    for _ in range(10):
        lg, cache = api.decode_step(params, tok, cache, kv_len)
        kv_len = kv_len + 1
    dt = (time.time() - t0) / 10 * 1e3
    state_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(cache))
    print(f"{arch:22s} decode {dt:7.1f} ms/step, "
          f"state cache {state_bytes/2**20:6.1f} MB "
          f"(constant in context length)")
