"""ReAct agent TREE via explicit fork() handles (DESIGN.md §11).

Demonstrates the session-centric serving API end-to-end:

  1. ``server.session(project_context)`` prefills a shared "project"
     context ONCE and pins it — the whole agent tree below inherits it
     copy-on-write, and no memory pressure can evict it mid-run.
  2. A *planner* agent forks the context and streams its plan token by
     token (``handle.stream()`` — tokens arrive as decode steps produce
     them, before the request completes).
  3. Each "plan step" spawns a *worker* subtree: a researcher fork plus a
     critic fork per worker, each with its own LoRA adapter and sampling
     policy, run concurrently through one ``server.poll()`` pump.
  4. A *synthesizer* agent forks once more over everything the tree
     produced (the ReAct observation chain).

Run:  PYTHONPATH=src python examples/react_agent_tree.py \
          [--mode forkkv|prefix|full_reuse] [--temperature 0.7]
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import build_server              # noqa: E402
from repro.serving.api import SamplingParams             # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--mode", default="forkkv",
                choices=["forkkv", "prefix", "full_reuse"])
ap.add_argument("--temperature", type=float, default=0.0)
ap.add_argument("--context", type=int, default=192)
ap.add_argument("--workers", type=int, default=2)
args = ap.parse_args()

server, cfg = build_server(args.mode, max_pages=256, max_batch=8,
                           n_adapters=16, max_pages_per_req=24)
rng = np.random.default_rng(0)
project = list(rng.integers(0, cfg.vocab_size, size=args.context))
greedy = SamplingParams(max_new_tokens=8)
creative = SamplingParams(temperature=args.temperature or 0.0,
                          top_k=50, seed=7, max_new_tokens=8)

with server.session(project) as session:
    # --- planner: stream the plan as it decodes ---------------------------
    print(f"[{args.mode}] planner streaming:", end=" ", flush=True)
    planner = session.fork(0, rng.integers(0, cfg.vocab_size, 16).tolist(),
                           creative)
    plan = []
    for ev in planner.stream():
        if ev.finished:
            print(f" <{ev.finish_reason}>")
        else:
            plan.append(ev.token)
            print(ev.token, end=" ", flush=True)

    # --- worker subtrees: researcher + critic per plan step ---------------
    observations = []
    handles = []
    for w in range(args.workers):
        instr = plan + rng.integers(0, cfg.vocab_size, 8).tolist()
        handles.append(("researcher", w,
                        session.fork(1 + 2 * w, instr, greedy)))
        handles.append(("critic", w,
                        session.fork(2 + 2 * w, instr, creative)))
    for role, w, h in handles:
        out = h.result()
        observations += out.tokens
        print(f"  {role}[{w}] adapter={h.adapter_id}: {len(out.tokens)} "
              f"tokens, reason={out.finish_reason}, "
              f"prefill_share={out.metrics['prefill_share']:.0f}")

    # --- synthesizer over the whole tree's observations -------------------
    final = session.fork(15, observations[:64], greedy).result()
    print(f"  synthesizer: {final.tokens}")

m = server.metrics()
print(f"summary mode={m['mode']} tasks={m['tasks_done']} "
      f"hit_rate={m['hit_rate']:.2f} hit_kinds={m.get('hit_kinds')} "
      f"peak_base_pages={m['peak_base_pages']} "
      f"prefill_saved={m['prefill_saved_frac']:.2f} "
      f"events={m['events_dispatched']}")
