"""Chaos suite (DESIGN.md §17): hypothesis-generated fault schedules
over fork/append/preempt/restore/quarantine/drain interleavings against
a real tiny engine.

The oracle extends ``test_radix_fuzz``'s leak discipline to the full
serving stack: whatever faults fire and wherever a drain cuts in,

  * every submitted request reaches a terminal ``finish_reason``;
  * after drain completes the engine reports ``drained`` and — once the
    trees release their refs — both device pools reclaim every page
    except the reserved dump page (zero page leaks);
  * error isolation holds: non-injected co-requests finish ``stop`` /
    ``length`` / scheduler-refused reasons, never a crash;
  * metrics stay coherent (counters match the faults that fired).

Optional-dep-guarded like test_radix_fuzz: the deterministic fallback
schedules below run even without hypothesis.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.paper_models import tiny_serving_model
from repro.core.config import ServeConfig
from repro.models import transformer as tfm
from repro.serving.api import ForkServer, SamplingParams

TERMINAL = {"stop", "length", "rejected", "stalled", "timeout", "error",
            "draining"}


@pytest.fixture(scope="module")
def model():
    cfg = tiny_serving_model(rank=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=16)
    return cfg, params, lora


def run_schedule(model, plan, seed, req_specs, drain_after, max_pages=12):
    """Drive one fault schedule to quiescence and check the invariants.

    ``req_specs``: list of (prompt_len, max_new, adapter) tuples;
    ``drain_after``: poll count after which drain() is called (None =
    never).  Small pool + preempt_after_steps=1 keeps preempt–restore in
    play on most schedules."""
    cfg, params, lora = model
    sc = ServeConfig(page_size=16, max_pages=max_pages, max_batch=4,
                     max_prefill_tokens=64, mode="forkkv",
                     max_pages_per_req=8, preempt_after_steps=1,
                     fault_plan=plan, fault_seed=seed)
    server = ForkServer(cfg, params, lora, sc)
    eng = server.engine
    rng = np.random.default_rng(seed)
    handles = []
    for i, (plen, max_new, aid) in enumerate(req_specs):
        prompt = [int(t) for t in rng.integers(0, cfg.vocab_size, plen)]
        handles.append(server.generate(
            aid, prompt, SamplingParams(max_new_tokens=max_new)))
    polls = 0
    while eng.waiting or eng.running:
        if drain_after is not None and polls == drain_after:
            server.drain()
        server.poll()
        polls += 1
        assert polls < 2000, "schedule failed to quiesce"

    # 1. every request reached a terminal state
    for h in handles:
        out = h.result()
        assert out.finish_reason in TERMINAL, out.finish_reason
        # non-injected failure reasons only ever come from the scheduler
        if out.finish_reason == "error":
            assert out.error, "error finish without a reason string"
    if drain_after is not None:
        assert eng.drained

    # 2. zero page leaks once the trees let go (dump page stays reserved)
    eng.dual.base.evict(eng.sc.max_pages)
    eng.dual.residual.evict(eng.res_pool.num_pages)
    assert eng.base_pool.free_pages == eng.sc.max_pages - 1, \
        "base pool leaked pages"
    assert eng.res_pool.free_pages == eng.res_pool.num_pages - 1, \
        "residual pool leaked pages"

    # 3. metrics coherence: counters only move when their fault fired
    m = server.metrics()
    fired = m["faults_fired"]
    if m["quarantined"]:
        assert fired.get("fault_nan_logits", 0) >= 1
    if fired.get("fault_executor", 0):
        assert m["exec_errors"] >= 1
    assert m["restored_requests"] <= m["preempted_requests"]
    assert m["fallback_gather_calls"] == 0
    return m


# ------------------------------------------------- deterministic fallback
def test_chaos_deterministic_preempt_and_quarantine(model):
    """One fixed schedule exercising preempt + quarantine + drain in a
    single run — the no-hypothesis smoke version of the fuzz below."""
    m = run_schedule(
        model, plan="nan_logits:r3", seed=5,
        req_specs=[(40, 12, 1), (40, 6, 2), (36, 6, 3), (38, 6, 4)],
        drain_after=None, max_pages=10)
    assert m["quarantined"] == 1


def test_chaos_deterministic_drain_mid_flight(model):
    m = run_schedule(
        model, plan="", seed=6,
        req_specs=[(40, 10, 1), (40, 10, 2), (40, 10, 3)],
        drain_after=2, max_pages=10)
    assert m["draining"] and m["drained"]


def test_chaos_deterministic_executor_storm(model):
    m = run_schedule(
        model, plan="executor:c2,c5;pool_alloc:c5,c6", seed=7,
        req_specs=[(40, 8, 1), (38, 8, 2), (36, 8, 3)],
        drain_after=None, max_pages=12)
    assert m["exec_errors"] >= 1


# ------------------------------------------------------- hypothesis fuzz
if HAVE_HYPOTHESIS:
    sites = st.sampled_from(
        ["pool_alloc", "nan_logits", "executor"])

    @st.composite
    def plans(draw):
        """0–3 fault rules with early-ish cN triggers (late triggers
        never fire on short schedules) and the occasional rN poisoning
        a specific request."""
        rules = []
        for site in draw(st.lists(sites, max_size=3, unique=True)):
            trigs = draw(st.lists(
                st.integers(1, 15).map(lambda n: f"c{n}"),
                min_size=1, max_size=2))
            if site == "nan_logits" and draw(st.booleans()):
                trigs = [f"r{draw(st.integers(1, 4))}"]
            rules.append(f"{site}:{','.join(trigs)}")
        return ";".join(rules)

    @settings(max_examples=8, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(data=st.data())
    def test_chaos_fault_schedule_fuzz(model, data):
        plan = data.draw(plans(), label="plan")
        seed = data.draw(st.integers(0, 99), label="seed")
        n_req = data.draw(st.integers(2, 4), label="n_req")
        req_specs = [
            (data.draw(st.sampled_from([32, 36, 40]), label=f"plen{i}"),
             data.draw(st.sampled_from([4, 6, 10]), label=f"new{i}"),
             1 + i)
            for i in range(n_req)]
        drain_after = data.draw(
            st.one_of(st.none(), st.integers(0, 6)), label="drain_after")
        max_pages = data.draw(st.sampled_from([9, 12, 16]),
                              label="max_pages")
        run_schedule(model, plan, seed, req_specs, drain_after,
                     max_pages=max_pages)
