"""Model-zoo correctness: forward/prefill/decode parity per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import LoRAConfig, ModelConfig
from repro.models import encdec, hybrid, ssm
from repro.models import transformer as tfm

TOL = dict(rtol=3e-4, atol=5e-4)


def dense_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                dtype="float32", lora=LoRAConfig(rank=8), remat=False)
    base.update(kw)
    return ModelConfig(**base)


def _prefill_decode_parity(mod, cfg, params, *, lora=None, ids=None,
                           disagg=False, extra=None, S=16, split=10):
    key = jax.random.PRNGKey(2)
    B = 2
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if lora is not None:
        kw = dict(lora=lora, adapter_ids=ids, disagg=disagg)
    fkw = dict(kw)
    pkw = dict(kw)
    if extra is not None:
        fkw["extra_embeds"] = extra
        pkw["extra_embeds"] = extra
    ref = mod.forward(params, tokens, cfg, **fkw)
    cache = mod.init_cache(cfg, B, 32, disagg=disagg, dtype=jnp.float32)
    lg, cache = mod.prefill(params, tokens[:, :split], cache, cfg, **pkw)
    off = ref.shape[1] - S           # vlm: logits include patch positions
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(ref[:, off + split - 1]), **TOL)
    kv_len = jnp.full((B,), split + off, jnp.int32)
    for t in range(split, S):
        lg2, cache = mod.decode_step(params, tokens[:, t], cache, kv_len,
                                     cfg, **kw)
        np.testing.assert_allclose(np.asarray(lg2),
                                   np.asarray(ref[:, off + t]), **TOL)
        kv_len = kv_len + 1


def test_dense_disagg_parity():
    cfg = dense_cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), 3)
    ids = jnp.array([0, 2])
    _prefill_decode_parity(tfm, cfg, params, lora=lora, ids=ids, disagg=True)


def test_dense_unified_lora_parity():
    cfg = dense_cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), 3)
    ids = jnp.array([1, 0])
    _prefill_decode_parity(tfm, cfg, params, lora=lora, ids=ids,
                           disagg=False)


def test_disagg_equals_unified_single_trajectory():
    """On one request the disaggregated math is EXACT (lossiness only comes
    from sharing bCache across divergent trajectories)."""
    cfg = dense_cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), 3)
    ids = jnp.array([0, 2])
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 97)
    a = tfm.forward(params, tokens, cfg, lora=lora, adapter_ids=ids)
    b = tfm.forward(params, tokens, cfg, lora=lora, adapter_ids=ids,
                    disagg=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4,
                               atol=5e-4)


def test_swa_ring_buffer_parity():
    cfg = dense_cfg(sliding_window=6)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    _prefill_decode_parity(tfm, cfg, params, S=20, split=12)


def test_moe_forward_finite_and_capacity():
    cfg = dense_cfg(family="moe", num_experts=4, num_experts_per_tok=2)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 97)
    logits = tfm.forward(params, tokens, cfg)
    assert bool(jnp.isfinite(logits).all())
    aux = tfm.moe_aux_loss(
        jax.tree_util.tree_map(lambda t: t[0], params["layers"]),
        params["embed"][tokens], cfg)
    assert float(aux) >= 1.0 - 1e-3      # >= 1 by Cauchy-Schwarz at balance


def test_moe_interleaved_parity():
    # capacity factor high enough to be dropless: token-drop patterns
    # differ between a 12-token full pass and an 8-token prefill, which is
    # expected capacity-MoE behaviour but breaks exact parity checks
    cfg = dense_cfg(family="moe", num_experts=4, num_experts_per_tok=1,
                    moe_interleave=2, moe_shared_expert=True,
                    moe_capacity_factor=8.0)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), 3)
    ids = jnp.array([0, 2])
    _prefill_decode_parity(tfm, cfg, params, lora=lora, ids=ids, disagg=True,
                           S=12, split=8)


def test_ssm_parity():
    cfg = ModelConfig(name="tssm", family="ssm", num_layers=2, d_model=64,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=97,
                      dtype="float32", ssm_state=16, ssm_heads=4,
                      remat=False)
    params = ssm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 70), 0, 97)
    ref = ssm.forward(params, tokens, cfg)
    cache = ssm.init_cache(cfg, 2, 70)
    lg, cache = ssm.prefill(params, tokens[:, :50], cache, cfg)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref[:, 49]),
                               **TOL)
    kv_len = jnp.full((2,), 50)
    for t in range(50, 55):
        lg2, cache = ssm.decode_step(params, tokens[:, t], cache, kv_len,
                                     cfg)
        np.testing.assert_allclose(np.asarray(lg2), np.asarray(ref[:, t]),
                                   **TOL)
        kv_len += 1


def test_hybrid_parity_disagg():
    cfg = ModelConfig(name="thyb", family="hybrid", num_layers=5, d_model=64,
                      num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=97,
                      dtype="float32",
                      block_pattern=("rglru", "rglru", "local"),
                      local_window=8, lru_width=64, lora=LoRAConfig(rank=8),
                      remat=False)
    params = hybrid.init_params(cfg, jax.random.PRNGKey(0))
    lora = hybrid.init_lora_stacks(cfg, jax.random.PRNGKey(1), 3)
    ids = jnp.array([0, 2])
    _prefill_decode_parity(hybrid, cfg, params, lora=lora, ids=ids,
                           disagg=True, S=20, split=12)


def test_whisper_parity():
    cfg = ModelConfig(name="tw", family="audio", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                      dtype="float32", use_rope=False,
                      is_encoder_decoder=True, num_encoder_layers=2,
                      encoder_seq=24, frontend="audio_stub",
                      mlp_activation="gelu", tie_embeddings=True,
                      remat=False)
    params = encdec.init_params(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 64))
    _prefill_decode_parity(encdec, cfg, params, extra=frames, S=16, split=10)


def test_flash_equals_exact_attention():
    from repro.core import attention as attn_lib
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 2, 150, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    got = attn_lib.flash_attention(q, k, v, qpos=pos, kpos=pos, causal=True,
                                   q_block=64, kv_block=32)
    s = attn_lib._gqa_scores(q, k) * D ** -0.5
    s = jnp.where(jnp.tril(jnp.ones((S, S), bool))[None, None], s, -1e30)
    want = attn_lib._gqa_out(jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_int8_kv_cache():
    """Beyond-paper int8 bCache: decode stays within quantization noise."""
    import dataclasses
    cfg = dense_cfg()
    cfg8 = dataclasses.replace(cfg, kv_quant="int8")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), 3)
    ids = jnp.array([0, 2])
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 97)
    ref = tfm.forward(params, tokens, cfg, lora=lora, adapter_ids=ids,
                      disagg=True)
    cache = tfm.init_cache(cfg8, 2, 32, disagg=True, dtype=jnp.float32)
    lg, cache = tfm.prefill(params, tokens[:, :10], cache, cfg8, lora=lora,
                            adapter_ids=ids, disagg=True)
    kv = jnp.full((2,), 10)
    lg2, cache = tfm.decode_step(params, tokens[:, 10], cache, kv, cfg8,
                                 lora=lora, adapter_ids=ids, disagg=True)
    err = float(jnp.abs(lg2 - ref[:, 10]).max())
    assert err < 0.05, err
    assert cache["k"].dtype == jnp.int8


def test_banded_prefill_parity_through_model():
    """The §Perf banded-window path must be bit-compatible with the dense
    path: force FLASH_THRESHOLD low so a ring-cache prefill takes it."""
    from repro.core import attention as attn_lib
    old = attn_lib.FLASH_THRESHOLD
    attn_lib.FLASH_THRESHOLD = 16
    try:
        cfg = dense_cfg(sliding_window=8)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 48), 0, 97)
        ref = tfm.forward(params, tokens, cfg)          # banded full path
        cache = tfm.init_cache(cfg, 2, 64, dtype=jnp.float32)
        lg, cache = tfm.prefill(params, tokens[:, :32], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(ref[:, 31]), **TOL)
        kv_len = jnp.full((2,), 32, jnp.int32)
        for t in range(32, 40):
            lg2, cache = tfm.decode_step(params, tokens[:, t], cache,
                                         kv_len, cfg)
            np.testing.assert_allclose(np.asarray(lg2),
                                       np.asarray(ref[:, t]), **TOL)
            kv_len = kv_len + 1
    finally:
        attn_lib.FLASH_THRESHOLD = old
