"""System-level behaviour: the paper's quality claims at small scale.

Fig. 5 analogue — with multiple LoRA agents over one shared context:
  * ForkKV (shared bCache + per-agent rCache) keeps hidden states close to
    exact per-agent caching (high cosine similarity),
  * full reuse (share EVERYTHING across adapters) diverges much further.
Plus: engine output parity against direct model decoding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import LoRAConfig, ModelConfig, ServeConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine, Request


def cos_sim(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="sys", family="dense", num_layers=4, d_model=128,
                      num_heads=8, num_kv_heads=4, d_ff=256, vocab_size=512,
                      dtype="float32", lora=LoRAConfig(rank=8), remat=False)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = tfm.init_lora_stacks(cfg, jax.random.PRNGKey(1), n_adapters=4)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 48), 0,
                                cfg.vocab_size)
    return cfg, params, lora, tokens


def _decode_with_cache(cfg, params, lora, tokens, cache, kv_len, ids,
                       disagg, steps=8):
    """Greedy-decode ``steps`` tokens given a prefilled cache."""
    outs = []
    logits_hist = []
    last = tokens[:, -1]
    for _ in range(steps):
        lg, cache = tfm.decode_step(params, last, cache, kv_len, cfg,
                                    lora=lora, adapter_ids=ids,
                                    disagg=disagg)
        logits_hist.append(lg)
        last = jnp.argmax(lg, -1)
        outs.append(int(last[0]))
        kv_len = kv_len + 1
    return outs, logits_hist


def test_forkkv_divergence_bounded_vs_full_reuse(setup):
    """ForkKV's lossy step (agent B reuses agent A's bCache) must stay far
    closer to exact than full reuse (agent B reuses ALL of A's cache)."""
    cfg, params, lora, tokens = setup
    B = tokens.shape[0]
    ids_a = jnp.zeros((B,), jnp.int32)
    ids_b = jnp.full((B,), 3, jnp.int32)

    # exact: agent B prefills its own full (unified) cache
    cache = tfm.init_cache(cfg, B, 96, dtype=jnp.float32)
    lg_exact, cache_exact = tfm.prefill(params, tokens, cache, cfg,
                                        lora=lora, adapter_ids=ids_b)

    # ForkKV: bCache from agent A's trajectory, rCache/Q from agent B
    cache = tfm.init_cache(cfg, B, 96, disagg=True, dtype=jnp.float32)
    _, cache_a = tfm.prefill(params, tokens, cache, cfg, lora=lora,
                             adapter_ids=ids_a, disagg=True)
    cache_fork = dict(cache_a)
    # agent B recomputes only its residuals over the SHARED bCache: run B's
    # disagg prefill and keep A's base entries (the shared, lossy part)
    cache_b = tfm.init_cache(cfg, B, 96, disagg=True, dtype=jnp.float32)
    _, cache_b = tfm.prefill(params, tokens, cache_b, cfg, lora=lora,
                             adapter_ids=ids_b, disagg=True)
    cache_fork["k_res"] = cache_b["k_res"]
    cache_fork["v_res"] = cache_b["v_res"]

    # full reuse: agent B uses agent A's unified cache verbatim
    cache = tfm.init_cache(cfg, B, 96, dtype=jnp.float32)
    _, cache_full = tfm.prefill(params, tokens, cache, cfg, lora=lora,
                                adapter_ids=ids_a)

    kv_len = jnp.full((B,), tokens.shape[1], jnp.int32)
    _, ref = _decode_with_cache(cfg, params, lora, tokens, cache_exact,
                                kv_len, ids_b, disagg=False)
    _, fork = _decode_with_cache(cfg, params, lora, tokens, cache_fork,
                                 kv_len, ids_b, disagg=True)
    _, full = _decode_with_cache(cfg, params, lora, tokens, cache_full,
                                 kv_len, ids_b, disagg=False)

    sim_fork = np.mean([cos_sim(a, b) for a, b in zip(ref, fork)])
    sim_full = np.mean([cos_sim(a, b) for a, b in zip(ref, full)])
    # Mechanism claim (paper Fig. 5): ForkKV stays far closer to exact than
    # full reuse.  The paper's absolute >99% similarity relies on a TRAINED
    # model's residual-stream robustness; on random weights the adapters
    # perturb activations much harder, so we assert the ordering + margin
    # here and measure the trained-model analogue in bench_quality.
    assert sim_fork > sim_full + 0.2, (sim_fork, sim_full)
    assert sim_fork > 0.5, sim_fork


def test_engine_matches_direct_model(setup):
    """A single request through the paged engine must reproduce the exact
    same greedy output as dense-cache decoding (no sharing involved)."""
    cfg, params, lora, tokens = setup
    prompt = [int(t) for t in np.asarray(tokens[0])]
    sc = ServeConfig(page_size=16, max_pages=128, max_batch=2,
                     max_prefill_tokens=64, mode="forkkv",
                     max_pages_per_req=8)
    eng = Engine(cfg, params, lora, sc)
    req = Request(rid=1, adapter_id=3, prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    while req.state != "done":
        eng.step()

    ids = jnp.full((1,), 3, jnp.int32)
    cache = tfm.init_cache(cfg, 1, 128, disagg=True, dtype=jnp.float32)
    lg, cache = tfm.prefill(params, tokens, cache, cfg, lora=lora,
                            adapter_ids=ids, disagg=True)
    kv_len = jnp.full((1,), len(prompt), jnp.int32)
    direct = [int(jnp.argmax(lg[0, 0]))]
    last = jnp.asarray([direct[-1]])
    for _ in range(6):
        lg2, cache = tfm.decode_step(params, last, cache, kv_len, cfg,
                                     lora=lora, adapter_ids=ids, disagg=True)
        direct.append(int(jnp.argmax(lg2[0])))
        last = jnp.asarray([direct[-1]])
        kv_len = kv_len + 1
    assert req.output[:6] == direct[:6], (req.output, direct)
