"""RG-LRU linear-scan Pallas kernel (Griffin / RecurrentGemma recurrence).

    h_t = a_t * h_{t-1} + b_t          a, b: (B, S, W)

The XLA path (models/hybrid._rglru_scan) uses a chunked associative scan;
on TPU the recurrence is bandwidth-bound and Griffin ships a dedicated
linear-scan kernel — this is that kernel's Pallas analogue.  Grid
(B, W//WB, S//BS) with the sequence dimension innermost: the running state
lives in VMEM scratch across sequence blocks, each block steps through BS
timesteps with vectorized FMAs over the WB lanes.

Validated in interpret mode against the associative-scan oracle
(tests/test_kernels.py::test_rg_lru_*).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 128
DEFAULT_BLOCK_W = 128


def _rglru_kernel(a_ref, b_ref, h0_ref, out_ref, h_scr, *, block_s: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)              # (BS, WB)
    b = b_ref[0].astype(jnp.float32)

    def step(i, h):
        hn = a[i] * h + b[i]
        out_ref[0, pl.dslice(i, 1), :] = hn[None].astype(out_ref.dtype)
        return hn

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "interpret"))
def rg_lru_scan(a, b, h0, *, block_s: int = DEFAULT_BLOCK_S,
                block_w: int = DEFAULT_BLOCK_W, interpret: bool = True):
    """Linear recurrence h_t = a_t h_{t-1} + b_t.

    a, b: (B, S, W); h0: (B, W).  Returns (states (B, S, W), h_last (B, W)).
    """
    from jax.experimental.pallas import tpu as pltpu

    bsz, s, w = a.shape
    bs = min(block_s, s)
    bw = min(block_w, w)
    ps, pw = (-s) % bs, (-w) % bw
    if ps or pw:
        # pad with a=1, b=0 (identity steps) so the carry passes through
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pw)))
        h0 = jnp.pad(h0, ((0, 0), (0, pw)))
    sp, wp = s + ps, w + pw

    grid = (bsz, wp // bw, sp // bs)
    kernel = functools.partial(_rglru_kernel, block_s=bs)
    states = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, sp, wp), a.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    states = states[:, :s, :w]
    return states, states[:, -1]
