"""Paper Fig. 14 — why ForkKV wins: per-agent memory, cache hit rate and
decode batch size, ForkKV vs prefix caching under identical load."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_workflow


def main() -> None:
    reps = {}
    for mode in ("forkkv", "prefix"):
        t0 = time.time()
        # mapreduce: parallel forks expose the decode-batch gains (Fig 14c)
        reps[mode] = run_workflow(mode, "mapreduce", n_workflows=3, agents=3,
                                  context=256, max_new=6, max_pages=192,
                                  max_batch=8, seed=1)
        reps[mode]["bench_us"] = (time.time() - t0) * 1e6
    f, p = reps["forkkv"], reps["prefix"]
    emit("internals.mem_per_agent", f["bench_us"],
         f"forkkv_MB={f['bytes_per_agent']/2**20:.2f};"
         f"prefix_MB={p['bytes_per_agent']/2**20:.2f};"
         f"reduction={p['bytes_per_agent']/max(f['bytes_per_agent'],1):.1f}x")
    gain = (f"{f['hit_rate']/p['hit_rate']:.1f}x" if p['hit_rate'] > 0
            else "inf(prefix=0)")
    emit("internals.hit_rate", p["bench_us"],
         f"forkkv={f['hit_rate']:.3f};prefix={p['hit_rate']:.3f};"
         f"gain={gain}")
    emit("internals.decode_batch", 0,
         f"forkkv={f['avg_decode_batch']:.2f};"
         f"prefix={p['avg_decode_batch']:.2f}")
    emit("internals.prefill_saved", 0,
         f"forkkv_frac={f['prefill_saved_frac']:.3f};"
         f"prefix_frac={p['prefill_saved_frac']:.3f}")
    emit("internals.hit_kinds", 0,
         ";".join(f"{k}={v}" for k, v in sorted(f["hit_kinds"].items())))


if __name__ == "__main__":
    main()
