"""Production mesh construction (TPU v5e).

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh for CPU tests/benchmarks."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
