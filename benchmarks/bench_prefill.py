"""Prefill cost vs prompt length: page-native prefill vs legacy gather.

The point of the page-native prefill path (DESIGN.md §13): the gather path
materializes every request's FULL block table for EVERY chunk — O(smax)
HBM traffic per chunk regardless of how many tokens the prompt actually
has — while the paged path's traffic tracks the live page count (bucketed
to powers of two).  So with ``smax`` fixed, gather per-token prefill cost
stays ~flat (pinned to smax) as the prompt shrinks, and paged per-token
cost drops with it.  Prefill is where shared-context agent workloads spend
their compute (PrefillShare / KVFlow), which is why this is the hot path
worth recording.

Method: for each (mode, path, ctx) cell, one ForkServer with a FIXED
``max_pages_per_req`` (so ``smax`` is identical across ctx values) prefills
one warm prompt (compiles the bucketed shapes) and then N DISTINCT fresh
prompts of the same length (radix misses, so prefill really recomputes);
the cell's cost is the delta of the engine's ``prefill_ms`` phase metric
per prompt token, min-of-N against scheduler noise.

Emits CSV rows (benchmarks.run harness format) AND writes
``BENCH_prefill.json`` — recorded next to ``BENCH_decode.json`` in the
repo's perf trajectory (both are CI artifacts).

  python -m benchmarks.bench_prefill             # full sweep
  python -m benchmarks.bench_prefill --smoke     # CI-sized, same JSON
"""
from __future__ import annotations

import argparse
import gc
import json
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import emit, get_tiny_model
from repro.core.config import ServeConfig
from repro.serving.api import ForkServer
from repro.serving.sampling import SamplingParams

FULL = dict(ctxs=(64, 128, 256, 448), max_pages_per_req=32, max_pages=640,
            passes=3)
SMOKE = dict(ctxs=(48, 96), max_pages_per_req=8, max_pages=192, passes=2)


def _measure_cell(mode: str, paged: bool, ctx: int, knobs: Dict) -> Dict:
    cfg, params, lora = get_tiny_model(rank=8)
    sc = ServeConfig(page_size=16, max_pages=knobs["max_pages"],
                     max_batch=4, max_prefill_tokens=128, mode=mode,
                     max_pages_per_req=knobs["max_pages_per_req"],
                     use_paged_kernel=paged)
    server = ForkServer(cfg, params, lora, sc)
    rng = np.random.default_rng(0)
    sp = SamplingParams(max_new_tokens=1)

    def one_pass(seed_offset: int) -> float:
        """Prefill one fresh ctx-length prompt; return Δprefill_ms."""
        prompt = list(rng.integers(0, cfg.vocab_size, ctx))
        m0 = server.metrics()
        out = server.wait([server.generate(1, prompt, sp)])[0]
        assert len(out.tokens) == 1, out
        return server.metrics()["prefill_ms"] - m0["prefill_ms"]

    one_pass(0)                         # warm: compiles the bucket shapes
    per_tok_ms = min(one_pass(i + 1) for i in range(knobs["passes"])) / ctx
    m = server.metrics()
    if paged:                           # acceptance probe: truly page-native
        assert m["fallback_gather_calls"] == 0, m["fallback_gather_calls"]
    return {
        "mode": mode,
        "path": "paged" if paged else "gather",
        "ctx_tokens": ctx,
        "smax_tokens": knobs["max_pages_per_req"] * sc.page_size,
        "us_per_prompt_token": per_tok_ms * 1e3,
        "fallback_gather_calls": m["fallback_gather_calls"],
    }


def run(smoke: bool) -> Dict:
    knobs = SMOKE if smoke else FULL
    rows: List[Dict] = []
    for mode in ("forkkv", "prefix"):
        for paged in (True, False):
            for ctx in knobs["ctxs"]:
                cell = _measure_cell(mode, paged, ctx, knobs)
                # each cell owns its own pools + jit cache; drop both so
                # later cells aren't measured under accumulated pressure
                gc.collect()
                jax.clear_caches()
                rows.append(cell)
                emit(f"prefill.{mode}.{cell['path']}.ctx{ctx}",
                     cell["us_per_prompt_token"],
                     f"smax={cell['smax_tokens']}")
    # scaling summary: per (mode, ctx extreme), paged per-token cost over
    # gather per-token cost — well below 1 at short ctx (gather pays smax,
    # paged pays live pages), converging toward 1 as ctx -> smax
    summary: Dict[str, float] = {}
    for mode in ("forkkv", "prefix"):
        sel = {p: [r for r in rows if r["mode"] == mode and r["path"] == p]
               for p in ("paged", "gather")}
        for tag, pick in (("short", min), ("long", max)):
            pg = pick(sel["paged"], key=lambda r: r["ctx_tokens"])
            ga = pick(sel["gather"], key=lambda r: r["ctx_tokens"])
            ratio = pg["us_per_prompt_token"] / \
                max(ga["us_per_prompt_token"], 1e-9)
            summary[f"{mode}.{tag}_ctx_paged_over_gather"] = round(ratio, 4)
            emit(f"prefill.{mode}.{tag}_paged_over_gather", 0, f"{ratio:.3f}")
    return {"smoke": smoke, "knobs": {k: list(v) if isinstance(v, tuple)
                                      else v for k, v in knobs.items()},
            "rows": rows, "summary": summary}


def main(argv=None) -> None:
    # benchmarks.run calls main() with no args while holding its own CLI
    # flags in sys.argv — parse only what we are explicitly handed
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (same JSON output)")
    ap.add_argument("--out", default="BENCH_prefill.json")
    args = ap.parse_args([] if argv is None else argv)
    report = run(args.smoke)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
