"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONs."""
from __future__ import annotations

import json
import os
import sys


def load(path):
    return json.load(open(path)) if os.path.exists(path) else []


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(recs):
    out = ["| arch | shape | status | compile_s | args GB/dev | temp GB/dev "
           "| HLO flops/dev (raw¹) | HLO coll B/dev (raw¹) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP² | | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL: "
                       f"{r.get('error','')[:40]} | | | | | |")
            continue
        m = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} "
            f"| {fmt_bytes(m.get('argument_size_in_bytes',0))} "
            f"| {fmt_bytes(m.get('temp_size_in_bytes',0))} "
            f"| {r['flops']:.2e} | {r['collectives']['total']:.2e} |")
    return "\n".join(out)


def roofline_table(recs):
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful³ | what moves the dominant term down |",
           "|---|---|---|---|---|---|---|---|"]
    hints = {
        ("train", "collective_s"): "less TP / more DP or FSDP; overlap AG "
                                   "with compute",
        ("train", "compute_s"): "near roofline; remat policy tuning",
        ("train", "memory_s"): "more grad accumulation; fused optimizers",
        ("prefill", "collective_s"): "FSDP-over-model instead of per-token "
                                     "TP all-reduces",
        ("prefill", "compute_s"): "causal block skipping in flash "
                                  "(counts full S² today)",
        ("prefill", "memory_s"): "larger flash q-blocks (fewer KV rereads)",
        ("decode", "memory_s"): "int8 bCache; paged reads of live pages "
                                "only",
        ("decode", "collective_s"): "replicate weights if they fit; "
                                    "batched all-reduce",
        ("decode", "compute_s"): "speculative decoding",
    }
    for r in recs:
        if r["status"] != "ok":
            continue
        a = r["analytic"]
        t = a["terms"]
        hint = hints.get((r["mode"], t["dominant"]), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} "
            f"| {t['memory_s']:.2e} | {t['collective_s']:.2e} "
            f"| **{t['dominant'].replace('_s','')}** "
            f"| {a.get('useful_fraction',0):.2f} | {hint} |")
    return "\n".join(out)


if __name__ == "__main__":
    for mesh in ("single", "multi"):
        recs = load(f"experiments/dryrun_{mesh}.json")
        if not recs:
            continue
        print(f"\n### {mesh}-pod mesh\n")
        print(dryrun_table(recs))
        print(f"\n### {mesh}-pod roofline\n")
        print(roofline_table(recs))
